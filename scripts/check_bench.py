"""Bench-regression gate: compare a benchmark run against the baseline,
or deterministically regenerate the baseline itself.

Usage:
    # gate (CI): fail on >10% est_wall drift per row
    PYTHONPATH=src python benchmarks/run.py --smoke --json > current.json
    python scripts/check_bench.py BENCH_baseline.json current.json

    # refresh the committed baseline (what the workflow_dispatch CI job
    # runs; byte-identical to piping run.py --smoke --json yourself)
    python scripts/check_bench.py --update [BENCH_baseline.json]

Both files are ``benchmarks/run.py --json`` documents (rows are emitted
in a stable name-sorted order, so regenerated baselines diff cleanly).
The gate fails (exit 1) when, for any table row present in the baseline:

* the row is missing from the current run (a table silently shrank), or
* its ``us_per_call`` (simulated est_wall in microseconds) drifts more
  than ``--tolerance`` (default 10%) in either direction, or
* a zero-cost baseline row (count-only tables like fig1/table2) became
  non-zero.

Rows only present in the current run are reported as informational —
new tables are how the benchmark surface grows — and on failure the
gate prints a per-row drift table covering EVERY offending row (worst
drift first), so the CI log shows the whole regression at once.
Refresh the baseline deliberately (``--update`` + commit) whenever a PR
*intends* to move est_wall.

The documents' ``scale`` section (measured simulator throughput —
object vs vectorized events/sec plus the 10k-node Monte-Carlo sweep)
is machine-dependent and therefore never drift-compared.  Instead the
gate applies thresholds to the CURRENT run:

* the largest churn trace must show at least ``--min-speedup`` (default
  50x) vectorized-over-object events/sec, and
* the Monte-Carlo sweep must finish within ``--max-mc-seconds``
  (default 10s),

so a simulator-throughput regression fails CI even though the absolute
rates float with the host.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
from typing import Dict, List, Tuple

DEFAULT_BASELINE = "BENCH_baseline.json"
_NAME_W = 44


def index_rows(doc: dict) -> Dict[str, float]:
    """Map row name -> us_per_call; duplicate names get ``#k`` suffixes.

    Some tables legitimately repeat a name (e.g. one ``fail`` row per
    victim node in a failure wave), so occurrences are disambiguated in
    order: ``name``, ``name#1``, ``name#2`` ...  (name-stable sorting in
    run.py keeps duplicates in their original relative order, so the
    suffixes match across runs).
    """
    out: Dict[str, float] = {}
    seen: Dict[str, int] = {}
    for row in doc.get("rows", []):
        name = str(row["name"])
        k = seen.get(name, 0)
        seen[name] = k + 1
        out[name if k == 0 else f"{name}#{k}"] = float(row["us_per_call"])
    return out


def _row(status: str, name: str, base: str, cur: str, drift: str) -> str:
    return (f"{status:<8} {name:<{_NAME_W}} {base:>12} {cur:>12} {drift:>8}")


def compare(
    baseline: dict, current: dict, tolerance: float = 0.10
) -> Tuple[List[str], List[str]]:
    """Return ``(failures, infos)`` comparing two ``--json`` documents.

    Failures are pre-formatted drift-table rows (status, row name,
    baseline us, current us, relative drift), worst drift first.
    """
    base = index_rows(baseline)
    cur = index_rows(current)
    failing: List[Tuple[float, str]] = []   # (|drift| sort key, row)
    infos: List[str] = []
    for name, b in base.items():
        if name not in cur:
            failing.append((float("inf"), _row(
                "MISSING", name, f"{b:.0f}", "—", "—")))
            continue
        c = cur[name]
        if b == 0.0:
            if c != 0.0:
                failing.append((float("inf"), _row(
                    "NONZERO", name, "0", f"{c:.0f}", "—")))
            continue
        drift = (c - b) / b
        if abs(drift) > tolerance:
            failing.append((abs(drift), _row(
                "DRIFT", name, f"{b:.0f}", f"{c:.0f}", f"{drift:+.1%}")))
    for name in cur:
        if name not in base:
            infos.append(f"NEW      {name}: {cur[name]:.0f} us (not in baseline)")
    # Ascending by -|drift|: MISSING/NONZERO (infinite severity) first,
    # then worst drift first.
    failures = [row for _, row in sorted(failing, key=lambda t: -t[0])]
    return failures, infos


def check_scale(
    current: dict, min_speedup: float = 50.0, max_mc_seconds: float = 10.0
) -> List[str]:
    """Threshold-check the current run's measured ``scale`` section.

    The section is measured wall time, so it is never compared against
    the baseline's copy (machine-dependent) — the thresholds themselves
    are the contract: the vectorized executor must beat the object path
    by ``min_speedup`` on the largest churn trace, and the Monte-Carlo
    sweep must finish within ``max_mc_seconds``.  A current run missing
    the section entirely fails too (the throughput gate silently
    disappearing is itself a regression).
    """
    failures: List[str] = []
    section = current.get("scale") or []
    churn = [r for r in section if r.get("table") == "scale"]
    if churn:
        big = max(churn, key=lambda r: r["events"])
        speedup = float(big.get("speedup_vs_object", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"SCALE    vectorized speedup at {big['events']} events is "
                f"{speedup:.1f}x (< required {min_speedup:.0f}x)")
    else:
        failures.append("SCALE    current run has no churn throughput rows "
                        "(scale section missing or empty)")
    mc = [r for r in section if r.get("table") == "scale-mc"]
    if mc:
        wall = float(mc[-1].get("wall_s", float("inf")))
        if wall > max_mc_seconds:
            failures.append(
                f"SCALE    Monte-Carlo sweep ({mc[-1].get('pool_nodes')} "
                f"nodes x {mc[-1].get('replicas')} replicas) took "
                f"{wall:.2f}s (> allowed {max_mc_seconds:.0f}s)")
    else:
        failures.append("SCALE    current run has no Monte-Carlo sweep row "
                        "(scale section missing or empty)")
    return failures


def _step_summary(lines: List[str]) -> None:
    """Append markdown to the GitHub Actions step summary, when present.

    No-op outside Actions (``GITHUB_STEP_SUMMARY`` unset), so local runs
    behave identically — the summary is a CI-reviewer convenience, not
    part of the gate's contract.
    """
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as f:
        for line in lines:
            f.write(line + "\n")


def update_baseline(path: str) -> int:
    """Regenerate ``path`` as a fresh ``--smoke --json`` document.

    Runs the benchmark driver in-process and writes its exact stdout, so
    the result is byte-identical to
    ``PYTHONPATH=src python benchmarks/run.py --smoke --json > path``.
    The simulator is deterministic and rows are name-sorted, so the
    drift-compared ``rows``/``envelopes`` sections reproduce exactly
    across refreshes of the same tree; only the measured ``scale``
    section (exempt from drift comparison) floats with the host.
    """
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (os.path.join(repo, "benchmarks"), os.path.join(repo, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    import run as bench_run  # benchmarks/run.py

    committed = 0
    if os.path.exists(path):
        try:
            with open(path) as f:
                committed = len(index_rows(json.load(f)))
        except (json.JSONDecodeError, OSError):
            committed = 0       # unreadable old baseline: report from zero
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        bench_run.main(["--smoke", "--json"])
    text = buf.getvalue()
    doc = json.loads(text)          # refuse to write a malformed baseline
    with open(path, "w") as f:
        f.write(text)
    refreshed = len(index_rows(doc))
    delta = refreshed - committed
    print(f"check_bench: wrote {len(doc['rows'])} rows to {path} "
          f"({committed} committed -> {refreshed} refreshed, {delta:+d})")
    _step_summary([
        "### Bench baseline refresh",
        "",
        f"- committed rows: **{committed}**",
        f"- refreshed rows: **{refreshed}** ({delta:+d})",
    ])
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", nargs="?", default=DEFAULT_BASELINE,
                    help=f"committed baseline (default {DEFAULT_BASELINE})")
    ap.add_argument("current", nargs="?", default=None,
                    help="fresh benchmarks/run.py --smoke --json output")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative est_wall drift per row (default 0.10)")
    ap.add_argument("--min-speedup", type=float, default=50.0,
                    help="required vectorized-over-object events/sec speedup "
                         "on the largest churn trace (default 50)")
    ap.add_argument("--max-mc-seconds", type=float, default=10.0,
                    help="allowed wall time for the Monte-Carlo sweep row "
                         "(default 10)")
    ap.add_argument("--update", action="store_true",
                    help="regenerate the baseline file deterministically "
                         "instead of comparing")
    args = ap.parse_args(argv)

    if args.update:
        if args.current is not None:
            ap.error("--update takes only the baseline path")
        path = args.baseline
        if not os.path.isabs(path):
            # Resolve against the repo root, not the CWD: running the
            # script from elsewhere must refresh the committed baseline,
            # not silently create a stray copy.
            repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            path = os.path.join(repo, path)
        return update_baseline(path)
    if args.current is None:
        ap.error("compare mode needs both baseline and current files")

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    if baseline.get("smoke") != current.get("smoke"):
        print("check_bench: baseline and current were produced with "
              "different --smoke settings; comparing anyway", file=sys.stderr)

    failures, infos = compare(baseline, current, tolerance=args.tolerance)
    scale_failures = check_scale(
        current, min_speedup=args.min_speedup,
        max_mc_seconds=args.max_mc_seconds)
    for line in infos:
        print(line)
    n = len(index_rows(baseline))
    _step_summary([
        "### Bench regression gate",
        "",
        f"- baseline rows compared: **{n}**",
        f"- new rows (current only): **{len(infos)}**",
        f"- failing rows: **{len(failures) + len(scale_failures)}**",
    ])
    if failures or scale_failures:
        if failures:
            print(_row("status", "row", "baseline_us", "current_us", "drift"),
                  file=sys.stderr)
            for line in failures:
                print(line, file=sys.stderr)
        for line in scale_failures:
            print(line, file=sys.stderr)
        print(f"check_bench: {len(failures)}/{n} baseline rows + "
              f"{len(scale_failures)} throughput thresholds FAILED "
              f"(tolerance {args.tolerance:.0%}, min speedup "
              f"{args.min_speedup:.0f}x, max MC {args.max_mc_seconds:.0f}s)",
              file=sys.stderr)
        return 1
    print(f"check_bench: {n} baseline rows within {args.tolerance:.0%}; "
          f"throughput >= {args.min_speedup:.0f}x, "
          f"MC <= {args.max_mc_seconds:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
