"""Bench-regression gate: compare a benchmark run against the baseline.

Usage:
    PYTHONPATH=src python benchmarks/run.py --smoke --json > current.json
    python scripts/check_bench.py BENCH_baseline.json current.json

Both files are ``benchmarks/run.py --json`` documents.  The gate fails
(exit 1) when, for any table row present in the baseline:

* the row is missing from the current run (a table silently shrank), or
* its ``us_per_call`` (simulated est_wall in microseconds) drifts more
  than ``--tolerance`` (default 10%) in either direction, or
* a zero-cost baseline row (count-only tables like fig1/table2) became
  non-zero.

Rows only present in the current run are reported as informational —
new tables are how the benchmark surface grows — and the gate prints
every drifting row before failing, so the artifact shows the whole
regression at once.  Refresh the baseline deliberately (rerun the two
commands above and commit) whenever a PR *intends* to move est_wall.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple


def index_rows(doc: dict) -> Dict[str, float]:
    """Map row name -> us_per_call; duplicate names get ``#k`` suffixes.

    Some tables legitimately repeat a name (e.g. one ``fail`` row per
    victim node in a failure wave), so occurrences are disambiguated in
    order: ``name``, ``name#1``, ``name#2`` ...
    """
    out: Dict[str, float] = {}
    seen: Dict[str, int] = {}
    for row in doc.get("rows", []):
        name = str(row["name"])
        k = seen.get(name, 0)
        seen[name] = k + 1
        out[name if k == 0 else f"{name}#{k}"] = float(row["us_per_call"])
    return out


def compare(
    baseline: dict, current: dict, tolerance: float = 0.10
) -> Tuple[List[str], List[str]]:
    """Return ``(failures, infos)`` comparing two ``--json`` documents."""
    base = index_rows(baseline)
    cur = index_rows(current)
    failures: List[str] = []
    infos: List[str] = []
    for name, b in base.items():
        if name not in cur:
            failures.append(f"MISSING  {name}: baseline {b:.0f} us, no current row")
            continue
        c = cur[name]
        if b == 0.0:
            if c != 0.0:
                failures.append(f"NONZERO  {name}: baseline 0 us -> {c:.0f} us")
            continue
        drift = (c - b) / b
        if abs(drift) > tolerance:
            failures.append(
                f"DRIFT    {name}: {b:.0f} us -> {c:.0f} us ({drift:+.1%})"
            )
    for name in cur:
        if name not in base:
            infos.append(f"NEW      {name}: {cur[name]:.0f} us (not in baseline)")
    return failures, infos


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", help="fresh benchmarks/run.py --smoke --json output")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative est_wall drift per row (default 0.10)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    if baseline.get("smoke") != current.get("smoke"):
        print("check_bench: baseline and current were produced with "
              "different --smoke settings; comparing anyway", file=sys.stderr)

    failures, infos = compare(baseline, current, tolerance=args.tolerance)
    for line in infos:
        print(line)
    for line in failures:
        print(line, file=sys.stderr)
    n = len(index_rows(baseline))
    if failures:
        print(f"check_bench: {len(failures)}/{n} baseline rows FAILED "
              f"(tolerance {args.tolerance:.0%})", file=sys.stderr)
        return 1
    print(f"check_bench: {n} baseline rows within {args.tolerance:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
