"""Public-API snapshot gate: the stable surface cannot shrink silently.

``repro.api.__all__`` is THE compatibility contract (``docs/api.md``).
This gate compares the live surface against the committed
``API_SNAPSHOT.txt`` (one sorted name per line) and fails (exit 1) when:

* a snapshot name is missing from ``repro.api.__all__`` — a public name
  was deleted or renamed without the one-release shim the deprecation
  policy requires;
* any name in ``__all__`` does not actually resolve via
  ``getattr(repro.api, name)`` — an export that raises on first touch
  is a broken promise whether or not the snapshot lists it (lazy
  JAX-backed names are exempted from resolution on hosts without jax;
  their *listing* is still checked).

Names present in ``__all__`` but not in the snapshot are reported as
informational — growing the surface is fine; run ``--update`` and
commit the refreshed snapshot so the addition is reviewed.

Usage:
    # gate (CI)
    PYTHONPATH=src python scripts/check_api.py

    # refresh the committed snapshot after deliberately changing the
    # surface (then commit API_SNAPSHOT.txt with the change)
    PYTHONPATH=src python scripts/check_api.py --update
"""
from __future__ import annotations

import argparse
import os
import sys

DEFAULT_SNAPSHOT = "API_SNAPSHOT.txt"


def _snapshot_path(path: str) -> str:
    if os.path.isabs(path):
        return path
    # Resolve against the repo root, not the CWD: running the script
    # from elsewhere must hit the committed snapshot, not a stray copy.
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    return os.path.join(repo, path)


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def live_surface():
    """Return ``(names, lazy_names)`` from the live ``repro.api``."""
    from repro import api

    return sorted(api.__all__), frozenset(api._LAZY_EXPORTS)


def check(path: str) -> int:
    from repro import api

    names, lazy = live_surface()
    failures: list[str] = []
    infos: list[str] = []

    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        failures.append(f"DUPLICATE  __all__ repeats: {', '.join(dupes)}")

    try:
        with open(path) as f:
            snapshot = [ln.strip() for ln in f if ln.strip()
                        and not ln.lstrip().startswith("#")]
    except FileNotFoundError:
        print(f"check_api: snapshot {path} missing — run --update and "
              "commit it", file=sys.stderr)
        return 1

    current = set(names)
    for name in snapshot:
        if name not in current:
            failures.append(
                f"REMOVED    {name!r} is in {os.path.basename(path)} but "
                "not in repro.api.__all__ (deprecation policy: shim for "
                "one release, then --update)")
    for name in names:
        if name not in snapshot:
            infos.append(f"NEW        {name!r} not yet in snapshot "
                         "(run --update and commit)")

    resolve = names if _jax_available() else [n for n in names
                                              if n not in lazy]
    skipped = len(names) - len(resolve)
    for name in resolve:
        try:
            getattr(api, name)
        except Exception as exc:
            failures.append(f"BROKEN     repro.api.{name} raises "
                            f"{type(exc).__name__}: {exc}")

    for line in infos:
        print(line)
    for line in failures:
        print(line, file=sys.stderr)
    if failures:
        print(f"check_api: FAILED — {len(failures)} problems "
              f"({len(snapshot)} snapshot names, {len(names)} live names)",
              file=sys.stderr)
        return 1
    note = f", {skipped} jax-backed names listing-checked only" if skipped \
        else ""
    print(f"check_api: {len(names)} public names OK against "
          f"{os.path.basename(path)} ({len(resolve)} resolved{note})")
    return 0


def update(path: str) -> int:
    names, _ = live_surface()
    with open(path, "w") as f:
        f.write("# repro.api public surface — regenerate with\n"
                "#   PYTHONPATH=src python scripts/check_api.py --update\n")
        for name in names:
            f.write(name + "\n")
    print(f"check_api: wrote {len(names)} names to {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("snapshot", nargs="?", default=DEFAULT_SNAPSHOT,
                    help=f"committed snapshot (default {DEFAULT_SNAPSHOT})")
    ap.add_argument("--update", action="store_true",
                    help="regenerate the snapshot from the live surface "
                         "instead of comparing")
    args = ap.parse_args(argv)
    path = _snapshot_path(args.snapshot)
    return update(path) if args.update else check(path)


if __name__ == "__main__":
    sys.exit(main())
