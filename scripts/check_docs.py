#!/usr/bin/env python
"""Docs health checks (the CI `docs` job).

Three checks, selectable by flag (default: all):

* ``--links``  — every intra-repo markdown link (``[text](path)`` with a
  relative, non-http target) in ``*.md`` files must resolve to an
  existing file, anchor stripped.
* ``--imports`` — every module under ``src/repro`` must be
  ``python -m pydoc``-importable (imported via ``pydoc.safeimport``, the
  machinery behind pydoc), so the documented API surface can always be
  rendered.
* ``--registry`` — docs–registry completeness: every registered
  scenario name must appear in ``docs/scenarios.md`` and every
  registered strategy key in ``docs/strategies.md``, so registering
  something without documenting it fails CI.

Exits non-zero listing every failure.
"""
from __future__ import annotations

import argparse
import pydoc
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".github", "results", "__pycache__", ".claude"}


def iter_markdown() -> list[Path]:
    return [
        p for p in REPO.rglob("*.md")
        if not any(part in SKIP_DIRS for part in p.parts)
    ]


def check_links() -> list[str]:
    errors = []
    for md in iter_markdown():
        for target in MD_LINK.findall(md.read_text()):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, ...
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure in-page anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def repro_modules() -> list[str]:
    src = REPO / "src"
    mods = []
    for py in sorted((src / "repro").rglob("*.py")):
        rel = py.relative_to(src).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.append(".".join(parts))
    return sorted(set(mods))


def check_imports() -> list[str]:
    sys.path.insert(0, str(REPO / "src"))
    errors = []
    for mod in repro_modules():
        try:
            if pydoc.safeimport(mod) is None:
                errors.append(f"{mod}: not found by pydoc")
        except pydoc.ErrorDuringImport as exc:
            errors.append(f"{mod}: {exc}")
    return errors


def check_registry() -> list[str]:
    """Registered scenarios/strategies must appear in their guide."""
    sys.path.insert(0, str(REPO / "src"))
    from repro.core import registered_strategies
    from repro.malleability import registered_scenarios

    errors = []
    for doc, names in (
        ("docs/scenarios.md",
         [sc.name for sc in registered_scenarios()]),
        ("docs/strategies.md",
         [spec.key for spec in registered_strategies()]),
    ):
        text = (REPO / doc).read_text()
        for name in names:
            if f"`{name}`" not in text:
                errors.append(f"{doc}: registered name `{name}` "
                              "is not documented")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links", action="store_true")
    ap.add_argument("--imports", action="store_true")
    ap.add_argument("--registry", action="store_true")
    args = ap.parse_args()
    run_all = not (args.links or args.imports or args.registry)

    errors = []
    if args.links or run_all:
        errors += check_links()
    if args.imports or run_all:
        errors += check_imports()
    if args.registry or run_all:
        errors += check_registry()
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        checked = []
        if args.links or run_all:
            checked.append(f"{len(iter_markdown())} markdown files")
        if args.imports or run_all:
            checked.append(f"{len(repro_modules())} modules")
        if args.registry or run_all:
            checked.append("registry coverage")
        print("docs OK:", ", ".join(checked))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
