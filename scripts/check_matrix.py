"""Strategy x scenario completeness gate.

Replays EVERY registered scenario under EVERY registered spawning
strategy through the timeline-charging simulator and fails (exit 1)
when:

* any compatible pair raises — a new strategy or scenario that silently
  cannot run the rest of the registry is exactly the coverage rot this
  gate exists to stop;
* any registered strategy ends up exercised by zero scenarios, or any
  registered scenario by zero strategies — a registry entry nothing can
  run is dead weight at best and a wiring bug at worst;
* a compatible pair produces zero reconfiguration records — the trace
  ran but did nothing, so its numbers pin nothing.

The only pairs skipped are the *documented* incompatibility: a
``homogeneous_only`` strategy (hypercube, §4.1) on a heterogeneous
uneven-width pool, which the planner rejects by design with its §4.2
guidance error.

Usage:
    PYTHONPATH=src python scripts/check_matrix.py [-v]
"""
from __future__ import annotations

import argparse
import sys
import traceback

# Strategies the registry must always carry: losing one of these to an
# import-order or registration regression would silently shrink the
# matrix instead of failing it.  "dmr-async" in particular must replay
# every registered scenario (the two-phase expansion path).
REQUIRED_STRATEGIES = ("sequential", "per_node", "single", "hypercube",
                       "diffusive", "topo", "dmr-async")

# Scenarios the registry must always carry, for the same reason.  The
# fault family (checkpoint/restart) is listed explicitly because the
# full-stop path has no other always-on sweep: losing its registration
# would drop CHECKPOINT/RESTORE charging from the matrix silently.
REQUIRED_SCENARIOS = ("ckpt-cycle", "node-fail-wave", "restart-vs-shrink")


def run_matrix(verbose: bool = False) -> int:
    from repro.core import registered_strategies
    from repro.malleability import registered_scenarios, run_scenario_sim

    strategies = registered_strategies()
    scenarios = registered_scenarios()
    failures: list[str] = []
    registered = {s.key for s in strategies}
    for key in REQUIRED_STRATEGIES:
        if key not in registered:
            failures.append(
                f"MISSING  required strategy {key!r} is not registered")
    registered_names = {sc.name for sc in scenarios}
    for name in REQUIRED_SCENARIOS:
        if name not in registered_names:
            failures.append(
                f"MISSING  required scenario {name!r} is not registered")
    exercised_strategy: dict[str, int] = {s.key: 0 for s in strategies}
    exercised_scenario: dict[str, int] = {sc.name: 0 for sc in scenarios}
    pairs = skipped = 0

    for sc in scenarios:
        for spec in strategies:
            if spec.homogeneous_only and sc.heterogeneous:
                skipped += 1      # documented §4.1/§4.2 incompatibility
                continue
            pairs += 1
            try:
                recs = run_scenario_sim(
                    sc, engine=sc.default_engine(strategy=spec.key))
            except Exception:
                failures.append(
                    f"ERROR    {sc.name} x {spec.key}:\n"
                    + traceback.format_exc(limit=3)
                )
                continue
            if not recs:
                failures.append(
                    f"EMPTY    {sc.name} x {spec.key}: trace produced no "
                    "reconfiguration records"
                )
                continue
            exercised_strategy[spec.key] += 1
            exercised_scenario[sc.name] += 1
            if verbose:
                print(f"ok  {sc.name:<22} x {spec.key:<12} "
                      f"{len(recs)} events")

    for key, n in exercised_strategy.items():
        if n == 0:
            failures.append(
                f"UNUSED   strategy {key!r} is exercised by no scenario")
    for name, n in exercised_scenario.items():
        if n == 0:
            failures.append(
                f"UNUSED   scenario {name!r} is exercised by no strategy")

    for line in failures:
        print(line, file=sys.stderr)
    if failures:
        print(
            f"check_matrix: FAILED — {len(failures)} problems across "
            f"{pairs} pairs ({len(strategies)} strategies x "
            f"{len(scenarios)} scenarios, {skipped} documented skips)",
            file=sys.stderr,
        )
        return 1
    print(
        f"check_matrix: {pairs} strategy x scenario pairs OK "
        f"({len(strategies)} strategies x {len(scenarios)} scenarios, "
        f"{skipped} documented homogeneous-only skips)"
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print one line per passing pair")
    args = ap.parse_args(argv)
    return run_matrix(verbose=args.verbose)


if __name__ == "__main__":
    sys.exit(main())
