"""MalleabilityManager facade + RMS event plumbing tests."""
import pytest

from repro.core import (
    MalleabilityManager,
    Method,
    Strategy,
    binary_connection_schedule,
)
from repro.elastic.rms import Event, EventKind, SimulatedRMS


class TestManager:
    def test_expand_plan_carries_all_stages(self):
        mgr = MalleabilityManager(method=Method.MERGE,
                                  strategy=Strategy.PARALLEL_HYPERCUBE)
        plan = mgr.plan_expand(ns=4, nt=16, cores=4)
        assert plan.kind == "expand"
        assert plan.spawn is not None and len(plan.spawn.groups) == 3
        assert plan.sync_graph is not None
        assert plan.connect_rounds == len(binary_connection_schedule(3))
        # stage 3: final layout covers the whole target world
        assert len(plan.redistribution.layout) == 16

    def test_hypercube_rejects_heterogeneous(self):
        mgr = MalleabilityManager(strategy=Strategy.PARALLEL_HYPERCUBE)
        with pytest.raises(ValueError):
            mgr.plan_expand(ns=4, nt=10, cores=[4, 2, 4])

    def test_diffusive_accepts_heterogeneous(self):
        mgr = MalleabilityManager(strategy=Strategy.PARALLEL_DIFFUSIVE)
        plan = mgr.plan_expand(ns=4, nt=10, cores=[4, 2, 4])
        assert plan.spawn.strategy is Strategy.PARALLEL_DIFFUSIVE
        assert sum(plan.spawn.group_sizes) == 6

    def test_sequential_strategies_have_no_sync_graph(self):
        for strat in (Strategy.SEQUENTIAL, Strategy.SINGLE,
                      Strategy.SEQUENTIAL_PER_NODE):
            mgr = MalleabilityManager(strategy=strat)
            plan = mgr.plan_expand(ns=4, nt=12, cores=4)
            assert plan.sync_graph is None
            assert plan.connect_rounds == 0

    def test_classic_merge_world_blocks_ts(self):
        """The defining contrast: one sequential spawn -> a multi-node
        world; parallel spawn -> node-confined groups."""
        seq = MalleabilityManager(strategy=Strategy.SEQUENTIAL).plan_expand(4, 16, 4)
        par = MalleabilityManager(strategy=Strategy.PARALLEL_HYPERCUBE).plan_expand(4, 16, 4)
        assert len(seq.spawn.groups[0].nodes_spanned()) == 3
        assert all(len(g.nodes_spanned()) == 1 for g in par.spawn.groups)


class TestRMS:
    def test_scripted_events_fire_once_in_order(self):
        rms = SimulatedRMS.scripted([
            (5, EventKind.GROW, 8),
            (10, EventKind.SHRINK, (6, 7)),
            (15, EventKind.FAIL, 3),
        ])
        assert list(rms.events_until(4)) == []
        evs = list(rms.events_until(10))
        assert [e.kind for e in evs] == [EventKind.GROW, EventKind.SHRINK]
        assert evs[0].target_nodes == 8
        assert evs[1].nodes == (6, 7)
        assert list(rms.events_until(10)) == []          # consumed
        assert [e.kind for e in rms.events_until(99)] == [EventKind.FAIL]
