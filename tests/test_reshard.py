"""Stage-3 resharding accounting: transfer_stats edge cases and the
predicted (devices_indices_map) twin that the cost simulator charges.

Multi-device cases run in a subprocess (the main test process must keep
seeing 1 device); the empty-tree edge cases run in-process.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.elastic import predicted_transfer_stats, transfer_stats

ZEROS = {"bytes_total": 0, "bytes_stayed": 0, "bytes_moved": 0}


class TestEmptyTree:
    def test_transfer_stats_empty_tree(self):
        assert transfer_stats({}, {}) == ZEROS
        assert transfer_stats([], []) == ZEROS

    def test_predicted_transfer_stats_empty_tree(self):
        assert predicted_transfer_stats({}, {}, {}) == ZEROS


RESHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.elastic import predicted_transfer_stats, transfer_stats

    devs = jax.devices()

    def mesh(k):
        return Mesh(np.asarray(devs[:k], dtype=object).reshape((k,)), ("data",))

    def place(tree, shardings):
        return jax.device_put(tree, shardings)  # broadcasts a single sharding

    def check(label, tree, old_sh, new_sh):
        old = place(tree, old_sh)
        new = place(old, new_sh)
        measured = transfer_stats(old, new)
        predicted = predicted_transfer_stats(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree),
            old_sh, new_sh)
        assert measured == predicted, (label, measured, predicted)
        print("RESHARD_OK", label, measured["bytes_moved"], "moved")
        return measured

    tree = {
        "replicated": jnp.ones((16, 16), jnp.float32),   # 1024 B
        "sharded": jnp.ones((8, 4), jnp.float32),        # 128 B, split on dim 0
    }
    rep, shd = P(), P("data")

    def sh(k):
        m = mesh(k)
        return {"replicated": NamedSharding(m, rep),
                "sharded": NamedSharding(m, shd)}

    # grow-only: 2 -> 4 devices
    m = check("grow", tree, sh(2), sh(4))
    # replicated leaf ships one copy to each NEW device; sharded leaf's
    # bounds all change (8 rows: 4+4 -> 2+2+2+2), so it moves entirely.
    assert m["bytes_moved"] == 2 * 1024 + 128, m
    assert m["bytes_stayed"] == 2 * 1024, m

    # shrink-only: 4 -> 2 devices
    m = check("shrink", tree, sh(4), sh(2))
    # survivor replicas suffice; the sharded leaf rebalances entirely.
    assert m["bytes_moved"] == 128, m
    assert m["bytes_stayed"] == 2 * 1024, m

    # identity: nothing moves
    m = check("identity", tree, sh(4), sh(4))
    assert m["bytes_moved"] == 0, m

    # uneven shard counts: 3-way -> 2-way split of dim 6 (neither count
    # divides the other, so no shard bounds coincide and all bytes move).
    uneven = {"u": jnp.ones((6,), jnp.float32)}
    m3 = {"u": NamedSharding(mesh(3), P("data"))}
    m2 = {"u": NamedSharding(mesh(2), P("data"))}
    m = check("uneven", uneven, m3, m2)
    assert m["bytes_moved"] == m["bytes_total"] == 24, m

    # single-sharding broadcast form (one sharding for the whole tree)
    one = {"a": jnp.ones((4, 4), jnp.float32), "b": jnp.ones((2,), jnp.float32)}
    m = check("broadcast", one, NamedSharding(mesh(2), P()),
              NamedSharding(mesh(4), P()))
    assert m["bytes_moved"] == 2 * (64 + 8), m

    print("ALL_RESHARD_CASES_OK")
""")


@pytest.mark.slow
def test_predicted_equals_measured_across_reshards():
    """predicted_transfer_stats must equal transfer_stats byte-for-byte
    for grow-only, shrink-only, identity, uneven-shard, and broadcast
    sharding transitions (8 forced host devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", RESHARD_SCRIPT], capture_output=True, text=True,
        timeout=600, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    assert "ALL_RESHARD_CASES_OK" in proc.stdout
