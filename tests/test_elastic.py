"""Elastic runtime tests: bookkeeping in-process, live resizing via
subprocess (the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

from repro.core import Method, ShrinkKind, Strategy
from repro.elastic import DevicePool, ElasticRuntime


def make_runtime(n_free=8):
    devs = [object() for _ in range(n_free)]  # bookkeeping-only fake devices
    pool = DevicePool(devices=devs, devices_per_node=1)
    return ElasticRuntime(pool=pool, initial_nodes=1)


class TestRuntimeBookkeeping:
    def test_expand_creates_node_confined_groups(self):
        rt = make_runtime()
        rec = rt.expand(5)
        assert rec.nodes_after == 5
        assert rec.mechanism == "hypercube"
        # every world spans exactly one node (the TS invariant)
        for w in rt.state.worlds.values():
            assert len(w.nodes) == 1

    def test_shrink_returns_devices_to_pool(self):
        rt = make_runtime()
        rt.expand(6)
        free_before = len(rt.pool.free)
        rec = rt.shrink(4)
        assert rec.mechanism == ShrinkKind.TS.value
        assert len(rec.nodes_returned) == 4
        assert len(rt.pool.free) == free_before + 4
        assert rt.n_nodes == 2

    def test_expand_after_shrink_reuses_nodes(self):
        rt = make_runtime()
        rt.expand(8)
        rt.shrink(6)
        rec = rt.expand(5)
        assert rec.nodes_after == 5

    def test_fail_node_is_forced_ts(self):
        rt = make_runtime()
        rt.expand(4)
        victim = sorted(rt.state.nodes_in_use())[-1]
        rec = rt.fail_node(victim)
        assert rec.kind == "fail"
        assert victim in rec.nodes_returned
        assert victim not in rt.state.nodes_in_use()

    def test_straggler_mitigation(self):
        rt = make_runtime()
        rt.expand(4)
        victim = sorted(rt.state.nodes_in_use())[1]
        rec = rt.drop_straggler(victim)
        assert rec.kind == "straggler"
        assert rt.n_nodes == 3

    def test_pool_exhaustion_raises(self):
        rt = make_runtime(n_free=4)
        with pytest.raises(RuntimeError):
            rt.expand(16)

    def test_shrink_cost_is_sub_millisecond_expand_is_not(self):
        rt = make_runtime()
        e = rt.expand(8)
        s = rt.shrink(6)
        assert s.est_wall_s < 1e-3 < e.est_wall_s

    def test_diffusive_strategy(self):
        rt = ElasticRuntime(
            pool=DevicePool(devices=[object()] * 8, devices_per_node=1),
            strategy=Strategy.PARALLEL_DIFFUSIVE,
            initial_nodes=1,
        )
        rec = rt.expand(6)
        assert rec.mechanism == "diffusive"
        assert rt.n_nodes == 6


@pytest.mark.slow
class TestLiveElastic:
    def test_elastic_train_example_end_to_end(self):
        """Run the full elastic training demo (8 host devices) and assert
        its internal loss-continuity checks pass."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = "src"
        proc = subprocess.run(
            [sys.executable, "examples/elastic_train.py"],
            capture_output=True, text=True, timeout=1200, env=env,
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        assert "loss continuous across 4 resizes" in proc.stdout
        assert "termination_shrinkage" in proc.stdout
