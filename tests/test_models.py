"""Per-arch smoke tests + decode/forward equivalence (reduced configs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, arch_config, input_shapes, smoke_config
from repro.models import Model


def make_batch(cfg, key, B, S):
    ks = jax.random.split(key, 3)
    if cfg.embed_inputs:
        batch = {
            "embeds": jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
    else:
        batch = {
            "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = smoke_config(arch)
        m = Model(cfg)
        params, specs = m.init(jax.random.key(0))
        assert set(params) == set(specs)
        B, S = 2, 16
        batch = make_batch(cfg, jax.random.key(1), B, S)
        logits, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    def test_one_train_step_no_nans(self, arch):
        from repro.optim import adamw_init, adamw_update

        cfg = smoke_config(arch)
        m = Model(cfg)
        params, _ = m.init(jax.random.key(0))
        batch = make_batch(cfg, jax.random.key(1), 2, 16)
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt, batch):
            loss, grads = jax.value_and_grad(m.loss)(params, batch)
            params, opt = adamw_update(grads, opt, params, 1e-3)
            return params, opt, loss

        params, opt, loss = step(params, opt, batch)
        assert bool(jnp.isfinite(loss))
        assert all(bool(jnp.all(jnp.isfinite(p))) for p in params.values())

    def test_full_config_instantiates_abstractly(self, arch):
        """FULL config: shapes only (no allocation), via eval_shape."""
        cfg = arch_config(arch)
        m = Model(cfg)
        shapes, specs = m.abstract_params()
        n_params = sum(int(np.prod(s.shape)) for s in shapes.values())
        assert n_params > 50_000_000, f"{arch}: suspiciously small ({n_params:,})"
        assert set(shapes) == set(specs)
        for k, s in shapes.items():
            assert len(specs[k]) == len(s.shape), k


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode == full parallel forward (fp32, no drops)."""
    cfg = smoke_config(arch).replace(dtype="float32", logit_dtype="float32")
    if cfg.family == "moe":
        # capacity drops depend on the token set; equivalence needs no-drop
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    m = Model(cfg)
    params, _ = m.init(jax.random.key(2))
    B, S = 2, 8
    batch = make_batch(cfg, jax.random.key(3), B, S)
    full_logits, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)

    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        tok = {"cache_pos": jnp.int32(t)}
        if cfg.embed_inputs:
            tok["embeds"] = batch["embeds"][:, t : t + 1]
        else:
            tok["tokens"] = batch["tokens"][:, t : t + 1]
        p = jnp.full((B, 1), t, jnp.int32)
        tok["positions"] = jnp.stack([p, p, p]) if cfg.mrope_sections else p
        lg, cache = step(params, cache, tok)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=5e-4
    )


def test_gemma2_window_masks_differ_by_layer():
    """Local layers must not attend beyond the window."""
    cfg = smoke_config("gemma2_9b").replace(dtype="float32", logit_dtype="float32")
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    B, S = 1, 12  # > window 8
    b1 = make_batch(cfg, jax.random.key(1), B, S)
    # Perturb the FIRST token: with window=8, a pure-local model's logits at
    # position 11 would be unaffected; gemma2's global layers must propagate.
    b2 = {k: (v.at[:, 0].set((v[:, 0] + 1) % cfg.vocab) if k == "tokens" else v)
          for k, v in b1.items()}
    l1, _ = m.forward(params, b1)
    l2, _ = m.forward(params, b2)
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 0  # global layers see it


def test_moe_load_is_distributed():
    """Router should hit multiple experts on random input."""
    cfg = smoke_config("phi35_moe_42b").replace(dtype="float32")
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1), 4, 32)
    x = m.embed(params, batch)
    lp = {k.split("blocks/")[1]: v[0] for k, v in params.items() if k.startswith("blocks/")}
    logits = jnp.einsum(
        "bsd,de->bse", x, lp["moe/router"].astype(x.dtype)
    )
    _, experts = jax.lax.top_k(logits.reshape(-1, cfg.n_experts), cfg.top_k)
    used = len(np.unique(np.asarray(experts)))
    assert used >= cfg.n_experts // 2, f"only {used} experts used"


def test_long_skip_policy():
    shapes = {s.name for s in input_shapes("yi_34b")}
    assert "long_500k" not in shapes
    shapes = {s.name for s in input_shapes("zamba2_1p2b")}
    assert "long_500k" in shapes
    assert len([s for a in ARCHS for s in input_shapes(a)]) == 10 * 4 - 7
