"""Tests for the TS/ZS/SS shrink planner (paper §4.6-§4.7)."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    ClusterState,
    Method,
    ShrinkActionKind,
    ShrinkKind,
    apply_shrink,
    plan_initial_world_shrink,
    plan_shrink,
)


def make_state(n_expanded=4, cores=4, initial_nodes=1):
    st_ = ClusterState()
    st_.add_world(list(range(initial_nodes)), [cores] * initial_nodes, is_initial=True)
    for k in range(n_expanded):
        st_.add_world([initial_nodes + k], [cores])
    st_.expansions_done = 1 if n_expanded else 0
    return st_


class TestTS:
    def test_whole_node_release_terminates_worlds(self):
        s = make_state(n_expanded=4)
        plan = plan_shrink(s, release_nodes=[3, 4])
        assert plan.kind is ShrinkKind.TS
        assert plan.nodes_returned == (3, 4)
        assert plan.nodes_pinned == ()
        kinds = [a.kind for a in plan.actions]
        assert kinds.count(ShrinkActionKind.TERMINATE_WORLD) == 2
        apply_shrink(s, plan)
        assert s.nodes_in_use() == {0, 1, 2}

    def test_root_migration_when_root_world_dies(self):
        s = make_state(n_expanded=3)
        assert s.global_root_wid == 0
        plan = plan_shrink(s, release_nodes=[0])
        assert any(a.kind is ShrinkActionKind.MIGRATE_ROOT for a in plan.actions)
        apply_shrink(s, plan)
        assert s.global_root_wid == 1

    def test_all_zombie_world_awakened_and_terminated(self):
        s = make_state(n_expanded=1)
        w = s.worlds[1]
        for r in w.ranks:
            r.zombie = True
        plan = plan_shrink(s, release_nodes=[1])
        assert any(a.kind is ShrinkActionKind.AWAKEN_AND_TERMINATE for a in plan.actions)
        assert plan.nodes_returned == (1,)


class TestZS:
    def test_partial_core_release_zombifies(self):
        s = make_state(n_expanded=2, cores=4)
        plan = plan_shrink(s, release_cores={1: 2})
        assert plan.kind is ShrinkKind.ZS
        assert plan.nodes_returned == ()
        assert plan.nodes_pinned == (1,)
        apply_shrink(s, plan)
        assert len(s.worlds[1].active_ranks) == 2

    def test_full_core_release_upgrades_to_ts(self):
        """Zombifying ALL ranks of a single-node world becomes TS (§4.7)."""
        s = make_state(n_expanded=2, cores=4)
        plan = plan_shrink(s, release_cores={1: 4})
        assert any(a.kind is ShrinkActionKind.AWAKEN_AND_TERMINATE for a in plan.actions)
        assert plan.nodes_returned == (1,)

    def test_multinode_world_partial_release_falls_back_to_zs(self):
        """§4.7: multi-node MCW asked for a subset of its nodes -> ZS,
        node stays pinned."""
        s = ClusterState()
        s.add_world([0, 1, 2], [4, 4, 4], is_initial=True)
        plan = plan_shrink(s, release_nodes=[2])
        assert plan.kind is ShrinkKind.ZS
        assert plan.nodes_returned == ()
        assert plan.nodes_pinned == (2,)
        apply_shrink(s, plan)
        assert all(r.zombie for r in s.worlds[0].ranks if r.node == 2)


class TestInitialWorldPolicy:
    def test_no_expansion_yet_requires_parallel_respawn(self):
        s = ClusterState()
        s.add_world([0, 1], [4, 4], is_initial=True)
        act = plan_initial_world_shrink(s, nodes_to_return=1)
        assert act.kind is ShrinkActionKind.PARALLEL_RESPAWN

    def test_small_request_postpones(self):
        s = ClusterState()
        s.add_world([0, 1, 2], [4, 4, 4], is_initial=True)
        s.add_world([3], [4])
        s.expansions_done = 1
        act = plan_initial_world_shrink(s, nodes_to_return=2)
        assert act.kind is ShrinkActionKind.POSTPONE

    def test_large_request_releases_whole_initial_world(self):
        s = ClusterState()
        s.add_world([0, 1], [4, 4], is_initial=True)
        s.add_world([2], [4])
        s.expansions_done = 1
        act = plan_initial_world_shrink(s, nodes_to_return=2)
        assert act.kind is ShrinkActionKind.TERMINATE_WORLD
        assert act.nodes == (0, 1)

    def test_single_node_initial_world_is_fine(self):
        s = make_state(n_expanded=2)
        act = plan_initial_world_shrink(s, nodes_to_return=1)
        assert act.kind is ShrinkActionKind.POSTPONE


class TestProperties:
    @given(
        n_worlds=st.integers(1, 12),
        cores=st.integers(1, 8),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=150, deadline=None)
    def test_returned_nodes_are_exactly_fully_freed(self, n_worlds, cores, seed):
        import random

        rng = random.Random(seed)
        s = ClusterState()
        s.add_world([0], [cores], is_initial=True)
        for k in range(n_worlds):
            s.add_world([k + 1], [rng.randint(1, cores)])
        s.expansions_done = 1
        release = sorted(rng.sample(range(n_worlds + 1), rng.randint(0, n_worlds)))
        plan = plan_shrink(s, release_nodes=release)
        apply_shrink(s, plan)
        # every returned node hosts nothing afterwards
        for node in plan.nodes_returned:
            assert not s.worlds_on_node(node)
        # non-returned release requests are pinned (zombies) or were empty
        for node in release:
            if node not in plan.nodes_returned:
                assert node in plan.nodes_pinned or not s.worlds_on_node(node)
        # a valid global root always survives
        if s.worlds:
            assert s.global_root_wid in s.worlds

    @given(
        cores=st.integers(2, 8),
        take=st.integers(1, 7),
    )
    @settings(max_examples=60, deadline=None)
    def test_zombie_counts_consistent(self, cores, take):
        take = min(take, cores - 1)
        s = make_state(n_expanded=1, cores=cores)
        plan = plan_shrink(s, release_cores={1: take})
        apply_shrink(s, plan)
        assert len(s.worlds[1].active_ranks) == cores - take
        assert sum(r.zombie for r in s.worlds[1].ranks) == take
