"""RMS policy engine tests: policy-generated traces, multi-job
arbitration, QUEUE-stage charging, and pinned sim == live parity for
every registered policy scenario (per-event downtime, bytes, AND queued
seconds through both executors)."""
import os
import subprocess
import sys
import textwrap
from dataclasses import replace
from types import SimpleNamespace

import pytest

from repro.core import ReconfigEngine, Stage
from repro.malleability import (
    BackfillPolicy,
    ChurnPolicy,
    JobSpec,
    PreemptionPolicy,
    PriorityArrival,
    RigidArrival,
    RmsPolicy,
    arbitrate_jobs,
    churn_trace,
    get_scenario,
    run_multijob_sim,
    run_scenario_live,
    run_scenario_sim,
    steady_cycle,
)
from repro.malleability.policies import POLICY_SCENARIO_NAMES, ClusterState

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "benchmarks"))
from paper_tables import policy_sweep  # noqa: E402


def _key(rec):
    return (rec.step, rec.kind, rec.mechanism, rec.nodes_before,
            rec.nodes_after, rec.est_wall_s, rec.downtime_s, rec.bytes_moved,
            rec.queued_s)


def _one_job_cluster(min_nodes=1, max_nodes=8, total=8, **kw):
    return ClusterState(
        total_nodes=total,
        jobs=(JobSpec("train", min_nodes=min_nodes, max_nodes=max_nodes, **kw),),
    )


class TestPolicyScenarioParity:
    """Acceptance: every policy-generated scenario runs through BOTH
    executors with identical per-event numbers — downtime, bytes, and
    queued seconds included (exact float equality; one engine timeline)."""

    @pytest.mark.parametrize("name", POLICY_SCENARIO_NAMES)
    def test_sim_equals_live(self, name):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert len(sim) >= 2, "policy trace must actually reconfigure"
        assert [_key(r) for r in sim] == [_key(r) for r in live]

    @pytest.mark.parametrize("name", POLICY_SCENARIO_NAMES)
    def test_async_parity_too(self, name):
        sc = get_scenario(name)
        engine = sc.default_engine()
        engine.asynchronous = True
        sim = run_scenario_sim(sc, engine=engine)
        engine2 = sc.default_engine()
        engine2.asynchronous = True
        live = run_scenario_live(sc, engine=engine2)
        assert [_key(r) for r in sim] == [_key(r) for r in live]


class TestClusterState:
    def test_overcommit_raises(self):
        with pytest.raises(ValueError):
            ClusterState(total_nodes=4, jobs=(
                JobSpec("a", min_nodes=3, max_nodes=4),
                JobSpec("b", min_nodes=3, max_nodes=4),
            ))

    def test_duplicate_job_names_raise(self):
        with pytest.raises(ValueError):
            ClusterState(total_nodes=8, jobs=(JobSpec("a"), JobSpec("a")))

    def test_from_pool_duck_types(self):
        cluster = ClusterState.from_pool(SimpleNamespace(n_nodes=5),
                                         jobs=(JobSpec("t"),))
        assert cluster.total_nodes == 5
        assert cluster.idle_nodes() == 4

    def test_clamp_grant_bounds(self):
        cluster = _one_job_cluster(min_nodes=2, max_nodes=32)
        spec = cluster.spec("train")
        assert cluster.clamp_grant(spec, 10 ** 9) == 8   # pool-capped
        assert cluster.clamp_grant(spec, 0) == 2         # floor
        assert cluster.clamp_grant(spec, 5) == 5

    def test_policies_satisfy_the_protocol(self):
        for policy in (BackfillPolicy(), PreemptionPolicy(), ChurnPolicy()):
            assert isinstance(policy, RmsPolicy)


class TestBackfillPolicy:
    def test_grant_exceeding_pool_clamps_not_crashes(self):
        """A job whose max_nodes dwarfs the pool receives the pool."""
        cluster = _one_job_cluster(min_nodes=2, max_nodes=32)
        sc = BackfillPolicy(horizon=10).generate(cluster).scenario()
        assert sc.max_nodes() == 8          # never 32
        recs = run_scenario_sim(sc)         # and the trace executes
        assert recs[0].nodes_after == 8

    def test_queue_pressure_reclaims_and_grant_returns(self):
        cluster = _one_job_cluster(min_nodes=2, max_nodes=8)
        policy = BackfillPolicy(
            arrivals=(RigidArrival(step=6, nodes=4, duration=6),), horizon=18)
        recs = run_scenario_sim(policy.generate(cluster).scenario())
        kinds = [(r.step, r.kind, r.nodes_after) for r in recs]
        assert kinds == [
            (2, "expand", 8),     # backfill grant: idle pool -> the job
            (6, "shrink", 4),     # rigid arrival reclaims down
            (12, "expand", 8),    # rigid job drains, grant returns
        ]

    def test_rigid_job_too_big_waits_forever(self):
        """An arrival that can never fit above the floor never starts —
        the malleable job keeps the whole pool."""
        cluster = _one_job_cluster(min_nodes=4, max_nodes=8)
        policy = BackfillPolicy(
            arrivals=(RigidArrival(step=4, nodes=6, duration=2),), horizon=12)
        recs = run_scenario_sim(policy.generate(cluster).scenario())
        assert [r.kind for r in recs] == ["expand"]
        assert recs[0].nodes_after == 8


class TestPreemptionPolicy:
    def test_mid_reconfiguration_preemption_composes(self):
        """The registered trace's second preemption lands on the regrow
        step: the forced shrink queues behind the in-flight grow's exact
        charged wall instead of cancelling it."""
        recs = run_scenario_sim(get_scenario("priority-preempt"))
        colliding = [r for r in recs if r.step == 12]
        assert [r.kind for r in colliding] == ["expand", "shrink"]
        grow, shrink = colliding
        assert grow.queued_s == 0.0
        assert shrink.queued_s == grow.est_wall_s          # exact, same engine
        # QUEUE raises makespan, never downtime
        assert shrink.est_wall_s == shrink.downtime_s + shrink.queued_s

    def test_preemptor_cannot_overcommit_the_pool(self):
        """A preemptor demanding the whole pool is trimmed to what the
        victim's guaranteed floor leaves — the ledger never models more
        nodes in use than the pool holds."""
        cluster = _one_job_cluster(min_nodes=2, max_nodes=8)
        policy = PreemptionPolicy(
            arrivals=(PriorityArrival(step=4, nodes=8, duration=4),),
            horizon=12)
        recs = run_scenario_sim(policy.generate(cluster).scenario())
        # victim shrinks exactly to its floor (preemptor got 8 - 2 = 6)
        floor = [r for r in recs if r.step == 4 and r.kind == "shrink"]
        assert floor and floor[0].nodes_after == 2
        # and regrows to the full pool when the preemptor leaves
        assert recs[-1].nodes_after == 8

    def test_arrival_outside_window_raises(self):
        cluster = _one_job_cluster()
        with pytest.raises(ValueError, match="outside the scheduled window"):
            PreemptionPolicy(
                arrivals=(PriorityArrival(step=1, nodes=2, duration=2),),
                horizon=10).generate(cluster)
        with pytest.raises(ValueError, match="outside the scheduled window"):
            BackfillPolicy(
                arrivals=(RigidArrival(step=40, nodes=2, duration=2),),
                horizon=10).generate(cluster)

    def test_low_priority_arrival_cannot_preempt(self):
        cluster = ClusterState(
            total_nodes=8,
            jobs=(JobSpec("train", min_nodes=1, max_nodes=8, priority=50),),
        )
        policy = PreemptionPolicy(
            arrivals=(PriorityArrival(step=4, nodes=6, duration=4, priority=10),),
            horizon=10)
        recs = run_scenario_sim(policy.generate(cluster).scenario())
        assert all(r.kind == "expand" for r in recs)       # never shrunk


class TestChurnPolicy:
    def test_deterministic_under_fixed_seed(self):
        t1 = ChurnPolicy(decisions=50, seed=3).generate(_one_job_cluster())
        t2 = ChurnPolicy(decisions=50, seed=3).generate(_one_job_cluster())
        assert t1.events == t2.events
        t3 = ChurnPolicy(decisions=50, seed=4).generate(_one_job_cluster())
        assert t1.events != t3.events

    def test_registered_trace_is_reproducible(self):
        rebuilt = churn_trace(name="churn-rebuild")
        assert rebuilt.events == get_scenario("churn-200").events

    def test_every_decision_resizes_within_bounds(self):
        sc = get_scenario("churn-200")
        assert len(sc.events) == 200
        recs = run_scenario_sim(sc)
        assert len(recs) == 200                  # no dropped no-ops
        for r in recs:
            assert r.nodes_before != r.nodes_after
            assert 1 <= r.nodes_after <= 8

    def test_pinned_job_has_no_churn_headroom(self):
        cluster = ClusterState(total_nodes=1, jobs=(JobSpec("t", 1, 1),))
        with pytest.raises(ValueError):
            ChurnPolicy(decisions=3).generate(cluster)


class TestMultiJobArbitration:
    def _jobs(self):
        return [
            ("a", steady_cycle(name="arb-a", low=2, high=6, cycles=2, period=4)),
            ("b", steady_cycle(name="arb-b", low=2, high=6, cycles=2, period=4)),
        ]

    def test_pool_capacity_never_exceeded(self):
        outcome = arbitrate_jobs(self._jobs(), pool_nodes=8)
        # replay per-step settled allocations across jobs
        allocs = {n: sc.initial_nodes for n, sc in outcome.scenarios.items()}
        steps = sorted({e.step for sc in outcome.scenarios.values()
                        for e in sc.events})
        for step in steps:
            for name, sc in outcome.scenarios.items():
                for ev in (e for e in sc.events if e.step == step):
                    if ev.kind == "grow":
                        allocs[name] = ev.target_nodes
                    else:
                        allocs[name] -= len(ev.nodes)
            assert sum(allocs.values()) <= 8, (step, allocs)

    def test_interference_queues_and_degrades_overlap(self):
        outcome = arbitrate_jobs(self._jobs(), pool_nodes=8)
        assert set(outcome.interfered) == {"a", "b"}
        b = outcome.job("b")
        assert b.deferred_events >= 1            # grow waited for capacity
        assert b.queued_events >= 1              # and queued behind A's resize
        assert all(j.scenario.contention == 1.25 for j in outcome.jobs)
        queued = [e for e in b.scenario.events if e.queue_delay_s > 0]
        assert queued, "interference must surface as queued RESIZE events"

    def test_degraded_overlap_raises_async_downtime(self):
        sc = get_scenario("two-job-interference")
        assert sc.contention == 1.25
        undegraded = replace(sc, name=sc.name + "-nc", contention=0.0)
        e1 = sc.default_engine()
        e1.asynchronous = True
        e2 = undegraded.default_engine()
        e2.asynchronous = True
        d_deg = sum(r.downtime_s for r in run_scenario_sim(sc, engine=e1))
        d_base = sum(r.downtime_s for r in run_scenario_sim(undegraded, engine=e2))
        assert d_deg > d_base

    def test_preexisting_queue_delays_survive_arbitration(self):
        """A trace that already carries a QUEUE charge (e.g. a composed
        preemption) keeps it; arbitration adds cross-job waits on top."""
        cluster = ClusterState(
            total_nodes=8,
            jobs=(JobSpec("train", min_nodes=1, max_nodes=6, priority=0,
                          initial_nodes=2),),
        )
        preempt = PreemptionPolicy(
            arrivals=(PriorityArrival(step=6, nodes=4, duration=6),
                      PriorityArrival(step=12, nodes=6, duration=6)),
            horizon=22,
        ).generate(cluster).scenario("train", name="arb-preempt")
        baked = {(e.step, e.kind): e.queue_delay_s for e in preempt.events
                 if e.queue_delay_s > 0}
        assert baked, "precondition: the input trace carries a QUEUE charge"
        outcome = arbitrate_jobs([("p", preempt)], pool_nodes=8)
        out = {(e.step, e.kind): e.queue_delay_s
               for e in outcome.job("p").scenario.events}
        for key, delay in baked.items():
            assert out[key] >= delay

    def test_overcommitted_start_raises(self):
        jobs = [("a", steady_cycle(name="oc-a", low=5, high=6)),
                ("b", steady_cycle(name="oc-b", low=5, high=6))]
        with pytest.raises(ValueError):
            arbitrate_jobs(jobs, pool_nodes=8)

    def test_run_multijob_sim_returns_both_jobs(self):
        records, outcome = run_multijob_sim(self._jobs(), pool_nodes=8)
        assert set(records) == {"a", "b"}
        assert all(recs for recs in records.values())
        assert outcome.pool_nodes == 8


class TestQueueStage:
    """Engine-level semantics of the QUEUE timeline event."""

    def test_queue_event_leads_the_timeline(self):
        engine = ReconfigEngine()
        plan = engine.plan_expand(1, 8, 1, queue_delay_s=0.5)
        tl = engine.timeline(plan)
        assert tl.events[0].stage is Stage.QUEUE
        assert tl.queued_s == 0.5

    def test_queue_counts_toward_makespan_never_downtime(self):
        engine = ReconfigEngine()
        base = engine.timeline(engine.plan_expand(1, 8, 1))
        queued = engine.timeline(engine.plan_expand(1, 8, 1, queue_delay_s=0.5))
        assert queued.total == base.total + 0.5
        assert queued.downtime() == base.downtime()
        assert queued.downtime(asynchronous=True) == base.downtime(asynchronous=True)

    def test_shrink_queue_charged_too(self):
        from repro.core import ClusterState as CoreClusterState

        engine = ReconfigEngine()
        state = CoreClusterState()
        state.add_world([0], [1], is_initial=True)
        state.add_world([1], [1])
        plan = engine.plan_shrink(state, release_nodes=[1], queue_delay_s=0.25)
        tl = engine.timeline(plan)
        assert tl.events[0].stage is Stage.QUEUE
        assert tl.downtime() == tl.total - 0.25


class TestPolicySweep:
    """Acceptance: the benchmark policy_sweep table covers every
    registered strategy x every registered policy trace."""

    def test_full_strategy_by_policy_coverage(self):
        from repro.core import registered_strategies

        rows = policy_sweep()
        got = {(r["policy"], r["strategy"]) for r in rows}
        want = {(trace, spec.key)
                for trace in POLICY_SCENARIO_NAMES
                for spec in registered_strategies()}
        assert want <= got

    def test_makespan_decomposes_into_downtime_plus_queue(self):
        from repro.core import get_strategy

        for r in policy_sweep():
            if get_strategy(r["strategy"]).two_phase:
                # Two-phase strategies (dmr-async) hide the spawn legs
                # under compute: wall keeps charging them, downtime
                # doesn't, so the identity relaxes to an inequality.
                assert (r["downtime_s"] + r["queued_s"]
                        <= r["makespan_s"] + 1e-9)
            else:
                assert r["makespan_s"] == pytest.approx(
                    r["downtime_s"] + r["queued_s"])
            assert r["events"] >= 2


class TestFromPolicy:
    def test_rms_script_matches_generated_scenario(self):
        from repro.elastic.rms import SimulatedRMS

        cluster = _one_job_cluster()
        policy = ChurnPolicy(decisions=5, seed=1)
        rms = SimulatedRMS.from_policy(policy, cluster)
        sc = policy.generate(_one_job_cluster()).scenario()
        got = [(e.step, e.kind.value, e.nodes, e.target_nodes, e.queue_delay_s)
               for e in rms.events_until(10 ** 9)]
        want = [(e.step, e.kind, e.nodes, e.target_nodes, e.queue_delay_s)
                for e in sc.events]
        assert got == want


TRAINER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.configs import smoke_config
    from repro.elastic import ElasticTrainer
    from repro.malleability import get_scenario, run_scenario_sim
    from repro.models import Model

    model = Model(smoke_config("stablelm_3b"))
    # churn-200 settles on sizes that don't divide any small batch, so it
    # stays bookkeeping-verified (run_scenario_live); the other policy
    # traces settle on {2, 4, 6, 8} and run the full training loop.
    for name in ("backfill-pressure", "priority-preempt",
                 "two-job-interference"):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        tr = ElasticTrainer.from_scenario(model, sc, batch=24, seq=16)
        tr.run(sc.steps)
        live = tr.runtime.history
        assert len(live) == len(sim), (name, len(live), len(sim))
        for s, l in zip(sim, live):
            assert l.downtime_s == s.downtime_s, (name, s, l)
            assert l.est_wall_s == s.est_wall_s, (name, s, l)
            assert l.queued_s == s.queued_s, (name, s, l)
            assert l.bytes_moved == s.bytes_moved, (name, s, l)
            assert (l.nodes_before, l.nodes_after) == (
                s.nodes_before, s.nodes_after), (name, s, l)
        losses = np.array(tr.losses())
        assert np.isfinite(losses).all(), name
        print("POLICY_TRAINER_OK", name, len(live), "reconfigs")
""")


@pytest.mark.slow
def test_trainer_loop_matches_simulator_on_policy_traces():
    """Full ElasticTrainer loop on the policy scenarios whose settled
    sizes shard a real batch: live history must carry exactly the
    simulator's timeline numbers, queued seconds included."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", TRAINER_SCRIPT], capture_output=True, text=True,
        timeout=1200, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    for name in ("backfill-pressure", "priority-preempt",
                 "two-job-interference"):
        assert f"POLICY_TRAINER_OK {name}" in proc.stdout
