"""Substrate tests: checkpoint store, optimizer, data pipeline, sharding
rule resolution."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, latest_step, restore_tree, save_tree
from repro.data import SyntheticTokens
from repro.models.common import ModelConfig
from repro.optim import adamw_init, adamw_update, global_norm, linear_warmup_cosine
from repro.parallel.sharding import ShardingContext, resolve_spec


# ------------------------------------------------------------- checkpoint --
class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,))}}
        save_tree(tree, str(tmp_path), 7)
        assert latest_step(str(tmp_path)) == 7
        out = restore_tree(tree, str(tmp_path), 7)
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
        np.testing.assert_allclose(np.asarray(out["b"]["c"]), 1.0)

    def test_manager_retention_and_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
        tree = {"w": jnp.zeros((4,))}
        for s in (1, 2, 3, 4):
            mgr.save({"w": jnp.full((4,), float(s))}, s)
        mgr.wait()
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
        assert steps == [3, 4]
        restored, step = mgr.restore_latest(tree)
        assert step == 4
        np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)

    def test_restore_is_mesh_independent(self, tmp_path):
        """Written under 1 device, restored with an explicit sharding."""
        from repro.launch.mesh import make_host_mesh

        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        save_tree(tree, str(tmp_path), 1)
        mesh = make_host_mesh()
        out = restore_tree(tree, str(tmp_path), 1, mesh=mesh, spec_tree=P())
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))


# -------------------------------------------------------------- optimizer --
class TestAdamW:
    def test_minimizes_quadratic(self):
        params = {"x": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        loss = lambda p: jnp.sum(p["x"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state = adamw_update(g, state, params, 5e-2, weight_decay=0.0)
        assert float(loss(params)) < 1e-2

    def test_clipping_bounds_update(self):
        params = {"x": jnp.zeros((4,))}
        state = adamw_init(params)
        g = {"x": jnp.full((4,), 1e9)}
        new, _ = adamw_update(g, state, params, 1e-3, clip_norm=1.0)
        assert float(jnp.max(jnp.abs(new["x"]))) < 1.0

    @given(scale=st.floats(0.1, 100.0))
    @settings(max_examples=20, deadline=None)
    def test_global_norm(self, scale):
        tree = {"a": jnp.ones((3,)) * scale, "b": jnp.zeros((2,))}
        assert float(global_norm(tree)) == pytest.approx(
            float(np.sqrt(3) * scale), rel=1e-5
        )

    def test_schedule_warmup_then_decay(self):
        lr = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
        assert float(lr(0)) == pytest.approx(0.0)
        assert float(lr(10)) == pytest.approx(1.0, abs=0.05)
        assert float(lr(110)) < float(lr(50)) < float(lr(10))


# ------------------------------------------------------------------- data --
class TestData:
    def _cfg(self):
        return ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                           n_heads=1, n_kv_heads=1, d_ff=8, vocab=128)

    def test_deterministic_per_step(self):
        d = SyntheticTokens(self._cfg(), batch=4, seq=16, seed=3)
        a, b = d.sample(5), d.sample(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = d.sample(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticTokens(self._cfg(), batch=2, seq=16, seed=0)
        s = d.sample(0)
        assert s["tokens"].shape == s["labels"].shape == (2, 16)
        # tokens[t+1] == labels[t] by construction
        full_a = d.sample(0)
        np.testing.assert_array_equal(full_a["tokens"][:, 1:], full_a["labels"][:, :-1])

    def test_tokens_in_vocab(self):
        d = SyntheticTokens(self._cfg(), batch=4, seq=64, seed=1)
        s = d.sample(0)
        assert s["tokens"].min() >= 0
        assert s["tokens"].max() < 128

    def test_prefetch_iterator(self):
        d = SyntheticTokens(self._cfg(), batch=2, seq=8, seed=0)
        it = d.iter(start_step=0)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], d.sample(0)["tokens"])


# --------------------------------------------------------------- sharding --
class TestShardingRules:
    def _ctx(self, mode="train"):
        from repro.launch.mesh import make_production_mesh
        # abstract mesh shape via a 1-device stand-in is not possible;
        # use a tiny host mesh with both axis names instead.
        import numpy as np_
        from jax.sharding import Mesh

        dev = np_.array(jax.devices()[:1], dtype=object).reshape(1, 1)
        return ShardingContext(mesh=Mesh(dev, ("data", "model")), mode=mode)

    def test_resolution_drops_small_dims_with_fallback(self):
        from jax.sharding import Mesh
        import numpy as np_
        # synthetic 4x4 mesh of the same device (shape logic only)
        dev = np_.array([jax.devices()[0]] * 16, dtype=object).reshape(4, 4)
        ctx = ShardingContext(mesh=Mesh(dev, ("data", "model")), mode="train")
        # kv_heads=2 < 4 shards -> dropped; the fallback pass re-places
        # 'model' on the largest divisible dim (embed=128) for storage.
        spec = resolve_spec(("embed", "kv_heads", "head_dim"), (128, 2, 64), ctx, "weight")
        assert spec == P(("data", "model"), None, None)
        spec = resolve_spec(("embed", "heads", "head_dim"), (128, 8, 64), ctx, "weight")
        assert spec == P("data", "model", None)

    def test_weight_divisibility_enforced_with_fallback(self):
        """56 heads over 16-way model: jit args reject uneven shardings,
        so the weight spec must fall back to a divisible dim."""
        from jax.sharding import Mesh
        import numpy as np_
        dev = np_.array([jax.devices()[0]] * 16, dtype=object).reshape(1, 16)
        ctx = ShardingContext(mesh=Mesh(dev, ("data", "model")), mode="train")
        spec = resolve_spec(("heads", "head_dim", "embed"), (56, 128, 7168), ctx, "weight")
        flat = []
        for e in spec:
            flat.extend(e if isinstance(e, tuple) else [e])
        assert "model" in flat
        assert spec[0] != "model"  # 56 % 16 != 0

    def test_uneven_dims_kept(self):
        from jax.sharding import Mesh
        import numpy as np_
        dev = np_.array([jax.devices()[0]] * 16, dtype=object).reshape(4, 4)
        ctx = ShardingContext(mesh=Mesh(dev, ("data", "model")), mode="train")
        # 56 heads over 4-way model: uneven but allowed
        spec = resolve_spec(("embed", "heads", "head_dim"), (128, 56, 64), ctx, "weight")
        assert spec == P("data", "model", None)

    def test_no_axis_reuse_within_tensor(self):
        from jax.sharding import Mesh
        import numpy as np_
        dev = np_.array([jax.devices()[0]] * 16, dtype=object).reshape(4, 4)
        ctx = ShardingContext(mesh=Mesh(dev, ("data", "model")), mode="train")
        spec = resolve_spec(("mlp", "vocab"), (64, 64), ctx, "weight")
        # both want 'model'; second must not reuse it
        flat = [e for e in spec]
        assert flat.count("model") <= 1

    def test_batch_rule_tuple_filters_missing_axes(self):
        from jax.sharding import Mesh
        import numpy as np_
        dev = np_.array([jax.devices()[0]] * 4, dtype=object).reshape(4,)
        ctx = ShardingContext(mesh=Mesh(dev.reshape(4, 1), ("data", "model")), mode="train")
        # 'pod' missing from this mesh -> silently skipped
        spec = resolve_spec(("batch", "seq"), (8, 16), ctx, "act")
        assert spec == P("data", None)
