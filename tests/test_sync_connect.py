"""Tests for §4.3 synchronization, §4.4 binary connection, §4.5 reordering."""
import math
import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Method,
    SOURCE_GID,
    assert_ports_before_release,
    binary_connection_schedule,
    build_sync_graph,
    extend_graph_with_connection,
    global_order,
    node_of_rank,
    plan_diffusive,
    plan_hypercube,
    port_openers,
    required_ports,
    simulate_merges,
    spawn_children,
)
from repro.core.sync import CONNECT, DOWN, PORT_OPEN, UP_READY


# ------------------------------------------------------------------- sync ---
class TestSync:
    @given(cores=st.integers(1, 8), initial=st.integers(1, 4),
           target=st.integers(2, 40))
    @settings(max_examples=60, deadline=None)
    def test_ports_always_open_before_any_release(self, cores, initial, target):
        if target <= initial:
            target = initial + 1
        p = plan_hypercube(initial * cores, target * cores, cores, Method.MERGE)
        g = build_sync_graph(p)
        extend_graph_with_connection(g, p)
        assert_ports_before_release(g, p)   # raises on violation
        g.topological()                     # and the graph must be acyclic

    @given(
        a_vec=st.lists(st.integers(0, 6), min_size=2, max_size=16),
        r0=st.integers(1, 6),
    )
    @settings(max_examples=60, deadline=None)
    def test_diffusive_sync_invariant(self, a_vec, r0):
        a_vec = [max(a_vec[0], r0)] + a_vec[1:]
        r_vec = [r0] + [0] * (len(a_vec) - 1)
        p = plan_diffusive(a_vec, r_vec, Method.MERGE)
        g = build_sync_graph(p)
        extend_graph_with_connection(g, p)
        assert_ports_before_release(g, p)

    def test_randomized_latency_simulation_no_port_race(self):
        """Event-driven execution with adversarial random latencies: no
        CONNECT may fire before its acceptor's PORT_OPEN timestamp."""
        p = plan_hypercube(2, 16, 2, Method.MERGE)
        g = build_sync_graph(p)
        extend_graph_with_connection(g, p)
        preds = g.predecessors()
        for trial in range(20):
            rng = random.Random(trial)
            finish: dict = {}
            for ev in g.topological():
                start = max((finish[p_] for p_ in preds[ev]), default=0.0)
                finish[ev] = start + rng.uniform(0.1, 10.0)
            opens = {e.gid: finish[e] for e in g.events if e.kind == PORT_OPEN}
            for e in g.events:
                if e.kind == CONNECT:
                    start = max((finish[p_] for p_ in preds[e]), default=0.0)
                    assert start >= opens[e.peer], (e, trial)

    def test_spawn_children_tree(self):
        p = plan_hypercube(1, 8, 1, Method.MERGE)
        ch = spawn_children(p)
        assert ch[SOURCE_GID] == [0, 1, 3]
        assert ch[0] == [2, 4]
        assert ch[1] == [5]
        assert ch[2] == [6]
        assert ch[3] == ch[4] == ch[5] == ch[6] == []

    def test_up_before_down(self):
        """Every group's UP_READY precedes every group's DOWN (no release
        until the whole forest is ready — the §4.3 guarantee)."""
        p = plan_hypercube(2, 18, 2, Method.MERGE)
        g = build_sync_graph(p)
        ups = [e for e in g.events if e.kind == UP_READY]
        downs = [e for e in g.events if e.kind == DOWN]
        for u in ups:
            reach = g.reachable_from(u)
            assert all(d in reach for d in downs)


# ---------------------------------------------------------------- connect ---
class TestBinaryConnection:
    def test_figure3_seven_groups(self):
        sched = binary_connection_schedule(7)
        assert len(sched) == 3
        assert sched[0].pairs == ((0, 6), (1, 5), (2, 4))
        assert sched[0].idle == (3,)
        assert sched[1].pairs == ((0, 3), (1, 2))
        assert sched[2].pairs == ((0, 1),)

    @given(n=st.integers(1, 4096))
    @settings(max_examples=200, deadline=None)
    def test_converges_to_single_group(self, n):
        members = simulate_merges(n)
        assert len(members) == 1
        (rep, got), = members.items()
        assert rep == 0
        assert sorted(got) == list(range(n))

    @given(n=st.integers(1, 2048))
    @settings(max_examples=200, deadline=None)
    def test_round_count_is_log2(self, n):
        assert len(binary_connection_schedule(n)) == (0 if n <= 1 else math.ceil(math.log2(n)))

    @given(n=st.integers(2, 2048))
    @settings(max_examples=200, deadline=None)
    def test_port_condition_matches_listing4(self, n):
        """Acceptor ids over all rounds == {id < G/2}, the open_port
        condition in Listing 4."""
        assert required_ports(n) == set(range(n // 2))

    @given(cores=st.integers(1, 6), target=st.integers(2, 30))
    @settings(max_examples=50, deadline=None)
    def test_port_openers_cover_required(self, cores, target):
        p = plan_hypercube(cores, target * cores, cores, Method.MERGE)
        assert {g for g in port_openers(p) if g != SOURCE_GID} >= required_ports(
            len(p.groups)
        )


# ---------------------------------------------------------------- reorder ---
class TestReorder:
    @given(cores=st.integers(1, 8), initial=st.integers(1, 4),
           target=st.integers(2, 40),
           method=st.sampled_from([Method.MERGE, Method.BASELINE]))
    @settings(max_examples=100, deadline=None)
    def test_eq9_is_a_permutation(self, cores, initial, target, method):
        if target <= initial:
            target = initial + 1
        p = plan_hypercube(initial * cores, target * cores, cores, method)
        layout = global_order(p)  # raises on collision/gap
        assert len(layout) == (target * cores if method is Method.BASELINE
                               else target * cores)

    @given(
        a_vec=st.lists(st.integers(0, 6), min_size=2, max_size=16),
        r0=st.integers(1, 6),
    )
    @settings(max_examples=60, deadline=None)
    def test_diffusive_rank_order_is_node_contiguous(self, a_vec, r0):
        a_vec = [max(a_vec[0], r0)] + a_vec[1:]
        r_vec = [r0] + [0] * (len(a_vec) - 1)
        p = plan_diffusive(a_vec, r_vec, Method.MERGE)
        nodes = node_of_rank(p)
        # Ranks walk the nodes monotonically: once we leave a node we never
        # return (the guarantee Eq. 9 exists to provide).
        seen: list[int] = []
        for n in nodes:
            if not seen or seen[-1] != n:
                assert n not in seen[:-1]
                seen.append(n)

    def test_merge_sources_keep_their_ranks(self):
        p = plan_hypercube(4, 12, 2, Method.MERGE)
        layout = global_order(p)
        assert layout[:4] == [(-1, 0), (-1, 1), (-1, 2), (-1, 3)]
