"""Checkpoint/restart as a priced reconfiguration path.

Pins the fault family end to end: sim == live == vectorized parity on
every record field (checkpointed/restored bytes included), the
restart-vs-shrink decision numbers under every registered strategy, the
failure-recovery RESTORE accounting, the PreemptionPolicy mechanism
knob, the Young/Daly checkpoint-interval policy, and the EventArrays
round-trip of the two new stages.
"""
import math
from dataclasses import replace

import pytest

from repro.core import (
    CheckpointSpec,
    Stage,
    checkpoint_timeline,
    registered_strategies,
    restart_timeline,
)
from repro.core.vectorized import EventArrays
from repro.malleability import (
    MN5,
    CheckpointIntervalPolicy,
    PreemptionPolicy,
    PriorityArrival,
    record_parity_key,
    registered_fault_scenarios,
    run_scenario_live,
    run_scenario_sim,
    run_scenario_vectorized,
)
from repro.malleability.policies import ClusterState, JobSpec

GIB = 1 << 30


# ===================================================== executor parity ==
class TestFaultScenarioParity:
    @pytest.mark.parametrize(
        "name", [sc.name for sc in registered_fault_scenarios()]
    )
    def test_sim_live_vectorized_agree_exactly(self, name):
        sc = next(
            s for s in registered_fault_scenarios() if s.name == name
        )
        sim = [record_parity_key(r) for r in run_scenario_sim(sc)]
        live = [record_parity_key(r) for r in run_scenario_live(sc)]
        vec = [record_parity_key(r) for r in run_scenario_vectorized(sc)]
        assert sim == live == vec
        assert sim  # the trace actually reconfigured

    def test_ckpt_cycle_charges_snapshots(self):
        sc = next(s for s in registered_fault_scenarios()
                  if s.name == "ckpt-cycle")
        recs = run_scenario_sim(sc)
        ckpts = [r for r in recs if r.kind == "checkpoint"]
        assert len(ckpts) == 3
        for r in ckpts:
            assert r.mechanism == "ckpt"
            assert r.bytes_checkpointed == GIB
            assert r.nodes_before == r.nodes_after  # no allocation change
            assert r.est_wall_s > 0
        # non-checkpoint events snapshot nothing
        assert all(r.bytes_checkpointed == 0 for r in recs
                   if r.kind != "checkpoint")

    def test_node_fail_wave_restores_doomed_share(self):
        sc = next(s for s in registered_fault_scenarios()
                  if s.name == "node-fail-wave")
        recs = run_scenario_sim(sc)
        fails = [r for r in recs if r.kind == "fail"]
        assert fails
        for r in fails:
            ns, nt = r.nodes_before, r.nodes_after
            assert r.bytes_restored == GIB * (ns - nt) // ns
            assert r.restored_s > 0
        # grows/checkpoints restore nothing
        assert all(r.bytes_restored == 0 for r in recs
                   if r.kind not in ("fail",))


# ============================================= the decision numbers ==
class TestRestartVsShrink:
    @pytest.mark.parametrize(
        "key", [spec.key for spec in registered_strategies()]
    )
    def test_malleable_shrink_beats_full_stop_under_every_strategy(
        self, key
    ):
        sc = next(s for s in registered_fault_scenarios()
                  if s.name == "restart-vs-shrink")
        recs = run_scenario_sim(
            sc, engine=sc.default_engine(strategy=key))
        restarts = [r for r in recs if r.kind == "restart"]
        shrinks = [r for r in recs if r.kind == "shrink"]
        assert len(restarts) == 1 and len(shrinks) == 1
        restart, shrink = restarts[0], shrinks[0]
        # the same 4 -> 2 allocation drop, both ways
        assert (restart.nodes_before, restart.nodes_after) == (4, 2)
        assert (shrink.nodes_before, shrink.nodes_after) == (4, 2)
        assert shrink.est_wall_s < restart.est_wall_s
        # the restart pays the full round trip: snapshot out + read back
        assert restart.mechanism == "ss"
        assert restart.bytes_checkpointed == GIB
        assert restart.bytes_restored == GIB
        assert shrink.bytes_checkpointed == shrink.bytes_restored == 0


# ================================================== policy layer ==
def _policy_kinds(policy):
    cluster = ClusterState(
        total_nodes=8,
        jobs=(JobSpec("train", min_nodes=1, max_nodes=8,
                      param_bytes=GIB),),
    )
    sc = policy.generate(cluster).scenario("train")
    return [ev.kind for ev in sc.events], sc


class TestPreemptionMechanism:
    ARRIVALS = (PriorityArrival(step=6, nodes=4, duration=6,
                                priority=100),)

    def test_default_mechanism_is_bit_identical_shrink(self):
        base, _ = _policy_kinds(PreemptionPolicy(arrivals=self.ARRIVALS))
        explicit, _ = _policy_kinds(
            PreemptionPolicy(arrivals=self.ARRIVALS, mechanism="shrink"))
        assert base == explicit
        assert "restart" not in base and "shrink" in base

    def test_restart_mechanism_emits_restart_events(self):
        kinds, sc = _policy_kinds(
            PreemptionPolicy(arrivals=self.ARRIVALS, mechanism="restart"))
        assert "restart" in kinds
        recs = run_scenario_sim(sc)
        restart = next(r for r in recs if r.kind == "restart")
        assert restart.bytes_checkpointed > 0
        assert restart.bytes_restored > 0

    def test_auto_picks_shrink_under_calibrated_profiles(self):
        default, _ = _policy_kinds(
            PreemptionPolicy(arrivals=self.ARRIVALS))
        auto, _ = _policy_kinds(
            PreemptionPolicy(arrivals=self.ARRIVALS, mechanism="auto",
                             decision_cost_model=MN5))
        assert auto == default  # TS wins by orders of magnitude

    def test_auto_flips_to_restart_when_termination_is_expensive(self):
        slow_term = replace(MN5, t_term_base=50.0)
        kinds, _ = _policy_kinds(
            PreemptionPolicy(arrivals=self.ARRIVALS, mechanism="auto",
                             decision_cost_model=slow_term))
        assert "restart" in kinds and "shrink" not in kinds

    def test_unknown_mechanism_raises(self):
        with pytest.raises(ValueError, match="mechanism"):
            _policy_kinds(
                PreemptionPolicy(arrivals=self.ARRIVALS,
                                 mechanism="reboot"))


class TestCheckpointIntervalPolicy:
    def test_young_daly_interval(self):
        pol = CheckpointIntervalPolicy(mtbf_s=3600.0, step_time_s=1.0)
        job = JobSpec("train", min_nodes=1, max_nodes=8,
                      param_bytes=GIB)
        cost = (pol.cost_model or MN5).checkpoint(GIB)
        expected = max(1, round(math.sqrt(2.0 * cost * 3600.0)))
        assert pol.interval_steps(job) == expected

    def test_generates_pure_checkpoint_cadence(self):
        cluster = ClusterState(
            total_nodes=4,
            jobs=(JobSpec("train", min_nodes=1, max_nodes=4,
                          param_bytes=GIB),),
        )
        pol = CheckpointIntervalPolicy(mtbf_s=0.001, step_time_s=1.0,
                                       horizon=12)
        sc = pol.generate(cluster).scenario("train")
        kinds = {ev.kind for ev in sc.events}
        assert kinds == {"checkpoint"}
        recs = run_scenario_sim(sc)
        assert recs and all(r.bytes_checkpointed == GIB for r in recs)


# ======================================== vectorized stage round-trip ==
class TestVectorizedNewStages:
    def test_checkpoint_timeline_round_trips(self):
        tl = checkpoint_timeline(MN5, snapshot_bytes=GIB)
        back = EventArrays.from_timeline(tl).to_timeline()
        assert back == tl
        assert back.bytes_checkpointed == GIB
        assert back.span(Stage.CHECKPOINT) == tl.total

    def test_restart_timeline_round_trips(self):
        spec = CheckpointSpec(bytes_checkpointed=GIB, bytes_restored=GIB)
        assert spec.bytes_checkpointed == spec.bytes_restored == GIB
        tl = restart_timeline(
            MN5, ns=4, nt=2, nodes=1,
            snapshot_bytes=GIB, restore_bytes=GIB)
        ea = EventArrays.from_timeline(tl)
        back = ea.to_timeline()
        assert back == tl
        assert back.bytes_restored == GIB
        assert back.restored_s == tl.span(Stage.RESTORE) > 0
        # RESTORE bytes stay out of the stage-3 sums
        assert back.bytes_moved == tl.bytes_moved
