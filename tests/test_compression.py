"""Gradient-compression tests: fidelity, error feedback, convergence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import adamw_init, adamw_update
from repro.optim.compression import (
    compressed_bytes,
    compress_grads,
    compression_init,
    dequantize_int8,
    quantize_int8,
)


@given(seed=st.integers(0, 100), scale=st.floats(1e-3, 1e3))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bounded(seed, scale):
    x = jax.random.normal(jax.random.key(seed), (1000,)) * scale
    q, s = quantize_int8(x, block=256)
    deq = dequantize_int8(q, s, x.shape, x.dtype)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(deq - x))) <= blockmax / 127.0 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the long-run average of dequantized grads
    approaches the true gradient even when each step truncates."""
    g = {"w": jnp.full((256,), 0.003)}
    state = compression_init(g)
    total = jnp.zeros((256,))
    steps = 50
    for _ in range(steps):
        deq, state = compress_grads(g, state)
        total = total + deq["w"]
    np.testing.assert_allclose(
        np.asarray(total / steps), 0.003, rtol=0.05
    )


def test_compression_ratio_about_4x():
    g = {"w": jnp.zeros((1 << 16,), jnp.float32)}
    raw, comp = compressed_bytes(g)
    assert raw / comp > 3.5


def test_training_converges_with_compression():
    params = {"x": jnp.array([4.0, -2.0, 1.0])}
    opt = adamw_init(params)
    cstate = compression_init(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        g, cstate = compress_grads(g, cstate)
        params, opt = adamw_update(g, opt, params, 3e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2
