"""Vectorized charging layer: bit-for-bit parity with the object path.

Three contracts pinned here:

* :mod:`repro.core.vectorized` — ``EventArrays`` views and the analytic
  chargers reproduce the object timeline EXACTLY (same floats, same
  bytes), not approximately;
* :func:`repro.malleability.scenarios.run_scenario_vectorized` — every
  registered scenario (and every strategy) yields records identical to
  :func:`run_scenario_sim` through :func:`record_parity_key`;
* the mega-scale surfaces — the pinned 100k-event churn checksum and
  the seeded Monte-Carlo sweep — stay deterministic and fast.
"""
import hashlib
import random
import time
from dataclasses import replace

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Method,
    ReconfigEngine,
    ShrinkKind,
    Stage,
    registered_strategies,
    shrink_timeline,
)
from repro.core.vectorized import (
    Charge,
    EventArrays,
    charge_stats,
    hypercube_expand_charges,
    queue_charge,
    redistribution_charge,
    ts_shrink_charges,
)
from repro.malleability import (
    MN5,
    NASP,
    ChurnPolicy,
    CostModel,
    JobSpec,
    TransitionCache,
    monte_carlo_sweep,
    param_bytes_for_arch,
    record_parity_key,
    registered_scenarios,
    replicated_bytes_model,
    run_scenario_sim,
    run_scenario_vectorized,
)
from repro.malleability.policies import ClusterState as RmsClusterState
from repro.malleability.policies import churn_trace


def keys(records):
    return [record_parity_key(r) for r in records]


# ========================================================= registry parity ==
class TestRegistryParity:
    """run_scenario_vectorized == run_scenario_sim, record for record."""

    def test_every_registered_scenario(self):
        for sc in registered_scenarios():
            assert keys(run_scenario_vectorized(sc)) == \
                keys(run_scenario_sim(sc)), sc.name

    def test_every_strategy_on_steady_cycle(self):
        sc = next(s for s in registered_scenarios()
                  if s.name == "steady-cycle")
        for spec in registered_strategies():
            engine = sc.default_engine(strategy=spec.key)
            assert keys(run_scenario_vectorized(sc, engine=engine)) == \
                keys(run_scenario_sim(sc, engine=engine)), spec.key

    def test_shared_cache_replay_is_exact(self):
        sc = next(s for s in registered_scenarios() if s.name == "churn-200")
        cache = TransitionCache()
        first = keys(run_scenario_vectorized(sc, cache=cache))
        misses = cache.misses
        second = keys(run_scenario_vectorized(sc, cache=cache))
        assert first == second
        assert cache.misses == misses      # second run was all hits
        assert cache.hits >= len(first)


# ============================================================ EventArrays ==
class TestEventArrays:
    """Array views of a Timeline reproduce every query bit-for-bit."""

    ENGINE = ReconfigEngine(
        cost_model=MN5,
        bytes_model=replicated_bytes_model(param_bytes_for_arch("xlstm_125m")),
    )

    @settings(max_examples=20, deadline=None)
    @given(i=st.integers(min_value=1, max_value=12),
           grow=st.integers(min_value=1, max_value=20),
           asynchronous=st.booleans())
    def test_from_timeline_matches_every_query(self, i, grow, asynchronous):
        engine = replace(self.ENGINE, asynchronous=asynchronous)
        tl = engine.timeline(engine.plan_expand(i, i + grow, 1))
        ea = EventArrays.from_timeline(tl)
        assert ea.total == tl.total
        assert ea.downtime(asynchronous) == tl.downtime(asynchronous)
        assert ea.queued_s == tl.queued_s
        for stage in Stage:
            assert ea.span(stage) == tl.span(stage), stage
        assert ea.span_by_stage() == {s: tl.span(s) for s in Stage}
        assert ea.bytes_moved == tl.bytes_moved
        assert ea.bytes_stayed == tl.bytes_stayed
        assert ea.bytes_cross_rack == tl.bytes_cross_rack
        assert ea.bytes_cross_pod == tl.bytes_cross_pod
        assert ea.bytes_by_class == tl.bytes_by_class

    def test_to_timeline_roundtrip(self):
        tl = self.ENGINE.timeline(self.ENGINE.plan_expand(2, 8, 1))
        back = EventArrays.from_timeline(tl).to_timeline()
        assert back.events == tl.events

    def test_from_charges_replays_builder_clock(self):
        charges = (
            queue_charge(0.25)
            + [Charge(Stage.SPAWN, 0.1, overlap_fraction=0.5),
               Charge(Stage.SYNC, 0.0),           # dropped: duration <= 0
               Charge(Stage.CONNECT, 1e-3)]
            + redistribution_charge(MN5, 10_000, 5_000)
        )
        ea = EventArrays.from_charges(charges, contention=1.25)
        st_ = charge_stats(charges, contention=1.25, asynchronous=True)
        assert ea.total == st_.total
        assert ea.downtime(True) == st_.downtime
        assert ea.queued_s == st_.queued
        assert ea.bytes_moved == st_.bytes_moved
        assert ea.bytes_stayed == st_.bytes_stayed


# ======================================================= analytic chargers ==
class TestAnalyticChargers:
    """Closed-form charge lists == the planner/builder object pipeline."""

    @settings(max_examples=25, deadline=None)
    @given(i=st.integers(min_value=1, max_value=16),
           grow=st.integers(min_value=1, max_value=32),
           cores=st.sampled_from([1, 4, 20, 112]),
           profile=st.sampled_from(["mn5", "nasp"]),
           asynchronous=st.booleans(),
           qd=st.sampled_from([0.0, 0.125]))
    def test_hypercube_expand_parity(self, i, grow, cores, profile,
                                     asynchronous, qd):
        cm = MN5 if profile == "mn5" else NASP
        engine = ReconfigEngine(
            cost_model=cm, asynchronous=asynchronous,
            bytes_model=replicated_bytes_model(
                param_bytes_for_arch("xlstm_125m")),
        )
        ns, nt = i * cores, (i + grow) * cores
        plan = engine.plan_expand(ns, nt, cores, queue_delay_s=qd)
        tl = engine.timeline(plan)
        stayed, moved = engine.redistribution_stats(ns, nt)
        charges = (queue_charge(qd)
                   + hypercube_expand_charges(cm, ns, nt, cores)
                   + redistribution_charge(cm, moved, stayed))
        stats = charge_stats(charges, contention=cm.overlap_contention,
                             asynchronous=asynchronous)
        assert stats.total == tl.total
        assert stats.downtime == tl.downtime(asynchronous)
        assert stats.queued == tl.queued_s
        assert stats.bytes_moved == tl.bytes_moved
        assert stats.bytes_stayed == tl.bytes_stayed
        # Per-stage spans too: the charge list is the same event
        # sequence the builder emits, not merely the same totals.
        spans = EventArrays.from_charges(
            charges, contention=cm.overlap_contention).span_by_stage()
        assert spans == {s: tl.span(s) for s in Stage}

    @settings(max_examples=25, deadline=None)
    @given(i=st.integers(min_value=2, max_value=32),
           keep=st.integers(min_value=1, max_value=31),
           cores=st.sampled_from([1, 20, 112]),
           profile=st.sampled_from(["mn5", "nasp"]))
    def test_ts_shrink_parity(self, i, keep, cores, profile):
        if keep >= i:
            return
        cm = MN5 if profile == "mn5" else NASP
        ns, nt = i * cores, keep * cores
        tl = shrink_timeline(ShrinkKind.TS, cm, ns=ns, nt=nt,
                             doomed_world_sizes=[cores] * (i - keep))
        stats = charge_stats(ts_shrink_charges(cm, [cores] * (i - keep)),
                             contention=cm.overlap_contention)
        assert stats.total == tl.total
        assert stats.downtime == tl.downtime(False)


# ===================================================== random-trace parity ==
class TestRandomTraceParity:
    """Seeded random policies/traces: vectorized == object, field for field."""

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_random_churn_trace(self, seed):
        cluster = RmsClusterState(
            total_nodes=8, jobs=(JobSpec("train", min_nodes=1, max_nodes=8),))
        trace = ChurnPolicy(decisions=30, seed=seed).generate(cluster)
        sc = trace.scenario("train", name=f"churn-prop-{seed}")
        assert keys(run_scenario_vectorized(sc)) == keys(run_scenario_sim(sc))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_random_failure_trace_falls_back_identically(self, seed):
        # Random FAIL victims usually break the prefix-range invariant,
        # forcing the wholesale object fallback — which must be exact
        # too (it IS the object path, but the gate decision is ours).
        from repro.malleability.scenarios import (
            FAIL, GROW, Scenario, ScenarioEvent)

        rng = random.Random(seed)
        count = 6
        events = [ScenarioEvent(step=2, kind=GROW, target_nodes=count)]
        step = 4
        for _ in range(3):
            victim = rng.randrange(count)
            events.append(ScenarioEvent(step=step, kind=FAIL,
                                        nodes=(victim,)))
            count -= 1
            step += 2
        sc = Scenario(name=f"fail-prop-{seed}", description="random failures",
                      initial_nodes=2, events=tuple(events), steps=step + 2)
        assert keys(run_scenario_vectorized(sc)) == keys(run_scenario_sim(sc))


# ==================================================== churn determinism ==
class TestChurnAtScale:
    # Re-pinned when record_parity_key grew time_to_result_s (sixteenth
    # field; == est_wall_s on this model-free trace).  The 15-field
    # prefix still hashes to the historical
    # 6afb2ac8f20c67e010fc6a75010dc1aca251cbb39b5f5a27985105284ef4c4e1
    # and the 12-field prefix to
    # 3b96130a21cde34c5294b74d23207b6bab2eac939c14daa5c40f70f7cc0b20c3.
    PINNED_100K_SHA256 = (
        "4e003a56cc35d801e529d34740d0e93c87db7b5b6459ed08831ff428880976b6")

    def test_draw_stream_matches_historical_list_choice(self):
        """The O(1) resize draw == random.choice over the candidate list."""
        lo, hi = 1, 8
        for seed in range(50):
            fast, slow = random.Random(seed), random.Random(seed)
            alloc_f = alloc_s = 2
            for _ in range(200):
                if lo <= alloc_f <= hi:
                    target = lo + fast.randrange(hi - lo)
                    if target >= alloc_f:
                        target += 1
                else:
                    target = lo + fast.randrange(hi - lo + 1)
                historical = slow.choice(
                    [n for n in range(lo, hi + 1) if n != alloc_s])
                assert target == historical
                alloc_f = alloc_s = target

    def test_pinned_100k_event_checksum(self):
        """The 100k-decision churn trace replays bit-for-bit everywhere.

        Charging is pure IEEE-754 float arithmetic and ``repr`` is
        shortest-roundtrip, so the digest is platform-stable; any drift
        in the engine's charging (or the vectorized fast path) moves it.
        """
        sc = churn_trace(name="churn-100k", decisions=100_000)
        recs = run_scenario_vectorized(sc)
        assert len(recs) == 100_000
        digest = hashlib.sha256(
            "\n".join(repr(k) for k in keys(recs)).encode()).hexdigest()
        assert digest == self.PINNED_100K_SHA256


# ======================================================= Monte-Carlo sweep ==
class TestMonteCarloSweep:
    def test_shapes_and_cache_accounting(self):
        sweep = monte_carlo_sweep(ChurnPolicy(decisions=10), 20)
        assert sweep.n_replicas == 20
        assert len(sweep.makespans) == len(sweep.downtimes) == 20
        assert sweep.reconfigs == 200
        assert sweep.cache_hits + sweep.cache_misses == sweep.reconfigs
        assert sweep.cache_hits > 0        # replicas share transitions
        row = sweep.summary()
        assert row["replicas"] == 20
        assert row["makespan_min_s"] <= row["makespan_mean_s"] \
            <= row["makespan_max_s"]

    def test_replicas_match_object_path(self):
        cluster = RmsClusterState(
            total_nodes=8, jobs=(JobSpec("train", min_nodes=1, max_nodes=8),))
        policy = ChurnPolicy(decisions=15)
        sweep = monte_carlo_sweep(policy, 4, cluster=cluster)
        for s in (0, 3):
            trace = replace(policy, seed=s).generate(cluster)
            recs = run_scenario_sim(trace.scenario("train", name=f"mc-{s}"))
            assert sweep.makespans[s] == sum(r.est_wall_s for r in recs)
            assert sweep.downtimes[s] == sum(r.downtime_s for r in recs)

    def test_mega_scale_pod_sweep(self):
        """10k-node pod x 1000 replicas: seconds, not minutes.

        The strict <10s CI budget is enforced by the bench gate
        (``scripts/check_bench.py --max-mc-seconds``); the loose bound
        here only catches a fallback to the object path, which would
        take minutes, while staying robust under coverage tracing.
        """
        cluster = RmsClusterState(
            total_nodes=10_000,
            jobs=(JobSpec("train", min_nodes=1, max_nodes=10_000),))
        t0 = time.perf_counter()
        sweep = monte_carlo_sweep(
            ChurnPolicy(decisions=25), 1000, cluster=cluster)
        wall = time.perf_counter() - t0
        assert sweep.reconfigs == 25_000
        assert len(sweep.makespans) == 1000
        assert wall < 60.0, f"mega-scale sweep took {wall:.1f}s"


# ================================================ cached bandwidth lookup ==
class TestCachedBandwidthResolution:
    """Per-class bandwidth caching never changes a resolved value."""

    MODELS = (
        MN5,
        NASP,
        MN5.with_link_bandwidths(local=25.0e9, cross=2.5e9),
        MN5.with_link_bandwidths(
            local=25.0e9, cross=2.5e9
        ).with_class_bandwidths(intra_rack=10.0e9, cross_pod=1.0e9),
    )
    PROPS = ("bw_local", "bw_cross", "bw_intra_rack", "bw_cross_rack",
             "bw_cross_pod")

    def test_cached_equals_uncached_bit_for_bit(self):
        for cm in self.MODELS:
            for prop in self.PROPS:
                uncached = getattr(CostModel, prop).func(cm)
                assert getattr(cm, prop) == uncached, (cm, prop)
                # and stable on re-read (the cached value is returned)
                assert getattr(cm, prop) == uncached, (cm, prop)
            assert cm.class_bandwidths == {
                "intra_node": cm.bw_local,
                "intra_rack": cm.bw_intra_rack,
                "cross_rack": cm.bw_cross_rack,
                "cross_pod": cm.bw_cross_pod,
            }

    def test_charges_identical_on_first_and_cached_call(self):
        by_class = {"intra_node": 10_000, "intra_rack": 5_000,
                    "cross_rack": 2_000, "cross_pod": 1_000}
        for cm in self.MODELS:
            fresh = replace(cm)            # empty cache
            first = fresh.redistribution_by_class(by_class)
            again = fresh.redistribution_by_class(by_class)
            assert first == again == cm.redistribution_by_class(by_class)

    def test_replace_resets_the_cache(self):
        cm = MN5.with_link_bandwidths(local=25.0e9, cross=2.5e9)
        assert cm.bw_cross == 2.5e9        # populate the cache
        bumped = replace(cm, redist_bw_cross=5.0e9)
        assert bumped.bw_cross == 5.0e9    # no stale carryover
