"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Shape/dtype sweeps + property-based gate/mask behavior.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import flash_attention, mlstm_scan, ssd_scan
from repro.kernels.ref import attention_ref, mlstm_ref, ssd_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------- flash attn --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,Sq,Sk,D,bq,bk",
    [
        (1, 2, 2, 128, 128, 64, 64, 64),     # MHA square
        (2, 8, 2, 128, 128, 64, 32, 64),     # GQA group=4
        (1, 4, 1, 64, 256, 32, 64, 64),      # MQA, cross lengths
        (2, 3, 3, 96, 96, 16, 32, 32),       # head dim 16, odd blocks
    ],
)
def test_flash_attention_shapes(B, H, KV, Sq, Sk, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = rand(ks[0], (B, H, Sq, D), dtype)
    k = rand(ks[1], (B, KV, Sk, D), dtype)
    v = rand(ks[2], (B, KV, Sk, D), dtype)
    causal = Sq == Sk
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(jax.random.key(1), 3)
    q = rand(ks[0], (1, 2, 128, 32), jnp.float32)
    k = rand(ks[1], (1, 2, 128, 32), jnp.float32)
    v = rand(ks[2], (1, 2, 128, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.key(2), 3)
    q = rand(ks[0], (1, 2, 64, 32), jnp.float32) * 4
    k = rand(ks[1], (1, 2, 64, 32), jnp.float32) * 4
    v = rand(ks[2], (1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, softcap=20.0, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@given(
    seed=st.integers(0, 1000),
    logsq=st.integers(5, 8),
    group=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(seed, logsq, group):
    """Random shapes: kernel == oracle, and each output row is a convex
    combination of V rows (|out| <= max |v|)."""
    S = 2 ** logsq
    KV, D = 2, 32
    ks = jax.random.split(jax.random.key(seed), 3)
    q = rand(ks[0], (1, KV * group, S, D), jnp.float32)
    k = rand(ks[1], (1, KV, S, D), jnp.float32)
    v = rand(ks[2], (1, KV, S, D), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


# -------------------------------------------------------------------- ssd --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (1, 64, 2, 16, 8, 16),
        (2, 128, 3, 16, 8, 32),
        (1, 128, 1, 32, 16, 64),
        (2, 96, 2, 8, 4, 32),
    ],
)
def test_ssd_shapes(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(3), 5)
    x = rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = rand(ks[3], (B, S, N), dtype)
    Cm = rand(ks[4], (B, S, N), dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_ssd_chunked_matches_model_oracle():
    """The kernel, the model's chunked jnp path, and the sequential
    recurrence must all agree."""
    from repro.models.ssm import ssd_chunked

    ks = jax.random.split(jax.random.key(4), 5)
    B, S, H, P, N = 2, 64, 2, 16, 8
    x = rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = rand(ks[3], (B, S, N), jnp.float32)
    Cm = rand(ks[4], (B, S, N), jnp.float32)
    y_seq, st_seq = ssd_ref(x, dt, A, Bm, Cm)
    y_chk, st_chk = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y_ker = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_seq), rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_ssd_decay_property(seed):
    """With very negative A (fast decay), output ~ local: dt*C.B*x only."""
    ks = jax.random.split(jax.random.key(seed), 5)
    B, S, H, P, N = 1, 32, 1, 8, 4
    x = rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jnp.ones((B, S, H)) * 0.5
    A = jnp.full((H,), -50.0)   # state dies between steps
    Bm = rand(ks[3], (B, S, N), jnp.float32)
    Cm = rand(ks[4], (B, S, N), jnp.float32)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=8)
    local = jnp.einsum("bsn,bsn->bs", Cm, Bm)[:, :, None, None] * 0.5 * x
    np.testing.assert_allclose(np.asarray(y), np.asarray(local), rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ mlstm --
@pytest.mark.parametrize(
    "B,S,H,D,chunk",
    [(1, 64, 2, 16, 16), (2, 128, 2, 16, 32), (1, 96, 1, 32, 32)],
)
def test_mlstm_shapes(B, S, H, D, chunk):
    ks = jax.random.split(jax.random.key(5), 5)
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, S, H, D), jnp.float32)
    v = rand(ks[2], (B, S, H, D), jnp.float32)
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h = mlstm_scan(q, k, v, ig, fg, chunk=chunk)
    hr = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=2e-4, atol=2e-4)


def test_mlstm_matches_model_chunked():
    from repro.models.xlstm import mlstm_chunked

    ks = jax.random.split(jax.random.key(6), 5)
    B, S, H, D = 2, 64, 2, 8
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, S, H, D), jnp.float32)
    v = rand(ks[2], (B, S, H, D), jnp.float32)
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    h_model, _ = mlstm_chunked(q, k, v, ig, fg, chunk=16)
    h_kernel = mlstm_scan(q, k, v, ig, fg, chunk=16)
    h_seq = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_kernel), np.asarray(h_seq), rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_mlstm_extreme_gates_stable(seed):
    """Extreme gate preactivations must not produce NaN/Inf (the
    stabilizer state is the whole point)."""
    ks = jax.random.split(jax.random.key(seed), 5)
    B, S, H, D = 1, 32, 1, 8
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, S, H, D), jnp.float32)
    v = rand(ks[2], (B, S, H, D), jnp.float32)
    ig = jax.random.normal(ks[3], (B, S, H)) * 20    # exp gate up to e^20
    fg = jax.random.normal(ks[4], (B, S, H)) * 20
    h = mlstm_scan(q, k, v, ig, fg, chunk=8)
    assert bool(jnp.all(jnp.isfinite(h)))
    hr = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=5e-4, atol=5e-4)
