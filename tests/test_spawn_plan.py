"""Tests for the spawn planners (paper §4.1-§4.2, Eqs. 1-8)."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Method,
    SOURCE_GID,
    Strategy,
    nodes_at_step,
    plan_diffusive,
    plan_hypercube,
    plan_sequential,
    procs_at_step,
    steps_required,
)


# ---------------------------------------------------------------- hypercube --
class TestHypercube:
    def test_figure1_example(self):
        """NS=1 -> NT=8 with C=1: 7 groups over 3 steps, cube edges."""
        p = plan_hypercube(1, 8, 1, Method.MERGE)
        assert p.steps == 3
        assert len(p.groups) == 7
        edges = {(g.parent_gid, g.gid) for g in p.groups}
        assert edges == {(SOURCE_GID, 0), (SOURCE_GID, 1), (0, 2),
                         (SOURCE_GID, 3), (0, 4), (1, 5), (2, 6)}
        assert [g.step for g in p.groups] == [1, 2, 2, 3, 3, 3, 3]

    def test_section41_20core_example(self):
        """§4.1: 20 cores/node, 1 full node: step1 +20 nodes, step2 +420."""
        assert nodes_at_step(1, 1, 20, Method.MERGE) == 21
        assert nodes_at_step(2, 1, 20, Method.MERGE) == 441
        assert procs_at_step(2, 1, 20, Method.MERGE) == 8820

    def test_divisibility_required(self):
        with pytest.raises(ValueError):
            plan_hypercube(3, 8, 2, Method.MERGE)
        with pytest.raises(ValueError):
            plan_hypercube(2, 7, 2, Method.MERGE)

    @given(
        cores=st.integers(1, 64),
        initial=st.integers(1, 8),
        target=st.integers(1, 64),
        method=st.sampled_from([Method.MERGE, Method.BASELINE]),
    )
    @settings(max_examples=200, deadline=None)
    def test_plan_invariants(self, cores, initial, target, method):
        if target < initial:
            target = initial + target  # keep it an expansion
        ns, nt = initial * cores, target * cores
        p = plan_hypercube(ns, nt, cores, method)
        want_groups = target if method is Method.BASELINE else target - initial
        # every group spawned exactly once, ids dense, node-confined, size C
        assert len(p.groups) == want_groups
        assert [g.gid for g in p.groups] == list(range(want_groups))
        assert all(g.size == cores for g in p.groups)
        assert all(len(g.nodes_spanned()) == 1 for g in p.groups)
        # nodes all distinct
        assert len({g.node for g in p.groups}) == want_groups
        # parent existed strictly before child
        step_of = {g.gid: g.step for g in p.groups}
        step_of[SOURCE_GID] = 0
        for g in p.groups:
            assert step_of[g.parent_gid] < g.step
        # per-step spawn count <= live processes (capacity, Eq. 2)
        for s in range(1, p.steps + 1):
            live = ns + sum(g.size for g in p.groups if g.step < s)
            assert len(p.groups_in_step(s)) <= live
        # step count matches the closed form
        if method is Method.MERGE:
            assert p.steps == steps_required(target, initial, cores)
        # total processes
        assert p.trace[-1].t == ns + sum(p.group_sizes)

    @given(cores=st.integers(1, 128), initial=st.integers(1, 16),
           target=st.integers(1, 600))
    @settings(max_examples=200, deadline=None)
    def test_eq3_closed_form(self, cores, initial, target):
        """Eq. 3 == smallest s with (C+1)^s * I >= N."""
        if target < initial:
            return
        s = steps_required(target, initial, cores)
        assert (cores + 1) ** s * initial >= target
        if s > 0:
            assert (cores + 1) ** (s - 1) * initial < target

    def test_baseline_respawns_full_allocation(self):
        p = plan_hypercube(4, 8, 2, Method.BASELINE)
        assert len(p.groups) == 4          # N groups, not N - I
        assert sum(p.group_sizes) == 8     # full NT
        # R records source occupancy during reconfig (nodes 0..I-1) but the
        # sources do not persist into the target world (method=BASELINE).
        assert tuple(p.running) == (2, 2, 0, 0)
        # the last groups land on the source nodes -> transient oversubscription
        assert {g.node for g in p.groups} == {0, 1, 2, 3}

    def test_baseline_shrink_direction_oversubscribes_all(self):
        p = plan_hypercube(8, 4, 2, Method.BASELINE)
        assert len(p.groups) == 2
        assert {g.node for g in p.groups} == {0, 1}   # all source-occupied


# ---------------------------------------------------------------- diffusive --
TABLE2_A = [4, 2, 8, 12, 3, 3, 4, 4, 6, 3]
TABLE2_R = [2, 0, 0, 0, 0, 0, 0, 0, 0, 0]


class TestDiffusive:
    def test_table2_exact(self):
        """Reproduce Table 2 (t, g, T, G columns exactly; lambda per Eq. 6).

        The paper's printed lambda_2=7 / lambda_3=47 is an off-by-one typo
        (propagated); iterating Eq. 6 gives 8 and 48, and the g/t/T/G
        values printed in the table are only consistent with 8/48.
        """
        p = plan_diffusive(TABLE2_A, TABLE2_R, Method.MERGE)
        ts = [tr.t for tr in p.trace]
        gs = [tr.g for tr in p.trace][1:]
        Ts = [tr.T for tr in p.trace]
        Gs = [tr.G for tr in p.trace][1:]
        lams = [tr.lam for tr in p.trace]
        assert ts == [2, 6, 40, 49]
        assert gs == [4, 34, 9]
        assert Ts == [1, 2, 8, 10]
        assert Gs == [1, 6, 2]
        assert lams == [0, 2, 8, 48]
        assert p.steps == 3
        assert p.nt == 49

    def test_group_node_alignment(self):
        p = plan_diffusive(TABLE2_A, TABLE2_R, Method.MERGE)
        # one group per node with S_i > 0, sized S_i, in node order
        assert [(g.node, g.size) for g in p.groups] == [
            (i, s) for i, s in enumerate(p.to_spawn) if s > 0
        ]

    @given(
        a_vec=st.lists(st.integers(0, 16), min_size=1, max_size=32),
        seed=st.integers(0, 2**31),
        method=st.sampled_from([Method.MERGE, Method.BASELINE]),
    )
    @settings(max_examples=300, deadline=None)
    def test_plan_invariants(self, a_vec, seed, method):
        import random

        rng = random.Random(seed)
        r_vec = [rng.randint(0, a) for a in a_vec]
        if sum(r_vec) == 0:
            r_vec[rng.randrange(len(r_vec))] = max(a_vec) or 1
            a_vec = [max(a, r) for a, r in zip(a_vec, r_vec)]
        p = plan_diffusive(a_vec, r_vec, method)
        s_expected = (
            [a - r for a, r in zip(a_vec, r_vec)] if method is Method.MERGE else a_vec
        )
        assert list(p.to_spawn) == s_expected
        # every positive S entry spawns exactly one node-confined group
        assert [(g.node, g.size) for g in p.groups] == [
            (i, s) for i, s in enumerate(s_expected) if s > 0
        ]
        # lambda progression consumes contiguous, non-overlapping segments
        for prev, cur in zip(p.trace, p.trace[1:]):
            assert cur.lam == prev.lam + prev.t          # Eq. 6
            lo, hi = prev.lam, min(len(a_vec), cur.lam)
            seg = [s_expected[i] for i in range(lo, hi)]
            assert cur.g == sum(seg)                     # Eq. 5
            assert cur.t == prev.t + cur.g               # Eq. 4
            assert cur.G == sum(                          # Eq. 8
                1 for i in range(lo, hi) if r_vec[i] == 0 and s_expected[i] > 0
            )
            assert cur.T == prev.T + cur.G               # Eq. 7
        # parent of each group existed before it
        step_of = {g.gid: g.step for g in p.groups}
        step_of[SOURCE_GID] = 0
        for g in p.groups:
            assert step_of[g.parent_gid] < g.step
        # capacity: per-step groups come from distinct live spawners
        for s in range(1, p.steps + 1):
            live = p.trace[s - 1].t
            assert len(p.groups_in_step(s)) <= live
        # totals
        assert p.nt == sum(s_expected) + (p.ns if method is Method.MERGE else 0)

    def test_rejects_mixed_shrink(self):
        with pytest.raises(ValueError):
            plan_diffusive([2, 2], [4, 0], Method.MERGE)

    def test_hypercube_is_diffusive_special_case(self):
        """Homogeneous allocations: both strategies spawn the same groups
        (same node/size multiset), though possibly in different steps."""
        c, i, n = 4, 2, 9
        hp = plan_hypercube(i * c, n * c, c, Method.MERGE)
        dp = plan_diffusive([c] * n, [c] * i + [0] * (n - i), Method.MERGE)
        assert sorted((g.node, g.size) for g in hp.groups) == sorted(
            (g.node, g.size) for g in dp.groups
        )


# --------------------------------------------------------------- sequential --
class TestSequential:
    def test_collective_spawn_spans_nodes(self):
        """Classic Merge: one world spanning all new nodes -> no TS possible."""
        p = plan_sequential(4, 16, [4, 4, 4, 4], Method.MERGE)
        assert p.strategy is Strategy.SEQUENTIAL
        assert len(p.groups) == 1
        assert p.groups[0].size == 12
        assert len(p.groups[0].nodes_spanned()) == 3

    def test_per_node_is_node_confined_but_serial(self):
        p = plan_sequential(4, 16, [4, 4, 4, 4], Method.MERGE, per_node=True)
        assert len(p.groups) == 3
        assert all(len(g.nodes_spanned()) == 1 for g in p.groups)
        # serial: steps == number of groups
        assert p.steps == 3
        assert [g.step for g in p.groups] == [1, 2, 3]
