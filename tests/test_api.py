"""Stable-surface tests: repro.api resolves completely, the deprecation
shims warn exactly once, the closed-loop scheduler optimizer is seeded-
deterministic and beats the rigid-cluster baseline on every registered
workload, and dmr-async's two-phase expands never stall longer than the
synchronous strategies on the identical schedule."""
import importlib
import subprocess
import sys
import warnings

import pytest

from repro import api
from repro.api import (
    KNOB_GRID,
    WORKLOAD_TRACES,
    SchedulerKnobs,
    evaluate_schedule,
    generate_workload,
    optimize_schedule,
    registered_strategies,
    registered_workload_scenarios,
    rigid_baseline,
)

# A CI-sized knob search: the 8 grid corners plus two seeded restarts —
# the same code path as the full 27-cell grid, seconds instead of
# minutes across the parametrized strategies.
SMALL_GRID = tuple(
    SchedulerKnobs(backfill_threshold=t, preempt_priority=p,
                   placement_quantum=q)
    for t in (1, 4) for p in (80, 1000) for q in (1, 2)
)


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


# ------------------------------------------------------------ the surface --
def test_all_is_sorted_within_sections_and_duplicate_free():
    assert len(set(api.__all__)) == len(api.__all__)


def test_every_public_name_resolves():
    """getattr succeeds for every name in __all__ (the check_api gate's
    contract); jax-backed lazy names are skipped on jax-less hosts but
    must still be *listed*."""
    lazy = set(api._LAZY_EXPORTS)
    assert lazy < set(api.__all__)
    has_jax = _jax_available()
    for name in api.__all__:
        if name in lazy and not has_jax:
            continue
        assert getattr(api, name) is not None, name


def test_package_level_reexport_is_the_same_object():
    import repro

    assert repro.ReconfigEngine is api.ReconfigEngine
    assert repro.api is api
    with pytest.raises(AttributeError):
        repro.no_such_name


def test_lazy_names_are_not_imported_eagerly():
    """`import repro.api` must stay cheap: a fresh interpreter that only
    imports the surface must not have pulled jax in."""
    code = (
        "import sys; import repro.api; "
        "sys.exit(1 if 'jax' in sys.modules else 0)"
    )
    proc = subprocess.run([sys.executable, "-c", code])
    assert proc.returncode == 0, "import repro.api imported jax eagerly"


# ------------------------------------------------------ deprecation shims --
def test_rms_policy_shim_warns_exactly_once():
    import repro.elastic.rms as rms

    name = "BackfillPolicy"
    rms.__dict__.pop(name, None)    # reset the warn-once cache
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        first = getattr(rms, name)
        second = getattr(rms, name)
    assert first is second
    from repro.malleability.policies import BackfillPolicy

    assert first is BackfillPolicy
    deprecations = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deprecations) == 1
    assert "repro.api" in str(deprecations[0].message)


def test_rms_native_names_do_not_warn():
    importlib.import_module("repro.elastic.rms")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.elastic.rms import Event, EventKind, SimulatedRMS  # noqa: F401


def test_rms_unknown_name_raises():
    import repro.elastic.rms as rms

    with pytest.raises(AttributeError):
        rms.definitely_not_a_name


# ------------------------------------------------- normalized signatures --
def test_monte_carlo_sweep_positional_cluster_shim_warns():
    from repro.api import ChurnPolicy, ClusterState, JobSpec, monte_carlo_sweep

    cluster = ClusterState(
        total_nodes=8, jobs=(JobSpec("train", min_nodes=1, max_nodes=8),))
    policy = ChurnPolicy(decisions=3)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = monte_carlo_sweep(policy, 2, cluster)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    new = monte_carlo_sweep(policy, 2, cluster=cluster)
    assert old.makespans == new.makespans


# ------------------------------------------------------- the closed loop --
def test_workloads_are_registered_as_scenarios():
    scs = registered_workload_scenarios()
    assert {sc.name.split(":")[0] for sc in scs} == set(WORKLOAD_TRACES)


def test_generate_workload_is_seeded():
    a = generate_workload("t", pool_nodes=16, n_malleable=3, n_rigid=10,
                          horizon=40, seed=7)
    b = generate_workload("t", pool_nodes=16, n_malleable=3, n_rigid=10,
                          horizon=40, seed=7)
    c = generate_workload("t", pool_nodes=16, n_malleable=3, n_rigid=10,
                          horizon=40, seed=8)
    assert a == b
    assert a != c


def test_optimizer_is_deterministic():
    trace = WORKLOAD_TRACES["slurm-burst"]
    r1 = optimize_schedule(trace, grid=SMALL_GRID, n_random=2, seed=3)
    r2 = optimize_schedule(trace, grid=SMALL_GRID, n_random=2, seed=3)
    assert r1.best.knobs == r2.best.knobs
    assert r1.best.score == r2.best.score
    assert r1.scores == r2.scores


@pytest.mark.parametrize("workload", sorted(WORKLOAD_TRACES))
def test_optimizer_beats_rigid_baseline(workload):
    """The acceptance criterion: for every registered workload trace the
    optimized malleable schedule scores strictly better than the
    rigid-cluster control, and the win holds under every registered
    spawning strategy at the same knobs."""
    trace = WORKLOAD_TRACES[workload]
    result = optimize_schedule(trace, grid=SMALL_GRID, n_random=2)
    assert result.beats_baseline
    base = result.baseline
    assert base.reconfigs == 0 and base.makespan_s == 0.0
    for spec in registered_strategies():
        out = evaluate_schedule(trace, result.best.knobs, strategy=spec.key)
        assert out.score < base.score, (workload, spec.key)
        assert out.reconfigs > 0


@pytest.mark.parametrize("workload", sorted(WORKLOAD_TRACES))
def test_dmr_async_expand_downtime_beats_sync(workload):
    """dmr-async overlaps the stage-1/2 spawn legs, so its expansions'
    downtime share must come in at or below every synchronous strategy's
    on the identical optimized schedule — at unchanged total makespan
    versus the plan-equivalent strategy (hypercube on homogeneous
    pools)."""
    trace = WORKLOAD_TRACES[workload]
    knobs = KNOB_GRID[0]
    dmr = evaluate_schedule(trace, knobs, strategy="dmr-async")
    sync = {spec.key: evaluate_schedule(trace, knobs, strategy=spec.key)
            for spec in registered_strategies() if spec.key != "dmr-async"}
    for key, out in sync.items():
        assert dmr.expand_downtime_s <= out.expand_downtime_s + 1e-9, key
    assert dmr.expand_downtime_s < sync["hypercube"].expand_downtime_s
    assert dmr.makespan_s == pytest.approx(sync["hypercube"].makespan_s)


def test_rigid_baseline_pins_peak_and_never_reconfigures():
    trace = WORKLOAD_TRACES["slurm-burst"]
    base = rigid_baseline(trace)
    assert base.knobs is None
    assert base.reconfigs == 0
    assert base.downtime_s == 0.0
    assert base.mean_queue_s > 0.0
