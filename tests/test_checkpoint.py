"""Checkpoint store: crash-safety, retention, mesh-independent restore.

The fast tests run in-process on the default (1-device) host; the
cross-mesh restore round-trip runs in a subprocess with 8 forced host
devices, like the other multi-device suites.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_tree,
    save_tree,
)


def tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(
            __import__("jax").tree.leaves(a), __import__("jax").tree.leaves(b)
        )
    )


class TestSaveRestore:
    def test_round_trip(self, tmp_path):
        tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b": np.ones(4, dtype=np.int32)}
        path = save_tree(tree, str(tmp_path), 7)
        assert os.path.isdir(path)
        assert latest_step(str(tmp_path)) == 7
        out = restore_tree({"w": 0, "b": 0}, str(tmp_path), 7)
        assert tree_eq(out, tree)

    def test_latest_step_discovery_ignores_tmp_and_noise(self, tmp_path):
        assert latest_step(str(tmp_path / "missing")) is None
        save_tree({"x": np.zeros(2)}, str(tmp_path), 3)
        save_tree({"x": np.zeros(2)}, str(tmp_path), 11)
        os.makedirs(tmp_path / "step_000000099.tmp")  # orphaned staging
        (tmp_path / "notes.txt").write_text("ignored")
        assert latest_step(str(tmp_path)) == 11

    def test_same_step_overwrite_replaces_whole_snapshot(self, tmp_path):
        save_tree({"x": np.zeros(4), "y": np.zeros(2)}, str(tmp_path), 5)
        save_tree({"x": np.full(4, 9.0)}, str(tmp_path), 5)
        out = restore_tree({"x": 0}, str(tmp_path), 5)
        assert np.array_equal(np.asarray(out["x"]), np.full(4, 9.0))
        # the stale second leaf did not survive the overwrite
        files = os.listdir(tmp_path / "step_000000005")
        assert sorted(files) == ["leaf_00000.npy", "manifest.json"]

    def test_failed_write_cleans_staging_dir(self, tmp_path):
        class Poison:
            def __array__(self, dtype=None):
                raise RuntimeError("leaf write failure")

        save_tree({"ok": np.zeros(2)}, str(tmp_path), 1)
        with pytest.raises(RuntimeError, match="leaf write failure"):
            save_tree({"a": np.zeros(2), "b": Poison()}, str(tmp_path), 2)
        # no orphaned .tmp, no half-published step, step 1 untouched
        assert [n for n in os.listdir(tmp_path) if n.endswith(".tmp")] == []
        assert latest_step(str(tmp_path)) == 1
        assert tree_eq(restore_tree({"ok": 0}, str(tmp_path), 1),
                       {"ok": np.zeros(2)})

    def test_leaf_count_mismatch_raises(self, tmp_path):
        save_tree({"x": np.zeros(2)}, str(tmp_path), 1)
        with pytest.raises(ValueError, match="leaves"):
            restore_tree({"x": 0, "y": 0}, str(tmp_path), 1)


class TestCheckpointManager:
    def test_restore_latest_empty_store(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        assert cm.restore_latest({"x": 0}) == (None, None)

    def test_async_save_then_restore_latest(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save({"x": np.arange(4.0)}, 10)
        cm.save({"x": np.arange(4.0) * 2}, 20)
        tree, step = cm.restore_latest({"x": 0})
        assert step == 20
        assert np.array_equal(np.asarray(tree["x"]), np.arange(4.0) * 2)

    def test_retention_keeps_last_k(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            cm.save({"x": np.full(2, float(s))}, s)
        kept = sorted(
            int(n.split("_")[1]) for n in os.listdir(tmp_path)
            if n.startswith("step_")
        )
        assert kept == [3, 4]
        tree, step = cm.restore_latest({"x": 0})
        assert step == 4 and float(np.asarray(tree["x"])[0]) == 4.0


CROSS_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager

    def mesh_of(k):
        devs = np.asarray(jax.devices()[:k], dtype=object).reshape((k,))
        return Mesh(devs, ("data",))

    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    big = mesh_of(8)
    sharded = jax.device_put(tree["w"], NamedSharding(big, P("data")))

    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save({"w": sharded}, 42)
        small = mesh_of(2)
        out, step = cm.restore_latest(
            {"w": 0}, mesh=small, spec_tree={"w": P("data")})
        assert step == 42
        restored = out["w"]
        assert restored.sharding.mesh.devices.shape == (2,)
        assert np.array_equal(np.asarray(restored), tree["w"])
    print("CROSS_MESH_OK")
""")


@pytest.mark.slow
def test_cross_mesh_restore_round_trip():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", CROSS_MESH_SCRIPT], capture_output=True,
        text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    assert "CROSS_MESH_OK" in proc.stdout
