"""Dry-run machinery tests: HLO analysis unit tests + one real cell as a
subprocess (slow)."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import analyze, split_computations

SAMPLE_HLO = """\
HloModule test

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[8,16] get-tuple-element(%arg), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%gte1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), to_apply=%add.1
  ROOT %tup = (s32[], f32[8,16]) tuple(%gte0, %ar)
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]) parameter(0)
  %gte = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%add.1 (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %a = f32[] add(%x, %y)
}

ENTRY %main.1 (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  %init = (s32[], f32[8,16]) tuple(s32[] constant(0), %p0)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""


class TestHloAnalysis:
    def test_split_finds_all_computations(self):
        comps = split_computations(SAMPLE_HLO)
        assert {"body.1", "cond.1", "add.1", "main.1"} <= set(comps)

    def test_while_trip_count_scales_body(self):
        r = analyze(SAMPLE_HLO)
        # dot: 2 * 8*16 * 16 = 4096 flops, x12 trips
        assert r["flops"] == pytest.approx(4096 * 12)
        # all-reduce: 8*16*4 bytes * 2 (ring) * 12 trips
        assert r["collectives"]["per_kind"]["all-reduce"] == 8 * 16 * 4 * 2 * 12
        assert r["collectives"]["counts"]["all-reduce"] == 12

    def test_no_collectives_outside_loop(self):
        r = analyze(SAMPLE_HLO)
        assert r["collectives"]["per_kind"]["all-gather"] == 0


class TestSkipPolicy:
    def test_long_500k_skip_records(self):
        from repro.configs import SHAPES, shape_skip_reason

        long = next(s for s in SHAPES if s.name == "long_500k")
        assert shape_skip_reason("yi_34b", long) is not None
        assert shape_skip_reason("zamba2_1p2b", long) is None
        assert shape_skip_reason("gemma2_9b", long) is None
        assert shape_skip_reason("xlstm_125m", long) is None


@pytest.mark.slow
class TestDryRunCell:
    def test_one_cell_compiles_multi_pod(self):
        """xlstm train_4k on the 2x16x16 mesh must lower+compile and emit
        a well-formed record (the multi-pod dry-run deliverable)."""
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = "/tmp/test_dryrun_cell.json"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "xlstm_125m", "--shape", "train_4k",
             "--mesh", "multi", "--out", out],
            capture_output=True, text=True, timeout=1800, env=env,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        rec = json.load(open(out))
        assert rec["status"] == "ok"
        assert rec["n_chips"] == 512
        assert rec["per_device"]["flops"] > 0
        assert rec["collectives"]["total_bytes"] > 0
