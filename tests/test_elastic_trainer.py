"""ElasticTrainer end-to-end on host devices (subprocess: needs >1 dev)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.configs import smoke_config
    from repro.elastic import DevicePool, ElasticTrainer, ElasticRuntime, SimulatedRMS
    from repro.elastic.rms import EventKind
    from repro.models import Model

    cfg = smoke_config("stablelm_3b")
    rt = ElasticRuntime(pool=DevicePool(), initial_nodes=1)
    rms = SimulatedRMS.scripted([
        (5, EventKind.GROW, 4),
        (10, EventKind.SHRINK, (2, 3)),
        (15, EventKind.FAIL, 1),
    ])
    tr = ElasticTrainer(model=Model(cfg), runtime=rt, rms=rms, batch=8, seq=32)
    hist = tr.run(20)
    assert len(hist) == 20
    nodes = [r.n_nodes for r in hist]
    assert nodes[4] == 1 and nodes[5] == 4, nodes
    assert nodes[10] == 2, nodes
    assert nodes[15] == 1, nodes
    losses = np.array(tr.losses())
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    # redistribution happened on every reconfiguration
    assert len(tr.transfer_log) == 3
    assert all(t["bytes_total"] > 0 for t in tr.transfer_log)
    # reconfig history recorded TS for the shrink and the failure
    kinds = [(r.kind, r.mechanism) for r in rt.history]
    assert ("shrink", "termination_shrinkage") in kinds
    assert ("fail", "termination_shrinkage") in kinds
    print("ELASTIC_TRAINER_OK", losses[0], "->", losses[-1])
""")


@pytest.mark.slow
def test_elastic_trainer_event_loop():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=1200, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    assert "ELASTIC_TRAINER_OK" in proc.stdout
