"""ReconfigEngine tests: registry parity with the pre-refactor planners,
timeline structure, and the downtime-dedup regression (simulator report
and runtime record must read the same timeline)."""
import pytest

from repro.core import (
    Method,
    ReconfigEngine,
    ShrinkKind,
    Stage,
    Strategy,
    StrategySpec,
    expansion_timeline,
    get_strategy,
    plan_diffusive,
    plan_hypercube,
    plan_sequential,
    register_strategy,
    registered_strategies,
    shrink_timeline,
    strategy_key,
)
from repro.core.engine import _STRATEGY_REGISTRY
from repro.elastic import DevicePool, ElasticRuntime
from repro.malleability import MN5, NASP, simulate_expansion, simulate_shrink

C = 112

# (ns, nt, cores) grid: scalar widths and heterogeneous vectors.
HOMOGENEOUS_CASES = [
    (C, 2 * C, C),
    (C, 8 * C, C),
    (2 * C, 32 * C, C),
    (4, 16, 4),
    (2, 20, 2),
]
HETEROGENEOUS_CASES = [
    (4, 10, [4, 2, 4]),
    (20, 104, [20, 32, 20, 32]),
    (6, 33, [6, 3, 8, 12, 4]),
]


def _running(alloc, ns):
    out, rem = [], ns
    for a in alloc:
        take = min(a, rem)
        out.append(take)
        rem -= take
    return out


class TestRegistryParity:
    """Every registered built-in must reproduce its pre-refactor planner
    exactly (plan objects compare field-by-field: frozen dataclasses)."""

    @pytest.mark.parametrize("ns,nt,cores", HOMOGENEOUS_CASES)
    @pytest.mark.parametrize("method", [Method.MERGE, Method.BASELINE])
    def test_hypercube_parity(self, ns, nt, cores, method):
        spec = get_strategy(Strategy.PARALLEL_HYPERCUBE)
        assert spec.planner(ns, nt, cores, method) == plan_hypercube(
            ns, nt, cores, method)

    @pytest.mark.parametrize("ns,nt,cores", HOMOGENEOUS_CASES + HETEROGENEOUS_CASES)
    @pytest.mark.parametrize("method", [Method.MERGE, Method.BASELINE])
    def test_diffusive_parity(self, ns, nt, cores, method):
        a_vec = [cores] * (-(-nt // cores)) if isinstance(cores, int) else cores
        spec = get_strategy(Strategy.PARALLEL_DIFFUSIVE)
        assert spec.planner(ns, nt, cores, method) == plan_diffusive(
            a_vec, _running(a_vec, ns), method)

    @pytest.mark.parametrize("ns,nt,cores", HOMOGENEOUS_CASES + HETEROGENEOUS_CASES)
    @pytest.mark.parametrize("method", [Method.MERGE, Method.BASELINE])
    @pytest.mark.parametrize(
        "strategy,kwargs",
        [
            (Strategy.SEQUENTIAL, {}),
            (Strategy.SEQUENTIAL_PER_NODE, {"per_node": True}),
            (Strategy.SINGLE, {"single": True}),
        ],
    )
    def test_classic_parity(self, ns, nt, cores, method, strategy, kwargs):
        a_vec = [cores] * (-(-nt // cores)) if isinstance(cores, int) else cores
        spec = get_strategy(strategy)
        assert spec.planner(ns, nt, cores, method) == plan_sequential(
            ns, nt, a_vec, method, **kwargs)

    def test_all_five_builtins_registered(self):
        keys = {s.key for s in registered_strategies()}
        assert {s.value for s in Strategy} <= keys

    def test_hypercube_collapses_uniform_vector(self):
        spec = get_strategy(Strategy.PARALLEL_HYPERCUBE)
        assert spec.planner(4, 16, [4, 4, 4, 4], Method.MERGE) == plan_hypercube(
            4, 16, 4, Method.MERGE)

    def test_hypercube_rejects_heterogeneous_vector(self):
        with pytest.raises(ValueError):
            get_strategy(Strategy.PARALLEL_HYPERCUBE).planner(
                4, 10, [4, 2, 4], Method.MERGE)


class TestRegistry:
    def test_unknown_strategy_raises(self):
        with pytest.raises(KeyError):
            get_strategy("warp-drive")

    def test_duplicate_registration_raises(self):
        spec = registered_strategies()[0]
        with pytest.raises(ValueError):
            register_strategy(spec)

    def test_third_party_strategy_registers_and_dispatches(self):
        key = "test-third-party"

        def planner(ns, nt, cores, method):
            return plan_hypercube(ns, nt, cores, method)

        register_strategy(StrategySpec(key=key, planner=planner, parallel=True,
                                       description="test"))
        try:
            engine = ReconfigEngine(strategy=key, cost_model=MN5)
            plan = engine.plan_expand(C, 4 * C, C)
            assert plan.spawn == plan_hypercube(C, 4 * C, C, Method.MERGE)
            assert plan.sync_graph is not None  # parallel spec gets the graph
        finally:
            _STRATEGY_REGISTRY.pop(key, None)

    def test_strategy_key_accepts_enum_and_string(self):
        assert strategy_key(Strategy.PARALLEL_HYPERCUBE) == "hypercube"
        assert strategy_key("hypercube") == "hypercube"


class TestTimeline:
    def test_events_are_contiguous_and_sum_to_total(self):
        plan = plan_hypercube(C, 16 * C, C, Method.MERGE)
        tl = expansion_timeline(plan, MN5)
        assert tl.events[0].start == 0.0
        for prev, nxt in zip(tl.events, tl.events[1:]):
            assert nxt.start == pytest.approx(prev.end)
        assert tl.total == pytest.approx(sum(e.duration for e in tl.events))

    def test_only_spawn_events_are_overlappable(self):
        plan = plan_hypercube(C, 16 * C, C, Method.BASELINE)
        tl = expansion_timeline(plan, MN5)
        for e in tl.events:
            assert e.overlappable == (e.stage is Stage.SPAWN)

    def test_async_downtime_is_total_minus_spawn(self):
        plan = plan_diffusive([20, 32, 20, 32], [20, 0, 0, 0], Method.MERGE)
        tl = expansion_timeline(plan, NASP)
        assert tl.downtime(asynchronous=False) == tl.total
        assert tl.downtime(asynchronous=True) == pytest.approx(
            tl.total - tl.span(Stage.SPAWN))

    def test_connect_round_count_matches_log2_groups(self):
        plan = plan_hypercube(C, 16 * C, C, Method.MERGE)
        rounds = [e for e in expansion_timeline(plan, MN5).events
                  if e.stage is Stage.CONNECT]
        import math
        assert len(rounds) == math.ceil(math.log2(len(plan.groups)))

    def test_classic_strategies_skip_parallel_stages(self):
        plan = plan_sequential(4, 16, [4, 4, 4, 4], Method.MERGE)
        tl = expansion_timeline(plan, MN5)
        assert tl.span(Stage.SYNC) == 0.0
        assert tl.span(Stage.CONNECT) == 0.0
        assert tl.span(Stage.REORDER) == 0.0
        assert tl.span(Stage.SPAWN) > 0.0

    def test_shrink_timelines_by_mechanism(self):
        ts = shrink_timeline(ShrinkKind.TS, MN5, doomed_world_sizes=[C] * 4)
        assert [e.stage for e in ts.events] == [Stage.TERMINATE]
        zs = shrink_timeline(ShrinkKind.ZS, MN5)
        assert [e.stage for e in zs.events] == [Stage.ZOMBIFY]
        rp = plan_hypercube(4 * C, C, C, Method.BASELINE)
        ss = shrink_timeline(ShrinkKind.SS, MN5, ns=4 * C, nt=C, respawn_plan=rp)
        stages = {e.stage for e in ss.events}
        assert Stage.TEARDOWN in stages and Stage.SPAWN in stages
        assert ts.total < zs.total * 100  # TS stays micro-scale
        assert ss.total > ts.total * 100  # SS respawn dwarfs TS


class TestDowntimeDedup:
    """Regression for the satellite: ExpansionReport.downtime (simulator)
    and ReconfigRecord.downtime_s (runtime) must agree exactly — both are
    reads of the same engine timeline, not independent arithmetic."""

    @pytest.mark.parametrize("asynchronous", [False, True])
    def test_expand_downtime_agrees(self, asynchronous):
        pool = DevicePool(devices=[object() for _ in range(8)], devices_per_node=1)
        rt = ElasticRuntime(pool=pool, initial_nodes=1, asynchronous=asynchronous)
        rec = rt.expand(8)
        plan = plan_hypercube(1, 8, 1, Method.MERGE)
        rep = simulate_expansion(plan, MN5, asynchronous=asynchronous)
        assert rec.est_wall_s == rep.total
        assert rec.downtime_s == rep.downtime
        if asynchronous:
            assert rec.downtime_s < rec.est_wall_s

    def test_shrink_downtime_agrees(self):
        pool = DevicePool(devices=[object() for _ in range(8)], devices_per_node=1)
        rt = ElasticRuntime(pool=pool, initial_nodes=1)
        rt.expand(8)
        rec = rt.shrink(5)
        rep = simulate_shrink(ShrinkKind.TS, MN5, ns=8, nt=3,
                              doomed_world_sizes=[1] * 5)
        assert rec.est_wall_s == rep.total
        assert rec.downtime_s == rep.total

    def test_expansion_report_phases_read_off_timeline(self):
        plan = plan_hypercube(C, 8 * C, C, Method.MERGE)
        rep = simulate_expansion(plan, MN5)
        tl = rep.timeline
        assert rep.t_spawn == tl.span(Stage.SPAWN)
        assert rep.t_sync == tl.span(Stage.SYNC)
        assert rep.t_connect == tl.span(Stage.CONNECT)
        assert rep.t_reorder == tl.span(Stage.REORDER)
        assert rep.t_final == tl.span(Stage.FINAL)
        assert rep.total == tl.total


class TestPartialOverlap:
    """The binary ASYNC flag is now the special case of the partial-
    overlap model: spawn_overlap=1, everything else 0, contention=1."""

    def test_defaults_reproduce_binary_async(self):
        plan = plan_hypercube(C, 16 * C, C, Method.MERGE)
        tl = expansion_timeline(plan, MN5)
        assert tl.downtime(asynchronous=True) == pytest.approx(
            tl.total - tl.span(Stage.SPAWN))

    def test_partial_spawn_overlap_hides_partially(self):
        plan = plan_hypercube(C, 16 * C, C, Method.MERGE)
        cm = MN5.with_overlap(spawn=0.5)
        tl = expansion_timeline(plan, cm)
        assert tl.downtime(asynchronous=True) == pytest.approx(
            tl.total - 0.5 * tl.span(Stage.SPAWN))

    def test_contention_degrades_hiding(self):
        plan = plan_hypercube(C, 16 * C, C, Method.MERGE)
        spawn = expansion_timeline(plan, MN5).span(Stage.SPAWN)
        for c, hidden_share in [(1.0, 1.0), (1.25, 0.75), (1.5, 0.5), (2.0, 0.0)]:
            tl = expansion_timeline(plan, MN5.with_overlap(contention=c))
            assert tl.downtime(asynchronous=True) == pytest.approx(
                tl.total - hidden_share * spawn), c
        # contention beyond 2 cannot make overlap WORSE than synchronous
        tl = expansion_timeline(plan, MN5.with_overlap(contention=3.0))
        assert tl.downtime(asynchronous=True) == pytest.approx(tl.total)

    def test_sync_and_connect_can_overlap_too(self):
        plan = plan_hypercube(C, 16 * C, C, Method.MERGE)
        cm = MN5.with_overlap(sync=1.0, connect=1.0)
        tl = expansion_timeline(plan, cm)
        assert tl.downtime(asynchronous=True) == pytest.approx(
            tl.total - tl.span(Stage.SPAWN) - tl.span(Stage.SYNC)
            - tl.span(Stage.CONNECT))

    def test_redistribution_overlap(self):
        plan = plan_hypercube(C, 4 * C, C, Method.MERGE)
        cm = MN5.with_overlap(redistribution=1.0)
        tl = expansion_timeline(plan, cm, bytes_total=10 ** 9)
        assert tl.span(Stage.REDISTRIBUTION) > 0
        assert tl.downtime(asynchronous=True) == pytest.approx(
            tl.total - tl.span(Stage.SPAWN) - tl.span(Stage.REDISTRIBUTION))

    def test_synchronous_downtime_ignores_overlap(self):
        plan = plan_hypercube(C, 8 * C, C, Method.MERGE)
        tl = expansion_timeline(plan, MN5.with_overlap(sync=1.0, contention=1.3))
        assert tl.downtime(asynchronous=False) == tl.total


class TestBytesCharging:
    """Stage-3 data movement is priced on the timeline end to end."""

    def test_expansion_timeline_charges_bytes(self):
        plan = plan_hypercube(C, 4 * C, C, Method.MERGE)
        base = expansion_timeline(plan, MN5)
        tl = expansion_timeline(plan, MN5, bytes_total=10 ** 10)
        assert tl.bytes_moved == 10 ** 10
        assert tl.total == pytest.approx(
            base.total + MN5.redist_alpha + 10 ** 10 / MN5.redist_bw)
        (ev,) = [e for e in tl.events if e.stage is Stage.REDISTRIBUTION]
        assert ev.bytes_moved == 10 ** 10

    def test_zero_bytes_adds_no_event(self):
        plan = plan_hypercube(C, 4 * C, C, Method.MERGE)
        tl = expansion_timeline(plan, MN5, bytes_total=0)
        assert tl.span(Stage.REDISTRIBUTION) == 0.0
        assert tl.bytes_moved == 0

    def test_shrink_timeline_charges_bytes(self):
        tl = shrink_timeline(ShrinkKind.TS, MN5, doomed_world_sizes=[C] * 4,
                             bytes_total=10 ** 9)
        assert tl.bytes_moved == 10 ** 9
        assert tl.span(Stage.REDISTRIBUTION) == pytest.approx(
            MN5.redist_alpha + 10 ** 9 / MN5.redist_bw)

    def test_engine_bytes_model_feeds_est_wall(self):
        calls = []

        def bm(ns, nt):
            calls.append((ns, nt))
            return 512 * abs(nt - ns)

        engine = ReconfigEngine(cost_model=MN5, bytes_model=bm)
        plan = engine.plan_expand(4, 16, 4)
        assert plan.redistribution.bytes_total == 512 * 12
        assert (4, 16) in calls
        out = engine.execute(plan)
        assert out.bytes_moved == 512 * 12
        base = ReconfigEngine(cost_model=MN5).execute(
            ReconfigEngine(cost_model=MN5).plan_expand(4, 16, 4))
        assert out.total_s > base.total_s

    def test_bytes_per_rank_fallback_now_connected(self):
        engine = ReconfigEngine(cost_model=MN5, bytes_per_rank=1024)
        plan = engine.plan_expand(4, 16, 4)
        assert plan.redistribution.bytes_total == 1024 * 12
        assert engine.execute(plan).bytes_moved == 1024 * 12

    def test_runtime_records_bytes_moved(self):
        pool = DevicePool(devices=[object() for _ in range(8)], devices_per_node=1)
        engine = ReconfigEngine(bytes_model=lambda ns, nt: 777 * abs(nt - ns))
        rt = ElasticRuntime(pool=pool, initial_nodes=1, engine=engine)
        rec = rt.expand(8)
        assert rec.bytes_moved == 777 * 7
        rep = simulate_expansion(plan_hypercube(1, 8, 1, Method.MERGE), MN5,
                                 bytes_total=777 * 7)
        assert rec.est_wall_s == rep.total
        assert rep.bytes_moved == 777 * 7
        shrink_rec = rt.shrink(4)
        assert shrink_rec.bytes_moved == 777 * 4


class TestPerLinkPricing:
    """redist_bw_local / redist_bw_cross split the aggregate bandwidth:
    bytes_stayed go over the local link, bytes_moved over the cross one."""

    def test_default_model_is_bitwise_the_old_aggregate(self):
        # local == cross == redist_bw and stayed == 0 (what every
        # moved-bytes-only model reports) is exactly the old charge
        assert MN5.bw_local == MN5.bw_cross == MN5.redist_bw
        for b in (1, 10 ** 6, 10 ** 10):
            assert MN5.redistribution(b) == MN5.redist_alpha + b / MN5.redist_bw
        assert MN5.redistribution(0) == 0.0

    def test_stayed_bytes_priced_on_the_local_link(self):
        cm = MN5.with_link_bandwidths(local=50.0e9, cross=5.0e9)
        assert cm.redistribution(10 ** 9, 10 ** 9) == pytest.approx(
            cm.redist_alpha + 10 ** 9 / 50.0e9 + 10 ** 9 / 5.0e9)
        # stayed-only traffic still creates an event (local re-validation)
        assert cm.redistribution(0, 10 ** 9) == pytest.approx(
            cm.redist_alpha + 10 ** 9 / 50.0e9)

    def test_scaled_profile_scales_split_bandwidths(self):
        cm = MN5.with_link_bandwidths(local=40.0e9, cross=4.0e9).scaled(4.0)
        assert cm.bw_local == pytest.approx(10.0e9)
        assert cm.bw_cross == pytest.approx(1.0e9)
        # unsplit models stay unsplit through scaled()
        assert MN5.scaled(4.0).redist_bw_local is None

    def test_dict_bytes_model_flows_into_timeline_event(self):
        engine = ReconfigEngine(
            cost_model=MN5.with_link_bandwidths(local=100.0e9),
            bytes_model=lambda ns, nt: {"bytes_stayed": 3 * 10 ** 9,
                                        "bytes_moved": 10 ** 9},
        )
        plan = engine.plan_expand(4, 16, 4)
        assert plan.redistribution.bytes_total == 10 ** 9
        assert plan.redistribution.bytes_stayed == 3 * 10 ** 9
        out = engine.execute(plan)
        assert out.bytes_moved == 10 ** 9
        assert out.bytes_stayed == 3 * 10 ** 9
        (ev,) = [e for e in out.timeline.events
                 if e.stage is Stage.REDISTRIBUTION]
        assert (ev.bytes_moved, ev.bytes_stayed) == (10 ** 9, 3 * 10 ** 9)
        assert ev.duration == pytest.approx(
            MN5.redist_alpha + 3 * 10 ** 9 / 100.0e9 + 10 ** 9 / MN5.redist_bw)

    def test_stats_attribute_preferred_over_call(self):
        class Model:
            def __call__(self, ns, nt):
                raise AssertionError("stats() should be consulted first")

            def stats(self, ns, nt):
                return {"bytes_stayed": 7, "bytes_moved": 11}

        engine = ReconfigEngine(cost_model=MN5, bytes_model=Model())
        assert engine.redistribution_stats(1, 4) == (7, 11)
        assert engine.redistribution_bytes(1, 4) == 11

    def test_replicated_link_model_shapes(self):
        from repro.malleability import replicated_link_model

        m = replicated_link_model(1000)
        assert m(2, 6) == {"bytes_stayed": 2000, "bytes_moved": 4000}
        assert m(6, 3) == {"bytes_stayed": 3000, "bytes_moved": 0}
        assert m(4, 4) == {"bytes_stayed": 0, "bytes_moved": 0}
        assert m(0, 4) == {"bytes_stayed": 0, "bytes_moved": 0}

    def test_shrink_timeline_charges_stayed_bytes(self):
        cm = MN5.with_link_bandwidths(local=20.0e9)
        tl = shrink_timeline(ShrinkKind.TS, cm, doomed_world_sizes=[C],
                             bytes_total=0, bytes_stayed=10 ** 9)
        assert tl.bytes_stayed == 10 ** 9 and tl.bytes_moved == 0
        assert tl.span(Stage.REDISTRIBUTION) == pytest.approx(
            cm.redist_alpha + 10 ** 9 / 20.0e9)


class TestEnginePlanning:
    def test_plan_shrink_captures_doomed_sizes(self):
        pool = DevicePool(devices=[object() for _ in range(6)], devices_per_node=1)
        rt = ElasticRuntime(pool=pool, initial_nodes=1)
        rt.expand(6)
        victims = sorted(rt.state.nodes_in_use())[-2:]
        plan = rt.engine.plan_shrink(rt.state, release_nodes=victims)
        assert plan.kind == "shrink"
        assert plan.shrink_world_sizes == (1, 1)
        assert plan.ns == 6 and plan.nt == 4

    def test_plan_expand_via_string_key(self):
        engine = ReconfigEngine(cost_model=MN5)
        plan = engine.plan_expand(C, 4 * C, C, strategy="diffusive")
        assert plan.spawn.strategy is Strategy.PARALLEL_DIFFUSIVE

    def test_engine_default_cost_model_is_mn5(self):
        assert ReconfigEngine().cost_model is MN5
