"""Uneven DevicePool edge cases: width-vector validation, exhaustion
under uneven allocation, whole-node release on shrink, and RMS policy
grants clamping against an uneven pool."""
import pytest

from repro.core import Strategy
from repro.elastic import DevicePool, ElasticRuntime
from repro.elastic.rms import SimulatedRMS
from repro.malleability.policies import (
    BackfillPolicy,
    ClusterState,
    JobSpec,
)


def uneven_pool(widths=(2, 1, 2, 1), extra=0):
    devs = [object() for _ in range(sum(widths) + extra)]
    return DevicePool(devices=devs, node_widths=widths)


class TestUnevenPartition:
    def test_widths_partition_in_pool_order(self):
        devs = [object() for _ in range(6)]
        pool = DevicePool(devices=devs, node_widths=(2, 1, 3))
        assert pool.node_widths == (2, 1, 3)
        assert pool.nodes[0] == tuple(devs[0:2])
        assert pool.nodes[1] == tuple(devs[2:3])
        assert pool.nodes[2] == tuple(devs[3:6])
        assert pool.width(0) == 2 and pool.width(2) == 3
        assert not pool.uniform
        assert pool.total_devices() == 6

    def test_width_vector_device_count_mismatch_raises(self):
        with pytest.raises(ValueError, match="needs 7 devices"):
            DevicePool(devices=[object()] * 6, node_widths=(2, 2, 3))

    def test_extra_devices_are_ignored(self):
        pool = uneven_pool(widths=(2, 1), extra=3)
        assert pool.n_nodes == 2 and pool.total_devices() == 3

    def test_invalid_widths_raise(self):
        with pytest.raises(ValueError):
            DevicePool(devices=[object()] * 4, node_widths=())
        with pytest.raises(ValueError):
            DevicePool(devices=[object()] * 4, node_widths=(2, 0))
        with pytest.raises(ValueError):
            DevicePool(devices=[object()] * 4, node_widths=(2,),
                       devices_per_node=2)

    def test_devices_per_node_undefined_when_uneven(self):
        pool = uneven_pool()
        with pytest.raises(ValueError, match="uneven"):
            pool.devices_per_node
        # a width vector that HAPPENS to be uniform keeps the accessor
        assert DevicePool(devices=[object()] * 4,
                          node_widths=(2, 2)).devices_per_node == 2


class TestUnevenRuntime:
    def make_runtime(self, widths=(2, 1, 2, 1)):
        return ElasticRuntime(pool=uneven_pool(widths),
                              strategy=Strategy.PARALLEL_DIFFUSIVE,
                              initial_nodes=1)

    def test_expand_allocates_uneven_widths(self):
        rt = self.make_runtime()
        assert rt.ranks_in_use() == 2          # node 0 is 2 wide
        rec = rt.expand(4)
        assert rec.mechanism == "diffusive"
        assert rt.n_nodes == 4
        assert rt.ranks_in_use() == 6          # 2+1+2+1
        # every world is node-confined and matches its node's width
        for w in rt.state.worlds.values():
            assert len(w.nodes) == 1
            assert w.size == rt.pool.width(w.nodes[0])

    def test_shrink_returns_whole_uneven_nodes(self):
        rt = self.make_runtime()
        rt.expand(4)
        rec = rt.shrink_nodes([2, 3])
        assert rec.mechanism == "termination_shrinkage"
        assert rec.nodes_returned == (2, 3)
        assert rt.pool.free == {2, 3}
        # the freed nodes still own their complete (uneven) device sets
        assert len(rt.pool.nodes[2]) == 2 and len(rt.pool.nodes[3]) == 1
        assert rt.ranks_in_use() == 3

    def test_exhaustion_under_uneven_allocation(self):
        rt = self.make_runtime(widths=(2, 1))
        with pytest.raises(RuntimeError, match="exhausted"):
            rt.expand(5)
        # the failed expand must not have leaked any acquisitions
        assert rt.pool.free == {1}

    def test_homogeneous_only_strategy_rejected_on_uneven_pool(self):
        rt = ElasticRuntime(pool=uneven_pool(), initial_nodes=1)  # hypercube
        with pytest.raises(ValueError, match="PARALLEL_DIFFUSIVE"):
            rt.expand(4)

    def test_regrow_reuses_lowest_freed_node(self):
        rt = self.make_runtime()
        rt.expand(4)
        rt.shrink_nodes([1, 2])
        rec = rt.expand(3)
        assert rec.nodes_after == 3
        assert sorted(rt.state.nodes_in_use()) == [0, 1, 3]
        assert rt.ranks_in_use() == 2 + 1 + 1


class TestPolicyOverUnevenPool:
    def test_from_policy_grants_clamp_against_uneven_pool(self):
        """RMS grants are node-counted: an uneven DevicePool clamps a
        greedy job to its node count, and the granted trace replays on
        the SAME uneven pool through the live runtime."""
        pool = uneven_pool(widths=(2, 1, 2, 1))
        cluster = ClusterState.from_pool(
            pool, jobs=(JobSpec("train", min_nodes=1, max_nodes=99),))
        assert cluster.total_nodes == 4
        policy = BackfillPolicy()
        trace = policy.generate(cluster)
        sc = trace.scenario("train")
        peak = sc.max_nodes()
        assert peak <= pool.n_nodes      # clamped to the uneven pool
        rms = SimulatedRMS.from_policy(policy, cluster)
        rt = ElasticRuntime(pool=pool,
                            strategy=Strategy.PARALLEL_DIFFUSIVE,
                            initial_nodes=sc.initial_nodes)
        for ev in rms.events_until(10 ** 9):
            if ev.kind.value == "grow" and ev.target_nodes > rt.n_nodes:
                rt.expand(ev.target_nodes)
            elif ev.kind.value == "shrink":
                victims = [n for n in ev.nodes
                           if n in rt.state.nodes_in_use()]
                if victims:
                    rt.shrink_nodes(victims)
        assert rt.n_nodes <= pool.n_nodes
