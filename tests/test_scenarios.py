"""Scenario subsystem tests: declarative traces run end-to-end through
both the timeline-charging simulator and the live NodeGroup runtime with
identical timeline-derived downtime numbers, and through the full
ElasticTrainer loop (slow)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.elastic.rms import EventKind, SimulatedRMS
from repro.malleability import (
    Scenario,
    ScenarioEvent,
    get_scenario,
    register_scenario,
    registered_scenarios,
    run_scenario_live,
    run_scenario_sim,
    steady_cycle,
)

DUAL_PATH = ["steady-cycle", "burst-arrival", "node-failures", "straggler-churn"]
HETERO = ["hetero-nasp", "hetero-redist"]
TOPO = ["topo-nasp", "topo-redist"]


# The canonical parity tuple (shared with the example's agreement gate).
from repro.malleability import record_parity_key as _key  # noqa: E402


class TestSimLiveAgreement:
    """Acceptance: >= 4 declarative scenarios through both executors with
    identical timeline-derived downtime numbers (exact float equality —
    both paths charge the same engine timeline)."""

    @pytest.mark.parametrize("name", DUAL_PATH + HETERO + TOPO)
    def test_downtimes_identical(self, name):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert len(sim) >= 2, "scenario must actually reconfigure"
        assert [_key(r) for r in sim] == [_key(r) for r in live]

    @pytest.mark.parametrize("name", DUAL_PATH)
    def test_async_engine_agrees_too(self, name):
        sc = get_scenario(name)
        engine = sc.default_engine()
        engine.asynchronous = True
        sim = run_scenario_sim(sc, engine=engine)
        engine2 = sc.default_engine()
        engine2.asynchronous = True
        live = run_scenario_live(sc, engine=engine2)
        assert [_key(r) for r in sim] == [_key(r) for r in live]
        # ASYNC hides spawn on expansions
        for r in sim:
            if r.kind == "expand":
                assert r.downtime_s < r.est_wall_s


class TestScenarioStructure:
    def test_registry_has_the_builtin_five(self):
        names = {s.name for s in registered_scenarios()}
        assert set(DUAL_PATH) <= names
        assert "hetero-nasp" in names

    def test_heterogeneous_runs_both_executors(self):
        """hetero-nasp is no longer simulator-only: the live DevicePool
        partitions with the uneven width vector and agrees per event."""
        sc = get_scenario("hetero-nasp")
        assert sc.heterogeneous
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert [_key(r) for r in sim] == [_key(r) for r in live]
        assert any(r.mechanism == "diffusive" for r in sim)
        assert any(r.mechanism == "termination_shrinkage" for r in sim)

    def test_hetero_shrink_returns_whole_uneven_nodes(self):
        """The paper's headline property on an uneven pool: a TS shrink
        hands COMPLETE nodes back, whatever their width."""
        from repro.malleability import scenario_pool

        sc = get_scenario("hetero-nasp")
        pool = scenario_pool(sc)
        run_scenario_live(sc, pool=pool)
        # trace ends at 7 of 8 nodes -> exactly one node is free again,
        # and every free node still owns its full width of devices
        assert len(pool.free) == 1
        for node in pool.free:
            assert len(pool.nodes[node]) == sc.core_pool[node]

    def test_mismatched_explicit_pool_rejected(self):
        """A caller-supplied pool whose widths disagree with the trace
        would silently break sim==live parity — it must raise instead."""
        from repro.elastic import DevicePool

        sc = get_scenario("hetero-nasp")
        uniform = DevicePool(devices=[object()] * sc.max_nodes(),
                             devices_per_node=1)
        with pytest.raises(ValueError, match="widths"):
            run_scenario_live(sc, pool=uniform)
        # homogeneous traces are guarded too
        wide = DevicePool(devices=[object()] * 16, devices_per_node=2)
        with pytest.raises(ValueError, match="widths"):
            run_scenario_live(get_scenario("steady-cycle"), pool=wide)

    def test_duplicate_registration_raises(self):
        sc = registered_scenarios()[0]
        with pytest.raises(ValueError):
            register_scenario(sc)

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError):
            get_scenario("no-such-trace")

    def test_max_nodes_tracks_peak(self):
        sc = Scenario(
            name="tmp", description="", initial_nodes=2,
            events=(
                ScenarioEvent(step=1, kind="grow", target_nodes=6),
                ScenarioEvent(step=2, kind="shrink", nodes=(4, 5)),
                ScenarioEvent(step=3, kind="grow", target_nodes=5),
            ),
        )
        assert sc.max_nodes() == 6

    def test_shrink_events_return_to_low_watermark(self):
        recs = run_scenario_sim(steady_cycle(name="tmp-cycle", low=2, high=5))
        assert recs[0].nodes_before == 2 and recs[0].nodes_after == 5
        assert recs[-1].nodes_after == 2

    def test_ts_is_orders_of_magnitude_cheaper_than_expand(self):
        """The paper's headline, visible in every scenario trace."""
        recs = run_scenario_sim(get_scenario("steady-cycle"))
        expands = [r.est_wall_s for r in recs if r.kind == "expand"]
        shrinks = [r.est_wall_s for r in recs if r.kind == "shrink"]
        assert min(expands) / max(shrinks) > 100


class TestRedistributionAware:
    """Stage-3 data movement flows from the model config into est_wall,
    identically in both executors (the PR-2 acceptance criteria)."""

    def test_registered_redist_scenario_charges_bytes(self):
        sc = get_scenario("redist-cycle")
        recs = run_scenario_sim(sc)
        expands = [r for r in recs if r.kind == "expand"]
        assert expands and all(r.bytes_moved > 0 for r in expands)
        # redistribution dominates: the same trace without a pytree is
        # several times cheaper (stage 3 is the bulk of est_wall)
        plain = run_scenario_sim(get_scenario("steady-cycle"))
        assert expands[0].est_wall_s > 5 * plain[0].est_wall_s

    def test_est_wall_changes_with_model_config_only(self):
        sc = get_scenario("redist-cycle")
        small = run_scenario_sim(sc.with_model(arch="xlstm_125m"))
        large = run_scenario_sim(sc.with_model(arch="stablelm_3b"))
        assert [r.step for r in small] == [r.step for r in large]
        for s, l in zip(small, large):
            if s.kind == "expand":
                assert s.bytes_moved < l.bytes_moved
                assert s.est_wall_s < l.est_wall_s

    def test_param_bytes_override_beats_arch(self):
        sc = get_scenario("redist-cycle").with_model(param_bytes=10 ** 6)
        recs = run_scenario_sim(sc)
        grow = next(r for r in recs if r.kind == "expand")
        # replicated model: one full copy per new rank (1 -> 4 nodes)
        assert grow.bytes_moved == 3 * 10 ** 6

    def test_bytes_agree_sim_vs_live(self):
        sc = get_scenario("redist-cycle")
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert [_key(r) for r in sim] == [_key(r) for r in live]
        assert any(r.bytes_moved > 0 for r in sim)


class TestPerLinkRedistribution:
    """Stage-3 pricing split per link: bytes_stayed charged against
    redist_bw_local, bytes_moved against redist_bw_cross."""

    def test_hetero_redist_charges_both_link_classes(self):
        recs = run_scenario_sim(get_scenario("hetero-redist"))
        expands = [r for r in recs if r.kind == "expand"]
        assert expands and all(r.bytes_moved > 0 for r in expands)
        assert all(r.bytes_stayed > 0 for r in expands)
        # the shrink leaves survivor replicas in place: local link only
        shrink = next(r for r in recs if r.kind == "shrink")
        assert shrink.bytes_moved == 0 and shrink.bytes_stayed > 0

    def test_link_bandwidths_change_est_wall(self):
        sc = get_scenario("hetero-redist")
        from dataclasses import replace

        slow_cross = replace(sc, name="tmp-slow-cross",
                             redist_bw_cross=sc.redist_bw_cross / 10)
        base = run_scenario_sim(sc)
        slow = run_scenario_sim(slow_cross)
        for b, s in zip(base, slow):
            assert (b.bytes_moved, b.bytes_stayed) == (s.bytes_moved,
                                                       s.bytes_stayed)
            if b.bytes_moved > 0:
                assert s.est_wall_s > b.est_wall_s

    def test_aggregate_traces_reproduce_single_bandwidth_numbers(self):
        """A trace without split bandwidths keeps the moved-only model:
        bytes_stayed stays 0 and est_wall is the pre-split aggregate
        charge, bit for bit."""
        from repro.malleability import MN5

        sc = get_scenario("redist-cycle")
        assert not sc.link_aware
        recs = run_scenario_sim(sc)
        grow = next(r for r in recs if r.kind == "expand")
        assert grow.bytes_stayed == 0
        plain = run_scenario_sim(get_scenario("steady-cycle"))
        base = next(r for r in plain if r.kind == "expand")
        assert grow.est_wall_s == base.est_wall_s + MN5.redist_alpha + (
            grow.bytes_moved / MN5.redist_bw)


class TestRMSBridge:
    def test_from_scenario_preserves_trace(self):
        sc = get_scenario("node-failures")
        rms = SimulatedRMS.from_scenario(sc)
        evs = list(rms.events_until(10**9))
        assert [e.step for e in evs] == sorted(e.step for e in sc.events)
        kinds = [e.kind for e in evs]
        assert kinds[0] is EventKind.GROW
        assert EventKind.FAIL in kinds


TRAINER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.configs import smoke_config
    from repro.elastic import ElasticTrainer
    from repro.malleability import (
        get_scenario, heterogeneous_pool, run_scenario_sim,
    )
    from repro.models import Model

    model = Model(smoke_config("stablelm_3b"))

    def run_one(name, sc, batch):
        sim = run_scenario_sim(sc)
        tr = ElasticTrainer.from_scenario(model, sc, batch=batch, seq=32)
        hist = tr.run(sc.steps)
        live = tr.runtime.history
        assert len(live) == len(sim), (name, len(live), len(sim))
        for s, l in zip(sim, live):
            assert l.downtime_s == s.downtime_s, (name, s, l)
            assert l.est_wall_s == s.est_wall_s, (name, s, l)
            assert l.queued_s == s.queued_s, (name, s, l)
            assert (l.bytes_moved, l.bytes_stayed) == (
                s.bytes_moved, s.bytes_stayed), (name, s, l)
            assert l.bytes_cross_rack == s.bytes_cross_rack, (name, s, l)
            assert (l.nodes_before, l.nodes_after) == (
                s.nodes_before, s.nodes_after), (name, s, l)
        losses = np.array(tr.losses())
        assert np.isfinite(losses).all(), name
        print("SCENARIO_TRAINER_OK", name, len(live), "reconfigs")

    for name in ("steady-cycle", "burst-arrival", "node-failures",
                 "straggler-churn"):
        run_one(name, get_scenario(name), batch=8)

    # Heterogeneous uneven-width pools through the FULL trainer loop:
    # the registered hetero-redist trace (pool (2,1,2,1), per-link
    # priced pytree), plus a width-scaled hetero-nasp built by the same
    # builder (the paper trace's 20/32-wide nodes need 208 host
    # devices; (2,1) preserves the trace shape on 6).  Node counts
    # along both traces are 2/6/3/5 ranks -> batch 30 shards cleanly.
    run_one("hetero-redist", get_scenario("hetero-redist"), batch=30)
    run_one("hetero-nasp-small",
            heterogeneous_pool(name="hetero-nasp-small", nodes=4,
                               widths=(2, 1)), batch=30)

    # Topology-aware traces: the topo strategy's rack-vacating shrink
    # and rack-local regrow run through the full trainer with exact
    # per-event parity, distance-class bytes included (rank counts
    # 2/8/2/4 -> batch 8 shards cleanly on the 8 host devices).
    run_one("topo-nasp", get_scenario("topo-nasp"), batch=8)
    run_one("topo-redist", get_scenario("topo-redist"), batch=8)
""")


@pytest.mark.slow
def test_trainer_loop_matches_simulator_downtime():
    """Full ElasticTrainer loop on every dual-path scenario — the
    heterogeneous uneven-width and rack-topology traces included: its
    runtime history must carry exactly the simulator's timeline-derived
    downtimes, queue spans, and per-distance-class bytes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", TRAINER_SCRIPT], capture_output=True, text=True,
        timeout=1800, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    for name in DUAL_PATH + ["hetero-redist", "hetero-nasp-small",
                             "topo-nasp", "topo-redist"]:
        assert f"SCENARIO_TRAINER_OK {name}" in proc.stdout


BYTES_AGREEMENT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.configs import smoke_config
    from repro.elastic import ElasticTrainer, PytreeBytesModel
    from repro.malleability import get_scenario, run_scenario_sim
    from repro.models import Model

    model = Model(smoke_config("stablelm_3b"))

    # One-event-per-step scenarios: the trainer's single reshard per
    # drained step covers exactly one engine-charged event, so the
    # measured bytes must equal the charged/simulated bytes EXACTLY —
    # per link: bytes_moved AND bytes_stayed.  hetero-redist runs the
    # same gate over an uneven (2,1,2,1) pool with split bandwidths.
    for name, batch in (("steady-cycle", 8), ("burst-arrival", 8),
                        ("hetero-redist", 30)):
        sc = get_scenario(name)
        engine = sc.default_engine()
        engine.bytes_model = PytreeBytesModel(model)
        sim = run_scenario_sim(sc, engine=engine)

        engine_live = sc.default_engine()
        engine_live.bytes_model = PytreeBytesModel(model)
        tr = ElasticTrainer.from_scenario(model, sc, engine=engine_live,
                                          batch=batch, seq=32)
        tr.run(sc.steps)
        live = tr.runtime.history
        assert len(live) == len(sim) == len(tr.transfer_log), name
        moved_any = False
        for s, l, t in zip(sim, live, tr.transfer_log):
            # simulator == live-charged == live-MEASURED, byte for byte
            assert s.bytes_moved == l.bytes_moved, (name, s, l)
            assert s.bytes_stayed == l.bytes_stayed, (name, s, l)
            assert t["charged_bytes_moved"] == s.bytes_moved, (name, s, t)
            assert t["bytes_moved"] == s.bytes_moved, (name, s, t)
            assert t["bytes_stayed"] == s.bytes_stayed, (name, s, t)
            assert s.est_wall_s == l.est_wall_s, (name, s, l)
            moved_any |= s.bytes_moved > 0
        assert moved_any, name
        print("BYTES_AGREEMENT_OK", name, len(live), "events")
""")


@pytest.mark.slow
def test_simulated_bytes_equal_measured_bytes_exactly():
    """Acceptance: the simulator's per-event bytes_moved AND bytes_stayed
    equal the live runtime's *measured* transfer_stats values exactly,
    per scenario (uneven pools included), when both charge through
    PytreeBytesModel."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", BYTES_AGREEMENT_SCRIPT], capture_output=True,
        text=True, timeout=1800, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    for name in ("steady-cycle", "burst-arrival", "hetero-redist"):
        assert f"BYTES_AGREEMENT_OK {name}" in proc.stdout
