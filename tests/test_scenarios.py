"""Scenario subsystem tests: declarative traces run end-to-end through
both the timeline-charging simulator and the live NodeGroup runtime with
identical timeline-derived downtime numbers, and through the full
ElasticTrainer loop (slow)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.elastic.rms import EventKind, SimulatedRMS
from repro.malleability import (
    Scenario,
    ScenarioEvent,
    get_scenario,
    register_scenario,
    registered_scenarios,
    run_scenario_live,
    run_scenario_sim,
    steady_cycle,
)

DUAL_PATH = ["steady-cycle", "burst-arrival", "node-failures", "straggler-churn"]


def _key(rec):
    return (rec.step, rec.kind, rec.mechanism, rec.nodes_before,
            rec.nodes_after, rec.est_wall_s, rec.downtime_s, rec.bytes_moved)


class TestSimLiveAgreement:
    """Acceptance: >= 4 declarative scenarios through both executors with
    identical timeline-derived downtime numbers (exact float equality —
    both paths charge the same engine timeline)."""

    @pytest.mark.parametrize("name", DUAL_PATH)
    def test_downtimes_identical(self, name):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert len(sim) >= 2, "scenario must actually reconfigure"
        assert [_key(r) for r in sim] == [_key(r) for r in live]

    @pytest.mark.parametrize("name", DUAL_PATH)
    def test_async_engine_agrees_too(self, name):
        sc = get_scenario(name)
        engine = sc.default_engine()
        engine.asynchronous = True
        sim = run_scenario_sim(sc, engine=engine)
        engine2 = sc.default_engine()
        engine2.asynchronous = True
        live = run_scenario_live(sc, engine=engine2)
        assert [_key(r) for r in sim] == [_key(r) for r in live]
        # ASYNC hides spawn on expansions
        for r in sim:
            if r.kind == "expand":
                assert r.downtime_s < r.est_wall_s


class TestScenarioStructure:
    def test_registry_has_the_builtin_five(self):
        names = {s.name for s in registered_scenarios()}
        assert set(DUAL_PATH) <= names
        assert "hetero-nasp" in names

    def test_heterogeneous_is_sim_only(self):
        sc = get_scenario("hetero-nasp")
        assert sc.sim_only
        with pytest.raises(ValueError):
            run_scenario_live(sc)
        recs = run_scenario_sim(sc)
        assert any(r.mechanism == "diffusive" for r in recs)
        assert any(r.mechanism == "termination_shrinkage" for r in recs)

    def test_duplicate_registration_raises(self):
        sc = registered_scenarios()[0]
        with pytest.raises(ValueError):
            register_scenario(sc)

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError):
            get_scenario("no-such-trace")

    def test_max_nodes_tracks_peak(self):
        sc = Scenario(
            name="tmp", description="", initial_nodes=2,
            events=(
                ScenarioEvent(step=1, kind="grow", target_nodes=6),
                ScenarioEvent(step=2, kind="shrink", nodes=(4, 5)),
                ScenarioEvent(step=3, kind="grow", target_nodes=5),
            ),
        )
        assert sc.max_nodes() == 6

    def test_shrink_events_return_to_low_watermark(self):
        recs = run_scenario_sim(steady_cycle(name="tmp-cycle", low=2, high=5))
        assert recs[0].nodes_before == 2 and recs[0].nodes_after == 5
        assert recs[-1].nodes_after == 2

    def test_ts_is_orders_of_magnitude_cheaper_than_expand(self):
        """The paper's headline, visible in every scenario trace."""
        recs = run_scenario_sim(get_scenario("steady-cycle"))
        expands = [r.est_wall_s for r in recs if r.kind == "expand"]
        shrinks = [r.est_wall_s for r in recs if r.kind == "shrink"]
        assert min(expands) / max(shrinks) > 100


class TestRedistributionAware:
    """Stage-3 data movement flows from the model config into est_wall,
    identically in both executors (the PR-2 acceptance criteria)."""

    def test_registered_redist_scenario_charges_bytes(self):
        sc = get_scenario("redist-cycle")
        recs = run_scenario_sim(sc)
        expands = [r for r in recs if r.kind == "expand"]
        assert expands and all(r.bytes_moved > 0 for r in expands)
        # redistribution dominates: the same trace without a pytree is
        # several times cheaper (stage 3 is the bulk of est_wall)
        plain = run_scenario_sim(get_scenario("steady-cycle"))
        assert expands[0].est_wall_s > 5 * plain[0].est_wall_s

    def test_est_wall_changes_with_model_config_only(self):
        sc = get_scenario("redist-cycle")
        small = run_scenario_sim(sc.with_model(arch="xlstm_125m"))
        large = run_scenario_sim(sc.with_model(arch="stablelm_3b"))
        assert [r.step for r in small] == [r.step for r in large]
        for s, l in zip(small, large):
            if s.kind == "expand":
                assert s.bytes_moved < l.bytes_moved
                assert s.est_wall_s < l.est_wall_s

    def test_param_bytes_override_beats_arch(self):
        sc = get_scenario("redist-cycle").with_model(param_bytes=10 ** 6)
        recs = run_scenario_sim(sc)
        grow = next(r for r in recs if r.kind == "expand")
        # replicated model: one full copy per new rank (1 -> 4 nodes)
        assert grow.bytes_moved == 3 * 10 ** 6

    def test_bytes_agree_sim_vs_live(self):
        sc = get_scenario("redist-cycle")
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert [_key(r) for r in sim] == [_key(r) for r in live]
        assert any(r.bytes_moved > 0 for r in sim)


class TestRMSBridge:
    def test_from_scenario_preserves_trace(self):
        sc = get_scenario("node-failures")
        rms = SimulatedRMS.from_scenario(sc)
        evs = list(rms.events_until(10**9))
        assert [e.step for e in evs] == sorted(e.step for e in sc.events)
        kinds = [e.kind for e in evs]
        assert kinds[0] is EventKind.GROW
        assert EventKind.FAIL in kinds


TRAINER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.configs import smoke_config
    from repro.elastic import ElasticTrainer
    from repro.malleability import get_scenario, run_scenario_sim
    from repro.models import Model

    model = Model(smoke_config("stablelm_3b"))
    for name in ("steady-cycle", "burst-arrival", "node-failures",
                 "straggler-churn"):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        tr = ElasticTrainer.from_scenario(model, sc, batch=8, seq=32)
        hist = tr.run(sc.steps)
        live = tr.runtime.history
        assert len(live) == len(sim), (name, len(live), len(sim))
        for s, l in zip(sim, live):
            assert l.downtime_s == s.downtime_s, (name, s, l)
            assert l.est_wall_s == s.est_wall_s, (name, s, l)
            assert (l.nodes_before, l.nodes_after) == (
                s.nodes_before, s.nodes_after), (name, s, l)
        losses = np.array(tr.losses())
        assert np.isfinite(losses).all(), name
        print("SCENARIO_TRAINER_OK", name, len(live), "reconfigs")
""")


@pytest.mark.slow
def test_trainer_loop_matches_simulator_downtime():
    """Full ElasticTrainer loop on every dual-path scenario: its runtime
    history must carry exactly the simulator's timeline-derived downtimes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", TRAINER_SCRIPT], capture_output=True, text=True,
        timeout=1200, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    for name in DUAL_PATH:
        assert f"SCENARIO_TRAINER_OK {name}" in proc.stdout


BYTES_AGREEMENT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.configs import smoke_config
    from repro.elastic import ElasticTrainer, PytreeBytesModel
    from repro.malleability import get_scenario, run_scenario_sim
    from repro.models import Model

    model = Model(smoke_config("stablelm_3b"))

    # One-event-per-step scenarios: the trainer's single reshard per
    # drained step covers exactly one engine-charged event, so the
    # measured bytes must equal the charged/simulated bytes EXACTLY.
    for name in ("steady-cycle", "burst-arrival"):
        sc = get_scenario(name)
        engine = sc.default_engine()
        engine.bytes_model = PytreeBytesModel(model)
        sim = run_scenario_sim(sc, engine=engine)

        engine_live = sc.default_engine()
        engine_live.bytes_model = PytreeBytesModel(model)
        tr = ElasticTrainer.from_scenario(model, sc, engine=engine_live,
                                          batch=8, seq=32)
        tr.run(sc.steps)
        live = tr.runtime.history
        assert len(live) == len(sim) == len(tr.transfer_log), name
        moved_any = False
        for s, l, t in zip(sim, live, tr.transfer_log):
            # simulator == live-charged == live-MEASURED, byte for byte
            assert s.bytes_moved == l.bytes_moved, (name, s, l)
            assert t["charged_bytes_moved"] == s.bytes_moved, (name, s, t)
            assert t["bytes_moved"] == s.bytes_moved, (name, s, t)
            assert s.est_wall_s == l.est_wall_s, (name, s, l)
            moved_any |= s.bytes_moved > 0
        assert moved_any, name
        print("BYTES_AGREEMENT_OK", name, len(live), "events")
""")


@pytest.mark.slow
def test_simulated_bytes_equal_measured_bytes_exactly():
    """Acceptance: the simulator's per-event bytes_moved equals the live
    runtime's *measured* transfer_stats value exactly, per scenario, when
    both charge through PytreeBytesModel."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", BYTES_AGREEMENT_SCRIPT], capture_output=True,
        text=True, timeout=1200, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    for name in ("steady-cycle", "burst-arrival"):
        assert f"BYTES_AGREEMENT_OK {name}" in proc.stdout
