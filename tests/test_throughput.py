"""Throughput model tests: width-weighted batch shares, step-time
monotonicity, executor accrual parity on every registered scenario,
the objective swap's bit-for-bit off-switch, and the modeled
checkpoint cadence."""
import os
import sys
from dataclasses import replace

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.malleability import (
    ThroughputModel,
    batch_shares,
    evaluate_schedule,
    get_scenario,
    optimize_schedule,
    registered_scenarios,
    run_scenario_live,
    run_scenario_sim,
    run_scenario_vectorized,
    time_to_result,
)
from repro.malleability.optimizer import WORKLOAD_TRACES, SchedulerKnobs
from repro.malleability.policies import (
    CheckpointIntervalPolicy,
    ClusterState,
    JobSpec,
)
from repro.malleability.scenarios import record_parity_key

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "benchmarks"))
from paper_tables import (  # noqa: E402
    SCHED_SMOKE_GRID,
    SCHED_SMOKE_RANDOM,
    THRPT_MODEL_UNEVEN,
)

#: Device-free constants (no arch lookup -> no jax): a 250M-param fp32
#: model at the default train_4k shape.
MODEL = ThroughputModel(flops_per_token=1.5e9, param_bytes=10**9)

widths_lists = st.lists(st.integers(min_value=1, max_value=16),
                        min_size=1, max_size=40)


class TestBatchShares:
    @given(gb=st.integers(min_value=0, max_value=4096), widths=widths_lists)
    @settings(max_examples=100)
    def test_shares_sum_exactly_to_global_batch(self, gb, widths):
        shares = batch_shares(gb, widths)
        assert len(shares) == len(widths)
        assert sum(shares) == gb
        assert min(shares) >= 0

    def test_weighting_follows_width(self):
        # A 4-chip node takes 4x the batch of a 1-chip node.
        assert batch_shares(10, (4, 1)) == (8, 2)
        assert batch_shares(8, (2, 2)) == (4, 4)

    def test_largest_remainder_is_deterministic(self):
        widths = (3, 3, 3)          # 10/3 each: one leftover sample
        assert batch_shares(10, widths) == (4, 3, 3)
        assert batch_shares(10, widths) == batch_shares(10, widths)

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError):
            batch_shares(8, ())
        with pytest.raises(ValueError):
            batch_shares(8, (2, 0))
        with pytest.raises(ValueError):
            batch_shares(-1, (2,))


class TestStepTime:
    @given(widths=widths_lists, extra=st.integers(min_value=1, max_value=8))
    @settings(max_examples=100)
    def test_zero_contention_monotone_in_nodes(self, widths, extra):
        # Under zero contention adding nodes NEVER slows the modeled
        # step: compute strictly shrinks with capacity, memory and the
        # base collective are allocation-independent.
        grown = tuple(widths) + (extra,)
        assert MODEL.step_time(grown) <= MODEL.step_time(widths)

    def test_equal_share_straggler_can_slow_the_step(self):
        # width_weighted=False reproduces today's equal-per-node data
        # plane: adding a narrow node makes the narrowest node carry a
        # full 1/n share and the step genuinely slows down.
        eq = replace(MODEL, width_weighted=False, param_bytes=1)
        assert eq.step_time((4, 4, 1)) > eq.step_time((4, 4))

    def test_widths_for_prefix_and_padding(self):
        m = replace(MODEL, node_widths=(4, 2))
        assert m.widths_for(1) == (4,)
        assert m.widths_for(2) == (4, 2)
        assert m.widths_for(4) == (4, 2, 1, 1)
        # No model widths: the scenario's core_pool governs.
        assert MODEL.widths_for(2, core_pool=(8, 8, 8)) == (8, 8)
        assert MODEL.widths_for(3, default_width=2) == (2, 2, 2)
        with pytest.raises(ValueError):
            MODEL.widths_for(0)

    def test_calibrate_round_trips_contention(self):
        truth = replace(MODEL, contention=0.37)
        widths = (4, 4, 2, 1)
        measured = truth.step_time(widths)
        fitted = MODEL.calibrate(measured, widths)
        assert fitted.contention == pytest.approx(0.37)
        assert fitted.step_time(widths) == pytest.approx(measured)

    def test_calibrate_clamps_at_zero(self):
        fast = 0.5 * MODEL.step_time((4, 4))
        assert MODEL.calibrate(fast, (4, 4)).contention == 0.0
        # Single-node measurements carry no contention signal.
        assert MODEL.calibrate(1e9, (4,)).contention == 0.0


class TestExecutorAccrualParity:
    """sim == vectorized == live on every registered scenario, the
    accrued time_to_result_s field included (16-field parity keys)."""

    @pytest.mark.parametrize(
        "name", sorted(sc.name for sc in registered_scenarios()))
    def test_three_executors_agree_under_the_model(self, name):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc, throughput=MODEL)
        vec = run_scenario_vectorized(sc, throughput=MODEL)
        live = run_scenario_live(sc, throughput=MODEL)
        k = [list(map(record_parity_key, recs)) for recs in (sim, vec, live)]
        assert k[0] == k[1] == k[2]

    def test_no_model_means_sentinel_equals_est_wall(self):
        sc = get_scenario("steady-cycle")
        for rec in run_scenario_vectorized(sc):
            assert rec.time_to_result_s == rec.est_wall_s

    def test_accrued_sum_is_time_to_result_minus_tail(self):
        sc = get_scenario("steady-cycle")
        recs = run_scenario_vectorized(sc, throughput=MODEL)
        last = max(r.step for r in recs)
        final = max(recs, key=lambda r: r.step).nodes_after
        tail = (sc.steps - last) * MODEL.step_time(
            MODEL.widths_for(final, core_pool=sc.core_pool,
                             default_width=sc.cores_per_node))
        accrued = sum(r.time_to_result_s for r in recs)
        assert accrued + tail == pytest.approx(
            time_to_result(recs, sc, MODEL))


class TestObjectiveSwap:
    def test_disabled_model_reproduces_old_scores_bit_for_bit(self):
        # The PR-8 objective pin: with no model the makespan term IS
        # the makespan and the score is unchanged to the last bit.
        out = evaluate_schedule(WORKLOAD_TRACES["slurm-burst"],
                                SchedulerKnobs())
        assert out.score == 9.082993378723405
        assert out.time_to_result_s == out.makespan_s

    def test_uneven_pool_objectives_diverge_and_ttr_wins(self):
        # The acceptance criterion, at the bench gate's smoke settings:
        # on the uneven pool the two objectives pick different knobs
        # and the time-to-result winner is genuinely faster.
        trace = WORKLOAD_TRACES["slurm-burst"]
        mk = optimize_schedule(trace, grid=SCHED_SMOKE_GRID,
                               n_random=SCHED_SMOKE_RANDOM, seed=0)
        tt = optimize_schedule(trace, grid=SCHED_SMOKE_GRID,
                               n_random=SCHED_SMOKE_RANDOM, seed=0,
                               throughput=THRPT_MODEL_UNEVEN)
        assert mk.best.knobs != tt.best.knobs
        mk_ttr = evaluate_schedule(trace, mk.best.knobs,
                                   throughput=THRPT_MODEL_UNEVEN)
        assert tt.best.time_to_result_s < mk_ttr.time_to_result_s


class TestModeledCheckpointCadence:
    def _cluster(self):
        return ClusterState(
            total_nodes=8,
            jobs=(JobSpec("train", min_nodes=1, max_nodes=8),))

    def test_flat_default_is_preserved(self):
        pol = CheckpointIntervalPolicy()
        assert pol.resolved_step_time_s() == pol.step_time_s
        job = self._cluster().jobs[0]
        assert pol.interval_steps(job) == pol.interval_steps(job, nodes=0)

    def test_wider_allocation_never_shortens_the_interval(self):
        # Zero contention: more nodes -> faster steps -> more steps fit
        # in the same Young/Daly seconds-optimal interval.
        pol = CheckpointIntervalPolicy(throughput=MODEL)
        job = self._cluster().jobs[0]
        i1 = pol.interval_steps(job, nodes=1)
        i8 = pol.interval_steps(job, nodes=8)
        assert i8 >= i1
        assert pol.resolved_step_time_s(8) <= pol.resolved_step_time_s(1)
