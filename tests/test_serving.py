"""Serving-plane tests: paged KV-cache parity, continuous-batching
invariants under random interleavings, and the pinned sim == live ==
trainer agreement for the registered serve traffic traces.

Three layers, mirroring the training-side gates:

* :class:`KVPageTable` predicted vs measured migration stats — the
  serving analog of ``tests/test_reshard.py``'s
  ``transfer_stats == predicted_transfer_stats``;
* property-based interleavings (arrival / admit+decode / resize in
  random order) through :meth:`ContinuousBatcher.check_invariants` —
  the zero-drop invariant is pinned here, not just asserted in prose;
* the three registered serve traces replayed end to end on both
  executors (fast) and through the full :class:`ElasticTrainer` loop
  in a subprocess (slow), with exact per-event parity,
  ``bytes_cross_rack`` included.
"""
import os
import subprocess
import sys
import textwrap

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ReconfigEngine
from repro.malleability import (
    MN5,
    get_scenario,
    record_parity_key,
    registered_scenarios,
    run_scenario_live,
    run_scenario_sim,
)
from repro.malleability.policies import SERVE_SCENARIO_NAMES, SERVE_TRAFFIC
from repro.serving import (
    ContinuousBatcher,
    KVBytesModel,
    KVPageTable,
    PageSpec,
    Request,
    check_serve_agreement,
    page_bytes_for_arch,
    run_serve,
    serve_config,
    serve_parity_key,
)

SPEC = PageSpec(page_tokens=16, page_bytes=1024)


def make_table(workers=2, pages_per_worker=8, **kw):
    return KVPageTable(SPEC, range(workers), pages_per_worker, **kw)


# ============================================================ page table ==
class TestPageGeometry:
    def test_pages_for_rounds_up(self):
        assert SPEC.pages_for(1) == 1
        assert SPEC.pages_for(16) == 1
        assert SPEC.pages_for(17) == 2
        assert SPEC.pages_for(0) == 1          # every request holds a page

    def test_bad_geometry_rejected(self):
        with pytest.raises(ValueError):
            PageSpec(page_tokens=0, page_bytes=1024)
        with pytest.raises(ValueError):
            PageSpec(page_tokens=16, page_bytes=0)

    def test_page_bytes_for_arch_is_real_cache_bytes(self):
        pb = page_bytes_for_arch("xlstm_125m", 16)
        assert pb > 0
        # deterministic (lru_cache or not, same inputs -> same bytes)
        assert pb == page_bytes_for_arch("xlstm_125m", 16)


class TestAllocation:
    def test_allocate_append_free_roundtrip(self):
        t = make_table()
        t.allocate(0, 2, worker=1)
        assert t.request_worker(0) == 1
        assert t.used_pages(1) == 2 and t.free_pages(1) == 6
        t.append_page(0)
        assert len(t.request_pages(0)) == 3
        assert t.request_bytes(0) == 3 * SPEC.page_bytes
        assert t.free_request(0) == 3
        assert t.total_pages() == 0
        assert t.pages_allocated == t.pages_freed == 3

    def test_allocation_errors(self):
        t = make_table()
        t.allocate(0, 1, worker=0)
        with pytest.raises(ValueError):
            t.allocate(0, 1, worker=0)          # duplicate rid
        with pytest.raises(KeyError):
            t.allocate(1, 1, worker=9)          # unknown worker
        with pytest.raises(ValueError):
            t.allocate(1, 0, worker=0)          # no pages

    def test_capacity_overrides(self):
        t = KVPageTable(SPEC, range(2), 8, capacities={1: 3})
        assert t.capacity(0) == 8 and t.capacity(1) == 3
        with pytest.raises(ValueError):
            KVPageTable(SPEC, range(2), 8, capacities={0: 0})


# ===================================== predicted == measured migration ==
class TestResizeParity:
    """The reshard-parity twin: ``predicted_resize_stats`` (pure, from
    the plan) equals ``apply_resize().stats`` (measured from the
    page→worker diff), byte for byte, for every resize shape."""

    def loaded_table(self, **kw):
        t = make_table(workers=2, **kw)
        t.allocate(0, 3, worker=0)
        t.allocate(1, 2, worker=0)
        t.allocate(2, 1, worker=1)
        return t

    def check(self, table, workers_after):
        predicted = table.predicted_resize_stats(workers_after)
        result = table.apply_resize(workers_after)
        assert result.stats == predicted, (predicted, result.stats)
        stats = result.stats
        assert stats["bytes_total"] == \
            stats["bytes_stayed"] + stats["bytes_moved"]
        assert table.worker_ids() == tuple(sorted(workers_after))
        return result

    def test_grow_parity_and_fresh_only_moves(self):
        t = self.loaded_table()
        res = self.check(t, range(4))
        assert res.added == (2, 3)
        for _rid, _src, dst in res.moves:
            assert dst in (2, 3)               # survivors untouched on grow

    def test_shrink_parity_and_clean_eviction(self):
        t = self.loaded_table()
        res = self.check(t, [0])
        assert res.evicted == (1,)
        assert t.used_pages(0) == 6            # everything landed on 0
        assert res.stats["bytes_moved"] == 1 * SPEC.page_bytes

    def test_uneven_capacities_parity(self):
        t = self.loaded_table(capacities={0: 20, 1: 4})
        self.check(t, range(4))
        t2 = self.loaded_table(capacities={0: 20, 1: 4})
        self.check(t2, [1])

    def test_plan_is_deterministic(self):
        t = self.loaded_table()
        assert t.plan_resize(range(4)) == t.plan_resize(range(4))

    def test_slot_limit_caps_fresh_workers(self):
        t = make_table(workers=1, slot_limit=1)
        for rid in range(4):
            t.allocate(rid, 2, worker=0)
        res = t.apply_resize(range(3))
        landed = {}
        for _rid, _src, dst in res.moves:
            landed[dst] = landed.get(dst, 0) + 1
        assert all(n <= 1 for w, n in landed.items() if w in res.added)

    def test_empty_target_rejected(self):
        with pytest.raises(ValueError):
            self.loaded_table().plan_resize([])


# ================================================== engine bytes model ==
class TestKVBytesModel:
    def test_noop_and_degenerate_resizes_are_free(self):
        m = KVBytesModel(make_table())
        zeros = {"bytes_total": 0, "bytes_stayed": 0, "bytes_moved": 0}
        assert m.stats(2, 2) == zeros
        assert m.stats(0, 4) == zeros
        assert m(2, 2) == zeros

    def test_prefix_contract_enforced(self):
        t = KVPageTable(SPEC, [0, 2], 8)     # hole in the worker range
        with pytest.raises(ValueError, match="prefix"):
            KVBytesModel(t).stats(2, 4)
        with pytest.raises(ValueError, match="width"):
            KVBytesModel(make_table(), width=2).stats(3, 4)

    def test_stats_match_table_prediction(self):
        t = make_table()
        t.allocate(0, 3, worker=0)
        t.allocate(1, 2, worker=1)
        m = KVBytesModel(t)
        assert m.stats(2, 4) == t.predicted_resize_stats(range(4))
        assert m.stats(2, 1) == t.predicted_resize_stats(range(1))

    def test_engine_charges_the_table_bytes(self):
        """A ReconfigEngine with the KV bytes model prices a pool resize
        from the actual resident pages — the stage-3 contract."""
        t = make_table()
        t.allocate(0, 3, worker=0)
        t.allocate(1, 2, worker=1)
        engine = ReconfigEngine(cost_model=MN5, bytes_model=KVBytesModel(t))
        predicted = t.predicted_resize_stats(range(1))
        stayed, moved = engine.redistribution_stats(2, 1)
        assert (stayed, moved) == (predicted["bytes_stayed"],
                                   predicted["bytes_moved"])


# ============================================== batching: random walks ==
SIZES = (1, 2, 3, 4, 6, 8)


def drive(batcher, ops):
    """Replay (op, arg) pairs; check invariants after every operation."""
    rid = step = 0
    for op, arg in ops:
        if op == 0:                                    # arrival
            batcher.submit(Request(
                rid=rid, arrival_step=step,
                prompt_tokens=1 + 3 * arg, gen_tokens=1 + arg))
            rid += 1
        elif op == 1:                                  # pool resize
            batcher.resize(range(SIZES[arg % len(SIZES)]), step)
        else:                                          # serve one step
            batcher.admit(step)
            batcher.decode(step)
        batcher.check_invariants()
        step += 1
    return rid, step


def drain(batcher, step, limit=600):
    for _ in range(limit):
        if not batcher.in_flight():
            return True
        batcher.admit(step)
        batcher.decode(step)
        batcher.check_invariants()
        step += 1
    return False


class TestBatcherProperties:
    """Random arrival/decode/resize interleavings: nothing is ever
    dropped or duplicated, and the page ledger balances at drain."""

    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2),
                  st.integers(min_value=0, max_value=7)),
        min_size=1, max_size=60))
    def test_interleavings_never_drop_or_duplicate(self, ops):
        table = make_table(workers=2, slot_limit=3)
        b = ContinuousBatcher(table, slots_per_worker=3)
        submitted, step = drive(b, ops)
        assert drain(b, step), "batcher failed to drain"
        assert b.dropped == 0
        assert set(b.completed) == set(range(submitted))
        assert table.total_pages() == 0
        assert table.pages_allocated == table.pages_freed

    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.integers(min_value=0, max_value=2),
                  st.integers(min_value=0, max_value=7)),
        min_size=1, max_size=40),
        n_after=st.sampled_from(SIZES))
    def test_resize_preserves_in_flight_and_progress(self, ops, n_after):
        table = make_table(workers=2, slot_limit=3)
        b = ContinuousBatcher(table, slots_per_worker=3)
        _, step = drive(b, ops)
        flight_before = b.in_flight()
        progress_before = dict(b.progress)
        b.resize(range(n_after), step)
        b.check_invariants()
        assert b.in_flight() == flight_before
        for rid, done in progress_before.items():
            assert b.progress.get(rid, done) == done   # nothing restarted

    def test_requeued_request_readmits_where_its_pages_are(self):
        """A resize survivor sent back to the queue re-admits only on
        the worker holding its pages — re-admission moves zero bytes."""
        table = make_table(workers=2, slot_limit=1)
        b = ContinuousBatcher(table, slots_per_worker=1)
        for rid in range(2):
            b.submit(Request(rid, 0, prompt_tokens=8, gen_tokens=6))
        b.admit(0)
        assert len(b.active) == 2              # one slot on each worker
        b.resize([0], 0)                       # both now hold pages on 0
        b.check_invariants()
        assert b.requeued >= 1 and b.dropped == 0
        queued = list(b.queue)
        assert queued
        allocated_before = table.pages_allocated
        b.admit(1)
        assert table.pages_allocated == allocated_before
        for rid in queued:
            if rid in b.active:
                assert b.active[rid] == table.request_worker(rid) == 0

    def test_head_of_line_blocking_is_fair(self):
        """When the oldest waiting request cannot be placed, nothing
        behind it jumps the queue."""
        table = make_table(workers=1, pages_per_worker=4)
        b = ContinuousBatcher(table, slots_per_worker=4)
        b.submit(Request(0, 0, prompt_tokens=64, gen_tokens=1))   # 4 pages
        b.submit(Request(1, 0, prompt_tokens=64, gen_tokens=1))   # blocked
        b.submit(Request(2, 0, prompt_tokens=1, gen_tokens=1))    # would fit
        assert b.admit(0) == [0]
        assert list(b.queue) == [1, 2]          # 2 did not overtake 1


# ================================================== the serve traces ==
class TestServeTraces:
    def test_traces_are_registered_scenarios(self):
        names = {s.name for s in registered_scenarios()}
        assert set(SERVE_SCENARIO_NAMES) <= names
        assert set(SERVE_SCENARIO_NAMES) == set(SERVE_TRAFFIC)

    @pytest.mark.parametrize("name", SERVE_SCENARIO_NAMES)
    def test_scenario_machinery_sim_live_parity(self, name):
        """As plain scenarios (nominal bytes model) the serve traces
        already agree per event on both scenario executors."""
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert len(sim) >= 2, "serve trace must actually reconfigure"
        assert [record_parity_key(r) for r in sim] == \
            [record_parity_key(r) for r in live]

    @pytest.mark.parametrize("name", SERVE_SCENARIO_NAMES)
    def test_zero_drop_pinned(self, name):
        """ACCEPTANCE: no serve trace drops an in-flight request across
        any resize, and every page is returned at drain (run_serve
        raises on violations; the report re-asserts the tallies)."""
        rep = run_serve(name)
        assert rep.dropped == 0
        assert rep.submitted == rep.completed > 0
        assert len(rep.records) >= 2
        assert rep.migrated + rep.requeued > 0   # resizes hit live requests
        assert rep.bytes_moved > 0               # ...and moved their KV
        assert len(rep.latencies) == rep.completed
        assert rep.downtime_s == sum(r.downtime_s for r in rep.records)

    @pytest.mark.parametrize("name", SERVE_SCENARIO_NAMES)
    def test_sim_equals_live_on_every_number(self, name):
        sim = run_serve(name, executor="sim")
        live = run_serve(name, executor="live")
        assert serve_parity_key(sim) == serve_parity_key(live)

    def test_check_serve_agreement_is_clean(self):
        assert check_serve_agreement() == 0

    def test_trace_specific_pricing(self):
        """The knobs that make each trace distinct actually bite."""
        flash = run_serve("serve-flashcrowd")
        assert flash.bytes_cross_rack > 0        # burst grow pays off-rack
        diurnal = run_serve("serve-diurnal")
        assert diurnal.bytes_cross_rack == 0     # no topology, no split
        slo = run_serve("serve-slo")
        assert slo.queued_s > 0                  # delayed grants are queued

    def test_phases_cover_the_run(self):
        rep = run_serve("serve-diurnal")
        assert rep.phases[0].start_step == 0
        for a, b in zip(rep.phases, rep.phases[1:]):
            assert a.end_step == b.start_step
        assert sum(p.completed for p in rep.phases) == rep.completed
        workers = [p.workers for p in rep.phases]
        assert max(workers) == 8 and workers[0] == workers[-1] == 2

    def test_unknown_trace_rejected(self):
        with pytest.raises(KeyError):
            run_serve("no-such-trace")
        with pytest.raises(KeyError, match="traffic"):
            run_serve("steady-cycle")            # registered, but not serve

    def test_bad_executor_rejected(self):
        with pytest.raises(ValueError, match="executor"):
            run_serve("serve-diurnal", executor="quantum")

    def test_serve_config_tracks_the_policy(self):
        for name in SERVE_SCENARIO_NAMES:
            cfg = serve_config(name)
            pol = SERVE_TRAFFIC[name]
            assert cfg.slots_per_worker == pol.slots_per_worker
            assert cfg.gen_tokens == pol.hold_steps - 2

    def test_launch_driver_agrees_and_prints_phases(self, capsys):
        """The rewired serve entry point replays sim + live and exits 0
        only when every number matches."""
        from repro.launch.serve import main, run_elastic

        assert run_elastic(("serve-diurnal",), "both", None) == 0
        out = capsys.readouterr().out
        assert "sim == live: OK" in out
        assert "total: wall" in out
        assert main(["--scenario", "serve-slo", "--executor", "sim"]) == 0
        assert "queued" in capsys.readouterr().out


# =============================================== trainer loop (slow) ==
SERVE_TRAINER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.configs import smoke_config
    from repro.elastic import ElasticTrainer
    from repro.malleability import get_scenario, run_scenario_sim
    from repro.models import Model

    model = Model(smoke_config("xlstm_125m"))

    # Node counts along every serve trace are 2/4/8, so batch 8 shards
    # cleanly on the 8 host devices at each allocation.
    for name in ("serve-diurnal", "serve-flashcrowd", "serve-slo"):
        sc = get_scenario(name)
        sim = run_scenario_sim(sc)
        tr = ElasticTrainer.from_scenario(model, sc, batch=8, seq=32)
        tr.run(sc.steps)
        live = tr.runtime.history
        assert len(live) == len(sim), (name, len(live), len(sim))
        for s, l in zip(sim, live):
            assert l.downtime_s == s.downtime_s, (name, s, l)
            assert l.est_wall_s == s.est_wall_s, (name, s, l)
            assert l.queued_s == s.queued_s, (name, s, l)
            assert (l.bytes_moved, l.bytes_stayed) == (
                s.bytes_moved, s.bytes_stayed), (name, s, l)
            assert l.bytes_cross_rack == s.bytes_cross_rack, (name, s, l)
            assert (l.nodes_before, l.nodes_after) == (
                s.nodes_before, s.nodes_after), (name, s, l)
        losses = np.array(tr.losses())
        assert np.isfinite(losses).all(), name
        print("SERVE_TRAINER_OK", name, len(live), "reconfigs")
""")


@pytest.mark.slow
def test_trainer_loop_matches_serve_simulator():
    """Full ElasticTrainer loop on every serve trace: its runtime
    history must carry exactly the simulator's per-event downtimes,
    queue spans, and bytes — ``bytes_cross_rack`` included."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", SERVE_TRAINER_SCRIPT], capture_output=True,
        text=True, timeout=1800, env=env,
    )
    assert proc.returncode == 0, (proc.stderr[-3000:], proc.stdout[-500:])
    for name in SERVE_SCENARIO_NAMES:
        assert f"SERVE_TRAINER_OK {name}" in proc.stdout
