"""Bench-regression gate: comparator semantics + committed baseline shape."""
import json
import os
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), os.pardir)
sys.path.insert(0, os.path.join(REPO, "scripts"))

from check_bench import check_scale, compare, index_rows, main  # noqa: E402


def scale_section(speedup=80.0, mc_wall=5.0):
    """A passing measured-throughput section (both threshold targets)."""
    return [
        {"table": "scale", "events": 1000, "speedup_vs_object": speedup},
        {"table": "scale", "events": 100000, "speedup_vs_object": speedup},
        {"table": "scale-mc", "pool_nodes": 10000, "replicas": 1000,
         "wall_s": mc_wall},
    ]


def doc(rows, smoke=True, scale=None):
    return {"smoke": smoke,
            "rows": [{"name": n, "us_per_call": us, "derived": ""}
                     for n, us in rows],
            "scale": scale_section() if scale is None else scale}


class TestComparator:
    def test_identical_runs_pass(self):
        d = doc([("a", 100), ("b", 0)])
        failures, infos = compare(d, d)
        assert failures == [] and infos == []

    def test_drift_beyond_tolerance_fails_both_directions(self):
        base = doc([("a", 100), ("b", 100)])
        cur = doc([("a", 111), ("b", 89)])
        failures, _ = compare(base, cur, tolerance=0.10)
        assert len(failures) == 2
        assert all("DRIFT" in f for f in failures)

    def test_drift_within_tolerance_passes(self):
        failures, _ = compare(doc([("a", 100)]), doc([("a", 109)]),
                              tolerance=0.10)
        assert failures == []

    def test_missing_row_fails_new_row_is_informational(self):
        failures, infos = compare(doc([("a", 100)]), doc([("b", 100)]))
        assert len(failures) == 1 and "MISSING" in failures[0]
        assert len(infos) == 1 and "NEW" in infos[0]

    def test_zero_baseline_rows_must_stay_zero(self):
        failures, _ = compare(doc([("t2", 0)]), doc([("t2", 5)]))
        assert len(failures) == 1 and "NONZERO" in failures[0]
        failures, _ = compare(doc([("t2", 0)]), doc([("t2", 0)]))
        assert failures == []

    def test_duplicate_names_compared_positionally(self):
        base = doc([("fail", 10), ("fail", 20)])
        assert set(index_rows(base)) == {"fail", "fail#1"}
        failures, _ = compare(base, doc([("fail", 10), ("fail", 40)]))
        assert len(failures) == 1 and "fail#1" in failures[0]

    def test_main_exit_codes(self, tmp_path):
        good = tmp_path / "good.json"
        bad = tmp_path / "bad.json"
        base = tmp_path / "base.json"
        base.write_text(json.dumps(doc([("a", 100)])))
        good.write_text(json.dumps(doc([("a", 105)])))
        bad.write_text(json.dumps(doc([("a", 200)])))
        assert main([str(base), str(good)]) == 0
        assert main([str(base), str(bad)]) == 1


class TestScaleThresholds:
    """The measured scale section is threshold-gated, never drift-compared."""

    def test_passing_section(self):
        assert check_scale(doc([])) == []

    def test_largest_trace_gates_the_speedup(self):
        # Only the LARGEST churn trace's speedup is thresholded: the
        # small traces amortize less fixed cost and may sit below it.
        section = [
            {"table": "scale", "events": 1000, "speedup_vs_object": 3.0},
            {"table": "scale", "events": 100000, "speedup_vs_object": 80.0},
            {"table": "scale-mc", "wall_s": 5.0},
        ]
        assert check_scale(doc([], scale=section)) == []

    def test_low_speedup_fails(self):
        failures = check_scale(doc([], scale=scale_section(speedup=10.0)))
        assert len(failures) == 1 and "speedup" in failures[0]

    def test_slow_monte_carlo_fails(self):
        failures = check_scale(doc([], scale=scale_section(mc_wall=30.0)))
        assert len(failures) == 1 and "Monte-Carlo" in failures[0]

    def test_missing_section_fails_both_checks(self):
        failures = check_scale({"rows": []})
        assert len(failures) == 2

    def test_thresholds_are_tunable(self):
        d = doc([], scale=scale_section(speedup=10.0, mc_wall=30.0))
        assert check_scale(d, min_speedup=5.0, max_mc_seconds=60.0) == []

    def test_main_fails_on_scale_regression(self, tmp_path):
        base = tmp_path / "base.json"
        cur = tmp_path / "cur.json"
        base.write_text(json.dumps(doc([("a", 100)])))
        cur.write_text(json.dumps(doc([("a", 100)],
                                      scale=scale_section(speedup=10.0))))
        assert main([str(base), str(cur)]) == 1
        assert main([str(base), str(cur), "--min-speedup", "5"]) == 0


class TestCommittedBaseline:
    """The committed BENCH_baseline.json must stay a valid --smoke --json
    document covering every table family run.py emits."""

    @pytest.fixture(scope="class")
    def baseline(self):
        with open(os.path.join(REPO, "BENCH_baseline.json")) as f:
            return json.load(f)

    def test_is_a_smoke_run_with_envelopes(self, baseline):
        assert baseline["smoke"] is True
        assert len(baseline["envelopes"]) == 5

    def test_covers_every_table_family(self, baseline):
        families = {r["name"].split("/")[0] for r in baseline["rows"]}
        assert {"fig4a", "fig4b", "fig5", "fig6a", "fig6b", "table2",
                "fig1", "scenario", "hetero", "topo", "redist", "overlap",
                "policy"} <= families

    def test_topo_rows_carry_four_class_bytes(self, baseline):
        topo = [r for r in baseline["rows"]
                if r["name"].startswith("topo/topo-pods/")]
        assert topo and all("cross_pod=" in r["derived"] for r in topo)

    def test_scale_section_present(self, baseline):
        tables = [r["table"] for r in baseline.get("scale", [])]
        assert tables.count("scale") == 3 and tables.count("scale-mc") == 1

    def test_hetero_rows_present_with_per_link_bytes(self, baseline):
        hetero = [r for r in baseline["rows"]
                  if r["name"].startswith("hetero/hetero-redist/")]
        assert hetero and all("stayed=" in r["derived"] for r in hetero)
