"""Minimal deterministic stand-in for the slice of `hypothesis` this
test-suite uses.

Installed by ``conftest.py`` into ``sys.modules`` only when the real
``hypothesis`` package is unavailable (the tier-1 environment does not
ship it).  It is NOT a property-based testing engine: ``@given`` simply
replays ``max_examples`` pseudo-random draws from a fixed seed, so runs
are reproducible and the suite collects and passes everywhere.  When the
real hypothesis is installed it is always preferred.
"""
from __future__ import annotations

import functools
import inspect
import math
import random
import types

_DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xC0FFEE


class _Strategy:
    """A draw rule: ``sample(rng) -> value``."""

    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


def _integers(min_value=None, max_value=None) -> _Strategy:
    lo = -(2**20) if min_value is None else int(min_value)
    hi = 2**20 if max_value is None else int(max_value)
    return _Strategy(lambda rng: rng.randint(lo, hi))


def _floats(min_value=None, max_value=None, **_kw) -> _Strategy:
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)
    # Sample log-uniformly when the range spans orders of magnitude and is
    # positive (mirrors how these tests use floats: scales like 1e-3..1e3).
    if lo > 0 and hi / lo > 1e3:
        return _Strategy(
            lambda rng: math.exp(rng.uniform(math.log(lo), math.log(hi)))
        )
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(elements) -> _Strategy:
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty collection")
    return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def _just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def _lists(elements: _Strategy, min_size=0, max_size=None, **_kw) -> _Strategy:
    hi = (min_size + 8) if max_size is None else max_size

    def sample(rng: random.Random):
        n = rng.randint(min_size, hi)
        return [elements.sample(rng) for _ in range(n)]

    return _Strategy(sample)


def _tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from
strategies.just = _just
strategies.lists = _lists
strategies.tuples = _tuples


class settings:
    """Records ``max_examples``; everything else is accepted and ignored."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    """Replay a fixed number of deterministic draws through the test."""
    if arg_strategies:
        raise TypeError(
            "the hypothesis stub supports keyword strategies only "
            "(all tests in this repo use @given(name=st...))"
        )

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", None
            )
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _UnsatisfiedAssumption:
                    continue

        # pytest must not see the drawn parameters (it would treat them as
        # fixtures): hide the original signature and advertise only the
        # pass-through parameters (``self`` for methods, fixtures if any).
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        passthrough = [
            p for name, p in sig.parameters.items() if name not in kw_strategies
        ]
        wrapper.__signature__ = sig.replace(parameters=passthrough)
        wrapper.hypothesis_stub = True
        return wrapper

    return decorate


def assume(condition) -> bool:
    """Best-effort: abort the current example quietly when unsatisfied."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.filter_too_much]
