"""Topology layer tests: the rack/pod tree, distance-class pricing, the
topo strategy's placement decisions, and the per-class byte reports.

The two load-bearing invariants:

* **degradation** — a single-rack topology (or none at all) reproduces
  the PR-4 local/cross split bit for bit, and the default 2-class
  CostModel prices any cross-rack split identically (both moved classes
  fall back to the cross link);
* **conservation** — ``bytes_by_class`` always sums to
  ``bytes_stayed + bytes_moved``, on every event, timeline, and record.
"""
import pytest

from repro.core import (
    DISTANCE_CLASSES,
    TOPO_KEY,
    Method,
    ReconfigEngine,
    Topology,
    get_strategy,
    place_rack_local,
    plan_topo,
    strategy_key,
    vacate_racks,
)
from repro.core.engine import _cross_share
from repro.malleability import (
    MN5,
    CostModel,
    get_scenario,
    param_bytes_for_arch,
    run_scenario_live,
    run_scenario_sim,
    scenario_pool,
)


# ================================================================= tree ==
class TestTopologyTree:
    def test_prefix_assignment_uneven_racks(self):
        t = Topology(rack_sizes=(3, 2))
        assert t.n_nodes == 5 and t.n_racks == 2
        assert [t.rack_of(n) for n in range(5)] == [0, 0, 0, 1, 1]
        assert t.nodes_in_rack(0) == (0, 1, 2)
        assert t.nodes_in_rack(1) == (3, 4)

    def test_distance_classes(self):
        t = Topology(rack_sizes=(2, 2))
        assert t.distance_class(0, 0) == "intra_node"
        assert t.distance_class(0, 1) == "intra_rack"
        assert t.distance_class(1, 2) == "cross_rack"
        assert set(DISTANCE_CLASSES) == {"intra_node", "intra_rack",
                                         "cross_rack", "cross_pod"}
        # cross_pod only ever appears with pods configured
        p = Topology(rack_sizes=(1, 1, 1, 1), pod_sizes=(2, 2))
        assert p.distance_class(0, 1) == "cross_rack"
        assert p.distance_class(0, 2) == "cross_pod"
        assert t.distance_class(0, 3) == "cross_rack"

    def test_pods(self):
        t = Topology(rack_sizes=(1, 1, 1, 1), pod_sizes=(2, 2))
        assert t.pod_of(0) == t.pod_of(1) == 0
        assert t.pod_of(2) == t.pod_of(3) == 1
        # without pods, each rack is its own pod
        assert Topology(rack_sizes=(2, 2)).pod_of_rack(1) == 1

    def test_uniform_and_single_rack_constructors(self):
        t = Topology.uniform(3, 4, racks_per_pod=3)
        assert t.rack_sizes == (4, 4, 4) and t.pod_sizes == (3,)
        assert Topology.single_rack(6).rack_sizes == (6,)

    def test_validation(self):
        with pytest.raises(ValueError):
            Topology(rack_sizes=())
        with pytest.raises(ValueError):
            Topology(rack_sizes=(2, 0))
        with pytest.raises(ValueError):
            Topology(rack_sizes=(2, 2), pod_sizes=(3,))   # covers 3 racks
        with pytest.raises(ValueError):
            Topology.uniform(3, 2, racks_per_pod=2)       # 3 % 2 != 0
        t = Topology(rack_sizes=(2,))
        with pytest.raises(ValueError):
            t.rack_of(2)
        with pytest.raises(ValueError):
            t.rack_of(-1)


# ====================================================== pricing degrades ==
class TestDistanceClassPricing:
    def test_three_class_charge_formula(self):
        cm = MN5.with_class_bandwidths(intra_node=20e9, intra_rack=10e9,
                                       cross_rack=2e9)
        got = cm.redistribution(100e9, stayed_bytes=40e9,
                                cross_rack_bytes=30e9)
        want = cm.redist_alpha + 40e9 / 20e9 + 70e9 / 10e9 + 30e9 / 2e9
        assert got == want

    def test_two_class_defaults_make_rack_split_cost_neutral(self):
        """The PR-4 model: with only local/cross set, intra_rack and
        cross_rack both resolve to the cross link, so ANY cross-rack
        split charges bit-for-bit the pre-topology number."""
        cm = MN5.with_link_bandwidths(local=25e9, cross=2.5e9)
        base = cm.redistribution(10**9, stayed_bytes=10**8)
        for xrack in (0, 1, 10**8, 10**9):
            assert cm.redistribution(10**9, 10**8, xrack) == base
        # and the fully-default model charges the aggregate number
        assert MN5.redistribution(10**9) == (
            MN5.redist_alpha + 10**9 / MN5.redist_bw)

    def test_bw_for_class_resolution_and_unknown(self):
        cm = CostModel(redist_bw_cross=4e9, redist_bw_intra_rack=8e9)
        assert cm.bw_for_class("intra_node") == cm.redist_bw
        assert cm.bw_for_class("intra_rack") == 8e9
        assert cm.bw_for_class("cross_rack") == 4e9   # falls back to cross
        with pytest.raises(ValueError):
            cm.bw_for_class("intra_pod")

    def test_scaled_scales_class_bandwidths(self):
        cm = MN5.with_class_bandwidths(intra_rack=10e9, cross_rack=2e9)
        slow = cm.scaled(4.0)
        assert slow.bw_intra_rack == 2.5e9
        assert slow.bw_cross_rack == 0.5e9

    def test_redistribution_by_class_zero_bytes_no_setup(self):
        assert MN5.redistribution_by_class(
            {"intra_node": 0, "intra_rack": 0, "cross_rack": 0}) == 0.0


# ======================================================== exact splitting ==
class TestCrossShare:
    def test_sums_exactly_whatever_the_remainders(self):
        parts = [(3, True), (2, False), (5, True), (1, False)]
        for total in (0, 1, 7, 10**9 + 7):
            cross = _cross_share(total, parts)
            inverse = _cross_share(
                total, [(w, not c) for w, c in parts])
            assert cross + inverse == max(0, total)

    def test_proportional_when_divisible(self):
        assert _cross_share(60, [(1, True), (2, False)]) == 20
        assert _cross_share(60, [(1, False), (2, True)]) == 40

    def test_empty_or_zero(self):
        assert _cross_share(100, []) == 0
        assert _cross_share(0, [(1, True)]) == 0


# ============================================================== placement ==
class TestPlacement:
    TOPO = Topology(rack_sizes=(2, 3))

    def test_rack_local_first_even_when_ids_are_higher(self):
        # used node 3 sits in rack 1; its free rack-mates {2, 4} beat
        # the lower-id nodes of the untouched rack 0
        got = place_rack_local(self.TOPO, {3}, {0, 1, 2, 4}, 2)
        assert got == [2, 4]
        # with rack 1 exhausted, the higher-id rack-mate still beats
        # the untouched rack's lower ids
        assert place_rack_local(self.TOPO, {2, 3}, {0, 1, 4}, 2) == [4, 0]

    def test_fresh_racks_packed_whole(self):
        # nothing rack-local available: open ONE fresh rack and fill it
        got = place_rack_local(self.TOPO, {0, 1}, {2, 3, 4}, 3)
        assert got == [2, 3, 4]

    def test_pod_local_fresh_rack_preferred(self):
        topo = Topology(rack_sizes=(1, 1, 1, 1), pod_sizes=(2, 2))
        # job occupies rack 0 (pod 0); the fresh rack in the SAME pod
        # (rack 1 -> node 1) beats the pod-1 racks
        assert place_rack_local(topo, {0}, {1, 2, 3}, 1) == [1]

    def test_raises_when_pool_too_small(self):
        with pytest.raises(RuntimeError):
            place_rack_local(self.TOPO, {0}, {1}, 3)

    def test_vacate_whole_rack_first(self):
        # rack 0 (2 used) is the cheapest complete rack to hand back
        assert vacate_racks(self.TOPO, {0, 1, 2, 3, 4}, 2) == [0, 1]
        # equal counts: the higher rack id goes (matches the default
        # highest-id-first release flavour)
        assert vacate_racks(self.TOPO, {0, 1, 3, 4}, 2) == [3, 4]

    def test_vacate_crosses_racks_when_it_must(self):
        # releasing 3 of 5: whole rack 0 (2 nodes) + highest id of rack 1
        assert vacate_racks(self.TOPO, {0, 1, 2, 3, 4}, 3) == [0, 1, 4]

    def test_vacate_remainder_from_least_loaded_rack(self):
        # no whole rack fits a budget of 1: take the highest id from the
        # least-loaded (tie -> higher) rack
        assert vacate_racks(self.TOPO, {0, 1, 3, 4}, 1) == [4]

    def test_vacate_clamps_to_used(self):
        assert vacate_racks(self.TOPO, {0, 1}, 5) == [0, 1]


# ======================================================== topo strategy ==
class TestTopoStrategy:
    def test_registered_with_topology_flag(self):
        spec = get_strategy(TOPO_KEY)
        assert spec.parallel and spec.topology_aware
        assert not spec.homogeneous_only

    def test_plan_matches_diffusive_structure(self):
        from repro.core import plan_diffusive

        topo = plan_topo(2, 8, [2, 1, 2, 1, 2], Method.MERGE)
        diff = plan_diffusive([2, 1, 2, 1, 2], [2, 0, 0, 0, 0], Method.MERGE)
        assert strategy_key(topo.strategy) == TOPO_KEY
        assert topo.to_spawn == diff.to_spawn
        assert topo.steps == diff.steps
        assert [g.size for g in topo.groups] == [g.size for g in diff.groups]

    def test_engine_plans_by_registry_key_without_topology(self):
        # the strategy is usable anywhere (topology optional): placement
        # simply stays greedy and every moved byte stays intra-rack
        engine = ReconfigEngine(strategy=TOPO_KEY)
        plan = engine.plan_expand(2, 6, 1)
        assert strategy_key(plan.strategy) == TOPO_KEY
        assert engine.select_expansion_nodes([0, 1], {2, 3, 4}, 2) == [2, 3]

    def test_placement_hooks_dispatch_on_topology(self):
        topo = Topology(rack_sizes=(2, 3))
        engine = ReconfigEngine(strategy=TOPO_KEY, topology=topo)
        assert engine.select_expansion_nodes({3}, {0, 1, 2, 4}, 2) == [2, 4]
        assert engine.select_release_nodes({0, 1, 2, 3, 4}, 2) == [0, 1]
        # topology-blind strategies keep the greedy orders on the SAME engine
        assert engine.select_expansion_nodes(
            {3}, {0, 1, 2, 4}, 2, strategy="diffusive") == [0, 1]
        assert engine.select_release_nodes(
            {0, 1, 2, 3, 4}, 2, strategy="diffusive") == [3, 4]


# ============================================= end-to-end class volumes ==
class TestBytesByClass:
    def test_sums_to_bytes_total_everywhere(self):
        """Conservation: per event, per timeline, per record."""
        for name in ("topo-redist", "hetero-redist", "redist-cycle"):
            for rec in run_scenario_sim(get_scenario(name)):
                assert sum(rec.bytes_by_class.values()) == (
                    rec.bytes_stayed + rec.bytes_moved), (name, rec)

    def test_topo_redist_class_volumes_pinned(self):
        """The registered trace's exact per-class accounting."""
        pb = param_bytes_for_arch("xlstm_125m")
        recs = run_scenario_sim(get_scenario("topo-redist"))
        burst, shrink, regrow = recs
        # burst 1->5 nodes (2->8 ranks): 2 replicas to rack-mate node 1,
        # 4 across to fresh rack 1; survivors re-validate 2 replicas
        assert burst.bytes_by_class == {
            "intra_node": 2 * pb, "intra_rack": 2 * pb, "cross_rack": 4 * pb,
            "cross_pod": 0}
        # rack-vacating shrink: survivor replicas stay put
        assert shrink.bytes_by_class == {
            "intra_node": 2 * pb, "intra_rack": 0, "cross_rack": 0,
            "cross_pod": 0}
        # rack-LOCAL regrow: both new replicas ride the intra-rack link
        assert regrow.bytes_by_class == {
            "intra_node": 2 * pb, "intra_rack": 2 * pb, "cross_rack": 0,
            "cross_pod": 0}

    def test_classics_pay_cross_rack_where_topo_stays_local(self):
        """The table_topology claim: greedy regrowth reopens the vacated
        rack and pays the cross_rack link for copies topo gets
        rack-locally."""
        sc = get_scenario("topo-redist")
        topo_total = sum(
            r.bytes_cross_rack for r in run_scenario_sim(sc))
        diff_recs = run_scenario_sim(
            sc, engine=sc.default_engine(strategy="diffusive"))
        diff_total = sum(r.bytes_cross_rack for r in diff_recs)
        assert diff_total > topo_total
        # and the diffusive regrow specifically crosses racks
        assert diff_recs[-1].bytes_cross_rack > 0

    def test_expansion_timeline_event_carries_the_split(self):
        from repro.core import Stage

        sc = get_scenario("topo-redist")
        recs = run_scenario_sim(sc)
        engine = sc.default_engine()
        # rebuild the burst expansion's plan and inspect its event
        plan = engine.plan_expand(2, 8, [2, 2, 1, 1, 2],
                                 node_ids=[0, 1, 2, 3, 4])
        tl = engine.timeline(plan)
        ev = next(e for e in tl.events if e.stage is Stage.REDISTRIBUTION)
        assert ev.bytes_by_class == recs[0].bytes_by_class
        assert sum(ev.bytes_by_class.values()) == (
            ev.bytes_stayed + ev.bytes_moved)
        row = tl.as_rows()[-1]
        assert row["bytes_cross_rack"] == ev.bytes_cross_rack


# =========================================================== degradation ==
class TestSingleRackDegradation:
    def test_single_rack_equals_pr4_split_bit_for_bit(self):
        """A topologized single-rack engine charges exactly what the
        pre-topology per-link engine charged, event for event."""
        from dataclasses import replace as dc_replace

        sc = get_scenario("hetero-redist")      # PR-4's per-link trace
        base = run_scenario_sim(sc)
        topologized = dc_replace(
            sc, name="tmp-single-rack",
            rack_sizes=(sc.max_nodes(),))
        topo = run_scenario_sim(topologized)
        assert len(base) == len(topo)
        for b, t in zip(base, topo):
            assert t.est_wall_s == b.est_wall_s
            assert t.downtime_s == b.downtime_s
            assert (t.bytes_moved, t.bytes_stayed) == (
                b.bytes_moved, b.bytes_stayed)
            assert t.bytes_cross_rack == 0      # one rack: nothing crosses

    def test_untopologized_records_report_zero_cross_rack(self):
        for rec in run_scenario_sim(get_scenario("redist-cycle")):
            assert rec.bytes_cross_rack == 0
            assert rec.bytes_by_class["cross_rack"] == 0


# ==================================================== live pool behaviour ==
class TestTopoScenarioLive:
    def test_topo_vacates_and_regrows_rack_local(self):
        """After topo-nasp: rack 0 is ENTIRELY free (handed back whole)
        and the regrow landed next to the rack-1 survivors."""
        sc = get_scenario("topo-nasp")
        pool = scenario_pool(sc)
        run_scenario_live(sc, pool=pool)
        assert pool.free == {0, 1}              # rack 0, complete
        assert sorted(set(pool.nodes) - pool.free) == [2, 3, 4]

    def test_greedy_strategy_fragments_the_same_trace(self):
        """The same trace under diffusive placement keeps low ids busy —
        the vacated capacity is NOT rack-granular."""
        sc = get_scenario("topo-nasp")
        pool = scenario_pool(sc)
        run_scenario_live(sc, pool=pool,
                          engine=sc.default_engine(strategy="diffusive"))
        assert pool.free == {3, 4}
        assert sorted(set(pool.nodes) - pool.free) == [0, 1, 2]

    def test_shrink_returns_whole_uneven_nodes_across_racks(self):
        """The paper's headline on a rack tree: the crossing shrink
        still returns COMPLETE nodes, whatever their width."""
        sc = get_scenario("topo-nasp")
        recs = run_scenario_live(sc)
        shrink = next(r for r in recs if r.kind == "shrink")
        assert shrink.nodes_before == 5 and shrink.nodes_after == 2
        # victims {0,1} empty rack 0 and {4} comes from rack 1
        t = sc.topology()
        assert {t.rack_of(0), t.rack_of(4)} == {0, 1}

    def test_spare_whole_racks_keep_sim_live_parity(self):
        """A rack tree larger than the trace's peak (spare whole racks)
        must size BOTH executors' pools identically — the simulator
        ranking placement against a smaller free set than the live
        DevicePool silently broke per-event parity."""
        from repro.malleability import Scenario, ScenarioEvent

        sc = Scenario(
            name="tmp-spare-racks",
            description="peak 3 nodes on a 6-node (1,2,3) rack tree",
            initial_nodes=1,
            cores_per_node=2,
            rack_sizes=(1, 2, 3),
            events=(
                ScenarioEvent(step=1, kind="grow", target_nodes=3),
                ScenarioEvent(step=3, kind="shrink", target_nodes=2),
                ScenarioEvent(step=5, kind="grow", target_nodes=3),
            ),
            steps=8,
            arch="xlstm_125m",
            redist_bw_local=25.0e9,
            redist_bw_cross=2.5e9,
            redist_bw_intra_rack=10.0e9,
        )
        assert sc.pool_nodes() == 6 > sc.max_nodes() == 3
        sim = run_scenario_sim(sc)
        live = run_scenario_live(sc)
        assert len(sim) == len(live) >= 3
        for s, l in zip(sim, live):
            assert (s.est_wall_s, s.bytes_moved, s.bytes_stayed,
                    s.bytes_cross_rack) == (
                l.est_wall_s, l.bytes_moved, l.bytes_stayed,
                l.bytes_cross_rack)

    def test_multi_node_initial_world_shrinks_class_per_node(self):
        """A multi-node initial world spanning racks is accounted node
        by node: ranks sitting in the victims' rack absorb their share
        intra-rack, not cross-rack."""
        from repro.core import ClusterState as CoreState
        from repro.malleability import fsdp_bytes_model

        topo = Topology(rack_sizes=(2, 3))
        engine = ReconfigEngine(strategy=TOPO_KEY, topology=topo,
                                bytes_model=fsdp_bytes_model(100))
        state = CoreState()
        state.add_world([0, 1, 2], [1, 1, 1], is_initial=True)  # spans racks
        state.add_world([3], [1])
        state.add_world([4], [1])
        plan = engine.plan_shrink(state, release_nodes=[3, 4])
        spec = plan.redistribution
        # victims empty rack 1's single-node worlds; the survivor world
        # has 2 ranks in rack 0 (cross) and 1 rank in rack 1 (intra)
        assert spec.bytes_total == 100
        assert spec.bytes_cross_rack == 66
        assert sum(spec.bytes_by_class.values()) == 100

    def test_runtime_does_not_mutate_callers_engine(self):
        from repro.elastic import ElasticRuntime

        sc = get_scenario("topo-nasp")
        pool = scenario_pool(sc)
        engine = ReconfigEngine(strategy=TOPO_KEY)     # no topology
        rt = ElasticRuntime(pool=pool, initial_nodes=1, engine=engine)
        assert engine.topology is None                 # caller untouched
        assert rt.engine.topology == sc.topology()     # runtime copy adopted

    def test_overcommitting_grow_raises_identically_in_both_executors(self):
        """A GROW beyond the pool must fail loudly in BOTH executors —
        the simulator truncating where the live runtime raises would be
        a silent parity break."""
        from repro.malleability import Scenario, ScenarioEvent

        sc = Scenario(
            name="tmp-overcommit", description="grow past the pool",
            initial_nodes=1, core_pool=(2, 2),
            events=(ScenarioEvent(step=1, kind="grow", target_nodes=3),),
            steps=4,
        )
        with pytest.raises(RuntimeError, match="pool exhausted"):
            run_scenario_sim(sc)
        with pytest.raises((RuntimeError, ValueError)):
            run_scenario_live(sc)

    def test_target_shrink_into_multinode_world_fails_loudly(self):
        """A target-count shrink whose victims sit inside a multi-node
        initial world would degrade to ZS (nodes pinned, target missed);
        it must raise, identically in both executors."""
        from repro.malleability import Scenario, ScenarioEvent

        sc = Scenario(
            name="tmp-zs-target", description="shrink-to inside initial MCW",
            initial_nodes=4, cores_per_node=1,
            events=(ScenarioEvent(step=1, kind="shrink", target_nodes=2),),
            steps=4,
        )
        with pytest.raises(ValueError, match="multi-node"):
            run_scenario_sim(sc)
        with pytest.raises(ValueError, match="multi-node"):
            run_scenario_live(sc)

    def test_pool_topology_must_match_scenario(self):
        from repro.elastic import DevicePool

        sc = get_scenario("topo-nasp")
        bare = DevicePool(devices=[object()] * sum(sc.core_pool),
                          node_widths=sc.core_pool)
        with pytest.raises(ValueError, match="topology"):
            run_scenario_live(sc, pool=bare)

    def test_pool_rejects_wrong_sized_topology(self):
        from repro.elastic import DevicePool

        with pytest.raises(ValueError, match="topology"):
            DevicePool(devices=[object()] * 4, devices_per_node=1,
                       topology=Topology(rack_sizes=(2, 3)))

    def test_pool_rack_of(self):
        pool = scenario_pool(get_scenario("topo-redist"))
        assert pool.rack_of(0) == 0 and pool.rack_of(4) == 1
        with pytest.raises(KeyError):
            pool.rack_of(99)
        bare = scenario_pool(get_scenario("steady-cycle"))
        assert bare.rack_of(0) == 0                 # no topology: one rack

    def test_runtime_rejects_conflicting_topologies(self):
        from repro.elastic import ElasticRuntime

        sc = get_scenario("topo-nasp")
        pool = scenario_pool(sc)
        engine = ReconfigEngine(strategy=TOPO_KEY,
                                topology=Topology(rack_sizes=(5,)))
        with pytest.raises(ValueError, match="topolog"):
            ElasticRuntime(pool=pool, engine=engine)

    def test_runtime_rejects_engine_topology_smaller_than_pool(self):
        """An engine-only rack tree that does not cover the pool would
        crash mid-reconfiguration (rack_of on an outside node) — it
        must be rejected at construction instead."""
        from repro.elastic import DevicePool, ElasticRuntime

        pool = DevicePool(devices=[object()] * 6, devices_per_node=1)
        engine = ReconfigEngine(strategy=TOPO_KEY,
                                topology=Topology(rack_sizes=(2, 2)))
        with pytest.raises(ValueError, match="covers 4 nodes"):
            ElasticRuntime(pool=pool, engine=engine)


# ======================================================= policy threading ==
class TestPolicyTopology:
    def test_from_pool_carries_topology_into_generated_traces(self):
        from repro.malleability import BackfillPolicy, JobSpec
        from repro.malleability.policies import ClusterState as RmsState

        sc = get_scenario("topo-nasp")
        pool = scenario_pool(sc)
        cluster = RmsState.from_pool(
            pool, jobs=(JobSpec("train", min_nodes=1, max_nodes=5),))
        assert cluster.topology == sc.topology()
        trace = BackfillPolicy(horizon=8).generate(cluster)
        generated = trace.scenario("train", name="tmp-topo-policy")
        assert generated.rack_sizes == sc.topology().rack_sizes
        assert generated.topology_aware
        # the generated trace replays through the simulator as-is
        assert run_scenario_sim(generated) is not None

    def test_undersized_topology_rejected(self):
        from repro.malleability.policies import ClusterState as RmsState

        with pytest.raises(ValueError, match="topology"):
            RmsState(total_nodes=8, topology=Topology(rack_sizes=(2, 2)))
