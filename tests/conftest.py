"""Test bootstrap: prefer the real ``hypothesis``; otherwise install the
deterministic stub from ``_hypothesis_stub`` so the suite still collects
and runs (the tier-1 environment does not ship hypothesis)."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub as _stub

    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies
