"""Simulator tests: the §5 envelopes + structural properties."""
import itertools

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Method, ShrinkKind, plan_diffusive, plan_hypercube, plan_sequential
from repro.malleability import MN5, NASP, simulate_expansion, simulate_shrink

C = 112
NODES = [1, 2, 4, 8, 16, 24, 32]


def _running(alloc, ns):
    out, rem = [], ns
    for a in alloc:
        take = min(a, rem)
        out.append(take)
        rem -= take
    return out


class TestPaperEnvelopes:
    """The four headline numbers of §5 must hold on the simulator."""

    def test_mn5_parallel_merge_overhead_under_1p13(self):
        worst = 0.0
        for i, n in itertools.combinations(NODES, 2):
            base = simulate_expansion(
                plan_sequential(i * C, n * C, [C] * n, Method.MERGE), MN5).total
            par = simulate_expansion(
                plan_hypercube(i * C, n * C, C, Method.MERGE), MN5).total
            worst = max(worst, par / base)
        assert worst <= 1.13

    def test_mn5_parallel_baseline_up_to_1p73(self):
        worst = 0.0
        for i, n in itertools.combinations(NODES, 2):
            base = simulate_expansion(
                plan_sequential(i * C, n * C, [C] * n, Method.MERGE), MN5).total
            par = simulate_expansion(
                plan_hypercube(i * C, n * C, C, Method.BASELINE), MN5).total
            worst = max(worst, par / base)
        assert 1.3 <= worst <= 1.73

    def test_mn5_ts_speedup_at_least_1387(self):
        m = 1e18
        for n, i in itertools.combinations(NODES, 2):
            rp = plan_hypercube(i * C, n * C, C, Method.BASELINE)
            ss = simulate_shrink(ShrinkKind.SS, MN5, ns=i * C, nt=n * C,
                                 respawn_plan=rp).total
            ts = simulate_shrink(ShrinkKind.TS, MN5, ns=i * C, nt=n * C,
                                 doomed_world_sizes=[C] * (i - n)).total
            m = min(m, ss / ts)
        assert m >= 1387

    def test_nasp_diffusive_overhead_under_1p25(self):
        nodes = [1, 2, 4, 6, 8, 10, 12, 14, 16]
        alloc = lambda n: [20 if k % 2 == 0 else 32 for k in range(n)]
        worst = 0.0
        for i, n in itertools.combinations(nodes, 2):
            a = alloc(n)
            ns, nt = sum(alloc(i)), sum(a)
            base = simulate_expansion(
                plan_sequential(ns, nt, a, Method.MERGE), NASP).total
            par = simulate_expansion(
                plan_diffusive(a, _running(a, ns), Method.MERGE), NASP).total
            worst = max(worst, par / base)
        assert worst <= 1.25

    def test_nasp_ts_speedup_at_least_20(self):
        nodes = [1, 2, 4, 6, 8, 10, 12, 14, 16]
        alloc = lambda n: [20 if k % 2 == 0 else 32 for k in range(n)]
        m = 1e18
        for n, i in itertools.combinations(nodes, 2):
            a = alloc(n)
            ns, nt = sum(alloc(i)), sum(a)
            rp = plan_diffusive(a, _running(a, min(ns, nt)), Method.BASELINE)
            ss = simulate_shrink(ShrinkKind.SS, NASP, ns=ns, nt=nt, respawn_plan=rp).total
            ts = simulate_shrink(ShrinkKind.TS, NASP, ns=ns, nt=nt,
                                 doomed_world_sizes=alloc(i)[n:]).total
            m = min(m, ss / ts)
        assert m >= 20


class TestStructure:
    @given(i=st.sampled_from(NODES), n=st.sampled_from(NODES))
    @settings(max_examples=30, deadline=None)
    def test_phase_decomposition_sums(self, i, n):
        if n <= i:
            return
        rep = simulate_expansion(plan_hypercube(i * C, n * C, C, Method.MERGE), MN5)
        assert rep.total == pytest.approx(
            rep.t_spawn + rep.t_sync + rep.t_connect + rep.t_reorder + rep.t_final
        )
        assert rep.downtime == rep.total

    @given(i=st.sampled_from(NODES), n=st.sampled_from(NODES))
    @settings(max_examples=30, deadline=None)
    def test_async_hides_spawn(self, i, n):
        if n <= i:
            return
        plan = plan_hypercube(i * C, n * C, C, Method.MERGE)
        sync_rep = simulate_expansion(plan, MN5, asynchronous=False)
        async_rep = simulate_expansion(plan, MN5, asynchronous=True)
        assert async_rep.downtime == pytest.approx(sync_rep.total - sync_rep.t_spawn)

    def test_per_node_sequential_scales_linearly(self):
        """[14]'s per-node spawning: cost grows ~linearly in node count,
        the scalability problem the paper exists to fix."""
        t8 = simulate_expansion(
            plan_sequential(C, 8 * C, [C] * 8, Method.MERGE, per_node=True), MN5).total
        t32 = simulate_expansion(
            plan_sequential(C, 32 * C, [C] * 32, Method.MERGE, per_node=True), MN5).total
        assert t32 / t8 > 3.0
        par8 = simulate_expansion(plan_hypercube(C, 8 * C, C, Method.MERGE), MN5).total
        par32 = simulate_expansion(plan_hypercube(C, 32 * C, C, Method.MERGE), MN5).total
        assert par32 / par8 < 1.5  # parallel strategy is ~flat in node count

    def test_zs_does_not_return_nodes_ts_does(self):
        ts = simulate_shrink(ShrinkKind.TS, MN5, ns=8 * C, nt=2 * C,
                             doomed_world_sizes=[C] * 6, nodes_returned=6)
        zs = simulate_shrink(ShrinkKind.ZS, MN5, ns=8 * C, nt=2 * C,
                             nodes_pinned=6)
        assert ts.nodes_returned == 6
        assert zs.nodes_returned == 0 and zs.nodes_pinned == 6
