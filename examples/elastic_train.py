"""Elastic training: the paper's reconfiguration pipeline, live.

Demonstrates the full malleability loop on host devices:

  1. start training on 1 NodeGroup,
  2. RMS grants nodes -> parallel-hypercube EXPANSION to 4, then 8 groups
     (log-round spawn plan + Eq. 9 device order), live params/optimizer
     resharding (stage 3) with bytes-moved accounting,
  3. RMS reclaims nodes -> TS SHRINK to 2 groups (sub-millisecond
     estimated reconfiguration vs seconds for an SS restart),
  4. a node FAILS -> forced TS shrink + continue,
  and asserts the loss curve is continuous across every resize.

    PYTHONPATH=src python examples/elastic_train.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.api import (
    DevicePool,
    ElasticRuntime,
    Method,
    Model,
    ShardingContext,
    Strategy,
    SyntheticTokens,
    build_init_fn,
    build_train_step,
    make_batch_on_mesh,
    param_sharding,
    reshard_tree,
    smoke_config,
    transfer_stats,
)


def make_step(model, ctx, shardings):
    step_fn, _, _ = build_train_step(model, ctx, lr=1e-3)
    return jax.jit(step_fn, in_shardings=(shardings, None),
                   out_shardings=(shardings, None), donate_argnums=(0,))


def resharded(state, model, ctx):
    """Stage 3 (data redistribution): move state onto the new mesh."""
    from repro.api import train_state_shardings

    _, shardings = train_state_shardings(model, ctx)
    new_state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
    )
    return new_state, shardings


def main():
    cfg = smoke_config("stablelm_3b")
    model = Model(cfg)
    rt = ElasticRuntime(
        pool=DevicePool(),
        method=Method.MERGE,
        strategy=Strategy.PARALLEL_HYPERCUBE,
        initial_nodes=1,
    )
    data = SyntheticTokens(cfg, batch=8, seq=64)
    losses = []

    def ctx_now():
        return ShardingContext(mesh=rt.mesh(("data",)), mode="train")

    ctx = ctx_now()
    from repro.api import train_state_shardings

    _, shardings = train_state_shardings(model, ctx)
    init_fn, _ = build_init_fn(model, ctx)
    state = init_fn(jax.random.key(0))
    step = make_step(model, ctx, shardings)

    def run(n, start):
        nonlocal state
        for i in range(start, start + n):
            batch = make_batch_on_mesh(data.sample(i), cfg, ctx)
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        print(f"  steps {start}..{start+n-1}: loss {losses[-1]:.4f} "
              f"on {rt.n_nodes} node(s)")

    print("== phase 1: 1 node ==")
    run(10, 0)

    for target in (4, 8):
        rec = rt.expand(target)
        print(f"== EXPAND -> {target} nodes: {rec.mechanism}, "
              f"{rec.steps} spawn rounds, est wall {rec.est_wall_s*1e3:.0f} ms ==")
        ctx = ctx_now()
        old = state
        state, shardings = resharded(state, model, ctx)
        stats = transfer_stats(old.params, state.params)
        print(f"  redistribution: {stats['bytes_moved']/1e6:.2f} MB moved, "
              f"{stats['bytes_stayed']/1e6:.2f} MB stayed local")
        step = make_step(model, ctx, shardings)
        run(10, len(losses))

    rec = rt.shrink(6)
    print(f"== SHRINK -> {rt.n_nodes} nodes via {rec.mechanism}: "
          f"est wall {rec.est_wall_s*1e3:.2f} ms, returned {rec.nodes_returned} ==")
    ctx = ctx_now()
    state, shardings = resharded(state, model, ctx)
    step = make_step(model, ctx, shardings)
    run(10, len(losses))

    victim = sorted(rt.state.nodes_in_use())[-1]
    rec = rt.fail_node(victim)
    print(f"== NODE {victim} FAILED -> TS recovery, {rt.n_nodes} node(s) left ==")
    ctx = ctx_now()
    state, shardings = resharded(state, model, ctx)
    step = make_step(model, ctx, shardings)
    run(10, len(losses))

    # loss continuity: no resize may cause a jump bigger than normal noise
    arr = np.array(losses)
    deltas = np.abs(np.diff(arr))
    resize_points = [10, 20, 30, 40]
    noise = np.percentile(deltas, 95)
    for p in resize_points:
        assert deltas[p - 1] <= max(3 * noise, 0.5), (p, deltas[p - 1], noise)
    print(f"\nloss continuous across {len(resize_points)} resizes "
          f"({arr[0]:.3f} -> {arr[-1]:.3f}); history:")
    for r in rt.history:
        print(f"  {r.kind:<10} {r.mechanism:<22} {r.nodes_before}->{r.nodes_after} "
              f"est {r.est_wall_s*1e3:.2f} ms")


if __name__ == "__main__":
    main()
