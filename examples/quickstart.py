"""Quickstart: train a reduced-config model for a few steps on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch stablelm_3b]
"""
import argparse
import time

import jax

from repro.api import (
    Model,
    ShardingContext,
    SyntheticTokens,
    build_init_fn,
    build_train_step,
    make_batch_on_mesh,
    make_host_mesh,
    smoke_config,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = Model(cfg)
    mesh = make_host_mesh()
    ctx = ShardingContext(mesh=mesh, mode="train")

    step_fn, shardings, _ = build_train_step(model, ctx, lr=1e-3)
    init_fn, _ = build_init_fn(model, ctx)
    state = init_fn(jax.random.key(0))
    step = jax.jit(step_fn, in_shardings=(shardings, None),
                   out_shardings=(shardings, None), donate_argnums=(0,))

    data = SyntheticTokens(cfg, batch=8, seq=64)
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch_on_mesh(data.sample(i), cfg, ctx)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if i % 5 == 0:
            print(f"step {i:>3}  loss {losses[-1]:.4f}")
    print(f"\n{args.arch}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {args.steps} steps ({time.time()-t0:.1f}s)")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
