"""Elastic decode serving demo: traffic-driven reconfiguration.

Replays the registered serve traffic traces (diurnal load, flash crowd,
tail-latency SLO breach) through the elastic decode service
(:mod:`repro.serving`): the pool of decode workers is grown/shrunk by
the traffic policy through the ReconfigEngine, in-flight KV caches are
migrated — never dropped — on every resize, and the migration is priced
as REDISTRIBUTION bytes.  Each trace runs on BOTH executors (simulator
and live NodeGroup runtime); the script prints per-phase
latency/throughput and **exits non-zero if they disagree on any
number**, like ``examples/malleability_sim.py``.

    PYTHONPATH=src python examples/serve.py [--scenario serve-diurnal]
    PYTHONPATH=src python examples/serve.py --static [--arch gemma2_9b]

``--static`` keeps the original single-shot demo: prefill + decode with
a KV cache on the host's devices, TS-shrinking the fleet between
batches and verifying identical generations.
"""
import argparse
import sys


def static_demo(args) -> int:
    """The original single-shot decode demo (JAX imported lazily)."""
    import os

    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=4")

    import time

    import jax
    import jax.numpy as jnp

    from repro.api import (
        DevicePool,
        ElasticRuntime,
        Method,
        Model,
        ShardingContext,
        Strategy,
        smoke_config,
        use_sharding,
    )

    def sample_greedy(logits):
        return jnp.argmax(logits[:, -1], axis=-1)[:, None]

    cfg = smoke_config(args.arch).replace(embed_inputs=False)
    model = Model(cfg)
    rt = ElasticRuntime(pool=DevicePool(), method=Method.MERGE,
                        strategy=Strategy.PARALLEL_HYPERCUBE, initial_nodes=1)
    rt.expand(4)
    print(f"serving fleet: {rt.n_nodes} node-groups")

    params, _ = model.init(jax.random.key(0))
    B, P, G = args.batch, args.prompt_len, args.gen_len
    max_len = P + G
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)

    ShardingContext(mesh=rt.mesh(("data",)), mode="decode")

    def serve_batch(params, prompts):
        cache = model.init_cache(B, max_len)
        decode = jax.jit(model.decode_step)
        toks = prompts[:, :1]
        out = [toks]
        t0 = time.time()
        with use_sharding(None):  # host demo: default placement
            # prefill token-by-token (teacher forcing over the prompt)
            for t in range(P):
                tok = {"tokens": prompts[:, t:t + 1],
                       "positions": jnp.full((B, 1), t, jnp.int32),
                       "cache_pos": jnp.int32(t)}
                logits, cache = decode(params, cache, tok)
            t_prefill = time.time() - t0
            nxt = sample_greedy(logits)
            out.append(nxt)
            t0 = time.time()
            for t in range(P, P + G - 1):
                tok = {"tokens": nxt,
                       "positions": jnp.full((B, 1), t, jnp.int32),
                       "cache_pos": jnp.int32(t)}
                logits, cache = decode(params, cache, tok)
                nxt = sample_greedy(logits)
                out.append(nxt)
            t_decode = time.time() - t0
        gen = jnp.concatenate(out[1:], axis=1)
        return gen, t_prefill, t_decode

    gen, tp, td = serve_batch(params, prompts)
    print(f"batch 1: prefill {tp:.2f}s, decode {td:.2f}s "
          f"({B * G / max(td, 1e-9):.1f} tok/s), output shape {gen.shape}")

    # Autoscale down between batches: TS-shrink half the fleet.
    rec = rt.shrink(2)
    print(f"autoscale: TS shrink -> {rt.n_nodes} nodes in est "
          f"{rec.est_wall_s * 1e3:.2f} ms (nodes {rec.nodes_returned} returned)")

    gen2, tp2, td2 = serve_batch(params, prompts)
    assert bool(jnp.all(gen == gen2)), "generation must be identical after shrink"
    print(f"batch 2 (post-shrink): identical output verified; "
          f"decode {td2:.2f}s")
    return 0


def elastic_demo(args) -> int:
    """Replay serve traces sim + live; count disagreements."""
    from repro.api import SERVE_SCENARIO_NAMES, run_elastic

    names = (SERVE_SCENARIO_NAMES if args.scenario == "all"
             else (args.scenario,))
    return run_elastic(names, "both", args.strategy)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--static", action="store_true",
                    help="original single-shot decode demo")
    ap.add_argument("--arch", default="gemma2_9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--scenario", default="all",
                    help="serve trace name, or 'all'")
    ap.add_argument("--strategy", default=None,
                    help="spawn strategy override")
    args = ap.parse_args()
    return static_demo(args) if args.static else elastic_demo(args)


if __name__ == "__main__":
    sys.exit(main())
