"""Batched serving demo: prefill + decode with a KV cache.

Serves a reduced-config model over synthetic prompts, batching requests,
and demonstrates a TS-shrink of the serving fleet between batches (the
paper's mechanism applied to inference autoscaling).

    PYTHONPATH=src python examples/serve.py [--arch gemma2_9b]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core import Method, Strategy
from repro.elastic import DevicePool, ElasticRuntime
from repro.models import Model
from repro.parallel.sharding import ShardingContext, use_sharding


def sample_greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(embed_inputs=False)
    model = Model(cfg)
    rt = ElasticRuntime(pool=DevicePool(), method=Method.MERGE,
                        strategy=Strategy.PARALLEL_HYPERCUBE, initial_nodes=1)
    rt.expand(4)
    print(f"serving fleet: {rt.n_nodes} node-groups")

    params, _ = model.init(jax.random.key(0))
    B, P, G = args.batch, args.prompt_len, args.gen_len
    max_len = P + G
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)

    ctx = ShardingContext(mesh=rt.mesh(("data",)), mode="decode")

    def serve_batch(params, prompts):
        cache = model.init_cache(B, max_len)
        decode = jax.jit(model.decode_step)
        toks = prompts[:, :1]
        out = [toks]
        t0 = time.time()
        with use_sharding(None):  # host demo: default placement
            # prefill token-by-token (teacher forcing over the prompt)
            for t in range(P):
                tok = {"tokens": prompts[:, t:t + 1],
                       "positions": jnp.full((B, 1), t, jnp.int32),
                       "cache_pos": jnp.int32(t)}
                logits, cache = decode(params, cache, tok)
            t_prefill = time.time() - t0
            nxt = sample_greedy(logits)
            out.append(nxt)
            t0 = time.time()
            for t in range(P, P + G - 1):
                tok = {"tokens": nxt,
                       "positions": jnp.full((B, 1), t, jnp.int32),
                       "cache_pos": jnp.int32(t)}
                logits, cache = decode(params, cache, tok)
                nxt = sample_greedy(logits)
                out.append(nxt)
            t_decode = time.time() - t0
        gen = jnp.concatenate(out[1:], axis=1)
        return gen, t_prefill, t_decode

    gen, tp, td = serve_batch(params, prompts)
    print(f"batch 1: prefill {tp:.2f}s, decode {td:.2f}s "
          f"({B * G / max(td, 1e-9):.1f} tok/s), output shape {gen.shape}")

    # Autoscale down between batches: TS-shrink half the fleet.
    rec = rt.shrink(2)
    print(f"autoscale: TS shrink -> {rt.n_nodes} nodes in est "
          f"{rec.est_wall_s * 1e3:.2f} ms (nodes {rec.nodes_returned} returned)")

    gen2, tp2, td2 = serve_batch(params, prompts)
    assert bool(jnp.all(gen == gen2)), "generation must be identical after shrink"
    print(f"batch 2 (post-shrink): identical output verified; "
          f"decode {td2:.2f}s")


if __name__ == "__main__":
    main()
