"""Reconfiguration-cost explorer: the paper's §5 on the engine.

Prints the preferred-method grid (paper Fig. 5) for a chosen cluster
profile — candidates come from the engine's strategy registry — shows
the event timeline for one expansion (bytes-moved included), and can
replay any registered declarative scenario.

Doubles as a smoke check: every replay (and the final sweep in the
default mode) runs the trace through BOTH the simulator and the live
bookkeeping runtime — heterogeneous uneven-width pools included — and
exits non-zero if any per-event wall time, downtime, queue, or
per-link bytes number disagrees.

    PYTHONPATH=src python examples/malleability_sim.py [--profile mn5|nasp]
    PYTHONPATH=src python examples/malleability_sim.py --scenario burst-arrival
    PYTHONPATH=src python examples/malleability_sim.py --list-scenarios
"""
import argparse
import sys

from repro.api import (
    MN5,
    NASP,
    Method,
    ReconfigEngine,
    ShrinkKind,
    Strategy,
    get_scenario,
    plan_hypercube,
    record_parity_key,
    registered_scenarios,
    registered_strategies,
    run_scenario_live,
    run_scenario_sim,
    simulate_expansion,
    simulate_shrink,
)


def preferred_grid(cm, C, nodes):
    print(f"(rows I, cols N; upper triangle = expand, lower = TS shrink)\n")
    header = "I\\N " + "".join(f"{n:>12}" for n in nodes)
    print(header)
    engine = ReconfigEngine(cost_model=cm)
    for i in nodes:
        row = [f"{i:<4}"]
        for n in nodes:
            if n == i:
                row.append(f"{'—':>12}")
                continue
            if n > i:
                cand = {}
                for spec in registered_strategies():
                    label = ("M" if spec.key == "sequential" else f"M+{spec.key}")
                    plan = engine.plan_expand(
                        i * C, n * C, C, strategy=spec.key, method=Method.MERGE)
                    cand[label] = simulate_expansion(plan.spawn, cm).total
            else:
                cand = {
                    "M+TS": simulate_shrink(
                        ShrinkKind.TS, cm, ns=i * C, nt=n * C,
                        doomed_world_sizes=[C] * (i - n)).total,
                    "B+par": simulate_shrink(
                        ShrinkKind.SS, cm, ns=i * C, nt=n * C,
                        respawn_plan=plan_hypercube(i * C, n * C, C, Method.BASELINE),
                    ).total,
                }
            row.append(f"{min(cand, key=cand.get):>12}")
        print("".join(row))


def show_timeline(cm, C):
    print("\nevent timeline, expansion 1 -> 32 nodes (parallel Merge):")
    engine = ReconfigEngine(cost_model=cm, strategy=Strategy.PARALLEL_HYPERCUBE)
    plan = engine.plan_expand(C, 32 * C, C)
    tl = engine.timeline(plan)
    for e in tl.events:
        flag = (f" (overlap {e.overlap_fraction:.0%})" if e.overlappable else "")
        moved = f"  moved {e.bytes_moved/1e6:.1f} MB" if e.bytes_moved else ""
        print(f"  {e.start*1e3:9.2f} -> {e.end*1e3:9.2f} ms  "
              f"{e.stage.value:<10} {e.label}{flag}{moved}")
    print(f"  total {tl.total*1e3:.2f} ms, "
          f"ASYNC downtime {tl.downtime(asynchronous=True)*1e3:.2f} ms "
          f"({plan.spawn.steps} spawn rounds, {len(plan.spawn.groups)} groups)")
    ts = simulate_shrink(ShrinkKind.TS, cm, ns=32 * C, nt=C,
                         doomed_world_sizes=[C] * 31)
    print(f"\nTS shrink 32 -> 1: {ts.total*1e3:.3f} ms "
          f"({tl.total/ts.total:.0f}x faster than the expansion)")


# The canonical parity tuple lives next to ScenarioRecord, so this gate
# and the test suite always compare the same field set.
_record_key = record_parity_key


def check_sim_live_agreement(scenarios, sim_records=None) -> int:
    """Run every scenario through both executors; report diffs.

    Heterogeneous traces included: the live pool partitions with the
    scenario's uneven width vector.  ``sim_records`` optionally maps
    scenario name -> already-computed simulator records, so callers that
    just simmed a trace don't pay for a rerun.
    """
    events = 0
    bad = 0
    checked = 0
    for sc in scenarios:
        checked += 1
        sim = [_record_key(r) for r in
               (sim_records or {}).get(sc.name) or run_scenario_sim(sc)]
        live = [_record_key(r) for r in run_scenario_live(sc)]
        diffs = [(s, l) for s, l in zip(sim, live) if s != l] + (
            [("length", (len(sim), len(live)))] if len(sim) != len(live) else [])
        events += len(sim)
        if diffs:
            bad += 1
            print(f"SIM/LIVE DISAGREEMENT in {sc.name!r}:", file=sys.stderr)
            for s, l in diffs:
                print(f"  sim={s}\n  live={l}", file=sys.stderr)
    if bad:
        return 1
    print(f"sim/live agreement OK ({checked} scenarios, "
          f"{events} events, per-class bytes included)")
    return 0


def replay_scenario(name):
    sc = get_scenario(name)
    print(f"scenario {sc.name!r}: {sc.description}")
    print(f"  pool: {sc.core_pool or f'{sc.cores_per_node} cores/node'}, "
          f"initial {sc.initial_nodes} nodes, profile {sc.profile}"
          + (f", pytree {sc.resolved_param_bytes()/1e9:.2f} GB ({sc.arch})"
             if sc.resolved_param_bytes() else ""))
    total = down = 0.0
    moved = 0
    records = run_scenario_sim(sc)
    for rec in records:
        print(f"  step {rec.step:>3} {rec.kind:<10} {rec.mechanism:<22} "
              f"{rec.nodes_before}->{rec.nodes_after} nodes  "
              f"total {rec.est_wall_s*1e3:9.3f} ms  "
              f"downtime {rec.downtime_s*1e3:9.3f} ms  "
              f"moved {rec.bytes_moved/1e6:10.1f} MB")
        total += rec.est_wall_s
        down += rec.downtime_s
        moved += rec.bytes_moved
    print(f"  cumulative reconfiguration {total*1e3:.2f} ms, "
          f"downtime {down*1e3:.2f} ms, {moved/1e9:.2f} GB moved")
    sys.exit(check_sim_live_agreement([sc], sim_records={sc.name: records}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=["mn5", "nasp"], default="mn5")
    ap.add_argument("--cores", type=int, default=112)
    ap.add_argument("--scenario", default=None,
                    help="replay a registered declarative scenario")
    ap.add_argument("--list-scenarios", action="store_true")
    args = ap.parse_args()

    if args.list_scenarios:
        for sc in registered_scenarios():
            print(f"{sc.name:<18} {sc.description}")
        return
    if args.scenario:
        replay_scenario(args.scenario)
        return

    cm = MN5 if args.profile == "mn5" else NASP
    nodes = [1, 2, 4, 8, 16, 24, 32]
    print(f"preferred method per (I -> N), profile={args.profile}, C={args.cores}")
    preferred_grid(cm, args.cores, nodes)
    show_timeline(cm, args.cores)
    print()
    sys.exit(check_sim_live_agreement(list(registered_scenarios())))


if __name__ == "__main__":
    main()
