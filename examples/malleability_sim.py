"""Reconfiguration-cost explorer: the paper's §5 on the simulator.

Prints the preferred-method grid (paper Fig. 5) for a chosen cluster
profile and shows the phase breakdown for one expansion.

    PYTHONPATH=src python examples/malleability_sim.py [--profile mn5|nasp]
"""
import argparse
import itertools

from repro.core import Method, ShrinkKind, plan_hypercube, plan_sequential
from repro.malleability import MN5, NASP, simulate_expansion, simulate_shrink


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=["mn5", "nasp"], default="mn5")
    ap.add_argument("--cores", type=int, default=112)
    args = ap.parse_args()
    cm = MN5 if args.profile == "mn5" else NASP
    C = args.cores
    nodes = [1, 2, 4, 8, 16, 24, 32]

    print(f"preferred method per (I -> N), profile={args.profile}, C={C}")
    print("(rows I, cols N; upper triangle = expand, lower = TS shrink)\n")
    header = "I\\N " + "".join(f"{n:>8}" for n in nodes)
    print(header)
    for i in nodes:
        row = [f"{i:<4}"]
        for n in nodes:
            if n == i:
                row.append(f"{'—':>8}")
                continue
            if n > i:
                cand = {
                    "M": simulate_expansion(
                        plan_sequential(i * C, n * C, [C] * n, Method.MERGE), cm).total,
                    "M+par": simulate_expansion(
                        plan_hypercube(i * C, n * C, C, Method.MERGE), cm).total,
                }
            else:
                cand = {
                    "M+TS": simulate_shrink(
                        ShrinkKind.TS, cm, ns=i * C, nt=n * C,
                        doomed_world_sizes=[C] * (i - n)).total,
                    "B+par": simulate_shrink(
                        ShrinkKind.SS, cm, ns=i * C, nt=n * C,
                        respawn_plan=plan_hypercube(i * C, n * C, C, Method.BASELINE),
                    ).total,
                }
            row.append(f"{min(cand, key=cand.get):>8}")
        print("".join(row))

    print("\nphase breakdown, expansion 1 -> 32 nodes (parallel Merge):")
    rep = simulate_expansion(plan_hypercube(C, 32 * C, C, Method.MERGE), cm)
    for k in ("t_spawn", "t_sync", "t_connect", "t_reorder", "t_final"):
        print(f"  {k:<10} {getattr(rep, k)*1e3:9.2f} ms")
    print(f"  {'total':<10} {rep.total*1e3:9.2f} ms "
          f"({rep.steps} spawn rounds, {rep.groups} groups)")
    ts = simulate_shrink(ShrinkKind.TS, cm, ns=32 * C, nt=C,
                         doomed_world_sizes=[C] * 31)
    print(f"\nTS shrink 32 -> 1: {ts.total*1e3:.3f} ms "
          f"({rep.total/ts.total:.0f}x faster than the expansion)")


if __name__ == "__main__":
    main()
