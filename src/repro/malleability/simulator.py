"""Timeline-charging backend for reconfiguration cost (paper §5).

The phase math lives in :mod:`repro.core.engine`: every plan is executed
as an explicit event timeline (spawn rounds, tree synchronization, binary
connection rounds, reordering, final intercomm; TS/ZS/SS for shrinks)
charged with the :class:`CostModel`.  This module is the report-shaped
view over those timelines — :class:`ExpansionReport` / :class:`ShrinkReport`
read *every* number (per-phase spans, total, ASYNC downtime) off the
timeline, so they can never disagree with the elastic runtime's
:class:`~repro.elastic.runtime.ReconfigRecord`, which reads the same one.

The event structure mirrors §4.6's task lists, so per-phase output is
directly comparable to the paper's discussion (e.g. "overhead grows when
more than 8 groups are created": that is the connect phase growing with
ceil(log2 G) unbalanced rounds).

Reports price reconfiguration only.  The symmetric question — what each
step of the horizon costs under the allocation a reconfiguration leaves
behind — belongs to :mod:`repro.malleability.throughput`; the scenario
executors compose the two into ``time_to_result_s`` so a cheap shrink
that halves step throughput stops looking like a good trade.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Union

from repro.core import (
    ShrinkKind,
    SpawnPlan,
    Stage,
    Timeline,
    expansion_timeline,
    shrink_timeline,
    strategy_key,
)
from repro.core.types import Method, Strategy

from .cost_model import CostModel


@dataclass(frozen=True)
class ExpansionReport:
    """Per-phase breakdown of one charged expansion timeline."""

    strategy: Union[Strategy, str]
    method: Method
    ns: int
    nt: int
    t_spawn: float
    t_sync: float
    t_connect: float
    t_reorder: float
    t_final: float
    total: float
    downtime: float      # app-visible stall (== total unless Async overlaps)
    steps: int
    groups: int
    timeline: Timeline = field(default_factory=Timeline, repr=False, compare=False)
    t_redist: float = 0.0
    bytes_moved: int = 0
    t_queue: float = 0.0
    bytes_stayed: int = 0
    bytes_cross_rack: int = 0
    bytes_cross_pod: int = 0

    def as_row(self) -> dict:
        """Report as a flat dict row (benchmark CSV shape)."""
        return {
            "strategy": strategy_key(self.strategy),
            "method": self.method.value,
            "ns": self.ns,
            "nt": self.nt,
            "queue_s": round(self.t_queue, 6),
            "spawn_s": round(self.t_spawn, 6),
            "sync_s": round(self.t_sync, 6),
            "connect_s": round(self.t_connect, 6),
            "reorder_s": round(self.t_reorder, 6),
            "final_s": round(self.t_final, 6),
            "redist_s": round(self.t_redist, 6),
            "total_s": round(self.total, 6),
            "downtime_s": round(self.downtime, 6),
            "bytes_moved": self.bytes_moved,
            "bytes_stayed": self.bytes_stayed,
            "bytes_cross_rack": self.bytes_cross_rack,
            "bytes_cross_pod": self.bytes_cross_pod,
            "steps": self.steps,
            "groups": self.groups,
        }


@dataclass(frozen=True)
class ShrinkReport:
    """Total + mechanism detail of one charged shrink timeline."""

    kind: ShrinkKind
    total: float
    nodes_returned: int
    nodes_pinned: int
    detail: dict = field(default_factory=dict)
    timeline: Timeline = field(default_factory=Timeline, repr=False, compare=False)
    bytes_moved: int = 0
    bytes_stayed: int = 0
    bytes_cross_rack: int = 0
    bytes_cross_pod: int = 0
    bytes_restored: int = 0   # shards re-read from the last checkpoint
    restored_s: float = 0.0   # RESTORE span charged on the timeline


def simulate_expansion(
    plan: SpawnPlan, cm: CostModel, asynchronous: bool = False,
    bytes_total: int = 0, queue_delay_s: float = 0.0, bytes_stayed: int = 0,
    bytes_cross_rack: int = 0, bytes_cross_pod: int = 0,
) -> ExpansionReport:
    """Charge one expansion plan and report its per-phase breakdown.

    Args:
        plan: the spawn plan to charge.
        cm: cost model (latencies, bandwidths, overlap fractions).
        asynchronous: report ASYNC downtime (partial overlap) instead of
            the full wall time.
        bytes_total: stage-3 cross-link data volume to charge as a
            REDISTRIBUTION event (0 skips the event).
        queue_delay_s: RMS arbitration wait charged as a leading QUEUE
            event (0 skips the event).
        bytes_stayed: stage-3 local-link volume (per-link pricing).
        bytes_cross_rack: rack-crossing portion of ``bytes_total``
            (distance-class pricing; the rest rides the intra-rack link).
        bytes_cross_pod: pod-crossing slice of ``bytes_cross_rack``.
    Returns:
        An :class:`ExpansionReport` whose every field is a read of the
        charged :class:`~repro.core.Timeline`.
    """
    tl = expansion_timeline(plan, cm, bytes_total=bytes_total,
                            queue_delay_s=queue_delay_s,
                            bytes_stayed=bytes_stayed,
                            bytes_cross_rack=bytes_cross_rack,
                            bytes_cross_pod=bytes_cross_pod)
    return ExpansionReport(
        strategy=plan.strategy,
        method=plan.method,
        ns=plan.ns,
        nt=plan.nt,
        t_spawn=tl.span(Stage.SPAWN),
        t_sync=tl.span(Stage.SYNC),
        t_connect=tl.span(Stage.CONNECT),
        t_reorder=tl.span(Stage.REORDER),
        t_final=tl.span(Stage.FINAL),
        total=tl.total,
        downtime=tl.downtime(asynchronous),
        steps=plan.steps,
        groups=len(plan.groups),
        timeline=tl,
        t_redist=tl.span(Stage.REDISTRIBUTION),
        bytes_moved=tl.bytes_moved,
        t_queue=tl.queued_s,
        bytes_stayed=tl.bytes_stayed,
        bytes_cross_rack=tl.bytes_cross_rack,
        bytes_cross_pod=tl.bytes_cross_pod,
    )


def simulate_shrink(
    kind: ShrinkKind,
    cm: CostModel,
    ns: int,
    nt: int,
    doomed_world_sizes: list[int] | None = None,
    respawn_plan: SpawnPlan | None = None,
    nodes_returned: int = 0,
    nodes_pinned: int = 0,
    bytes_total: int = 0,
    bytes_stayed: int = 0,
    bytes_cross_rack: int = 0,
    bytes_cross_pod: int = 0,
    restore_bytes: int = 0,
) -> ShrinkReport:
    """Charge one shrink by mechanism (TS / ZS / SS) off its timeline.

    ``bytes_total`` > 0 (cross link) or ``bytes_stayed`` > 0 (local
    link) additionally charges the survivors' absorption of the doomed
    ranks' shards as a REDISTRIBUTION event; ``bytes_cross_rack`` is the
    rack-crossing portion of ``bytes_total`` (distance-class pricing).
    ``restore_bytes`` > 0 charges recovering that much of the last
    checkpoint as a trailing RESTORE event (failure recovery).
    """
    tl = shrink_timeline(
        kind,
        cm,
        ns=ns,
        nt=nt,
        doomed_world_sizes=doomed_world_sizes,
        respawn_plan=respawn_plan,
        bytes_total=bytes_total,
        bytes_stayed=bytes_stayed,
        bytes_cross_rack=bytes_cross_rack,
        bytes_cross_pod=bytes_cross_pod,
        restore_bytes=restore_bytes,
    )
    if kind is ShrinkKind.TS:
        detail = {"worlds_terminated": len(doomed_world_sizes or [])}
    elif kind is ShrinkKind.ZS:
        detail = {"zombified": ns - nt}
    elif respawn_plan is not None:
        detail = {"respawn_total_s": tl.total - tl.span(Stage.TEARDOWN)}
    else:
        detail = {}
    return ShrinkReport(
        kind=kind,
        total=tl.total,
        nodes_returned=nodes_returned,
        nodes_pinned=nodes_pinned,
        detail=detail,
        timeline=tl,
        bytes_moved=tl.bytes_moved,
        bytes_stayed=tl.bytes_stayed,
        bytes_cross_rack=tl.bytes_cross_rack,
        bytes_cross_pod=tl.bytes_cross_pod,
        bytes_restored=tl.bytes_restored,
        restored_s=tl.restored_s,
    )


def simulate_redistribution(cm: CostModel, total_bytes: int,
                            stayed_bytes: int = 0,
                            cross_rack_bytes: int = 0,
                            cross_pod_bytes: int = 0) -> float:
    """Stage-3 wall time for one redistribution (setup + per-class bw)."""
    return cm.redistribution(total_bytes, stayed_bytes, cross_rack_bytes,
                             cross_pod_bytes)
