"""Event/phase-driven simulator for reconfiguration cost (paper §5).

Executes a :class:`repro.core.SpawnPlan` phase by phase — spawn rounds,
tree synchronization, binary connection, reordering, final intercomm —
charging each phase with the :class:`CostModel`.  Shrinks are charged per
mechanism (TS / ZS / SS).  The phase structure mirrors §4.6's task lists,
so per-phase output is directly comparable to the paper's discussion
(e.g. "overhead grows when more than 8 groups are created": that is the
connect phase growing with ceil(log2 G) unbalanced rounds).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.core import (
    Method,
    ShrinkKind,
    SpawnPlan,
    Strategy,
    binary_connection_schedule,
)

from .cost_model import CostModel


@dataclass(frozen=True)
class ExpansionReport:
    strategy: Strategy
    method: Method
    ns: int
    nt: int
    t_spawn: float
    t_sync: float
    t_connect: float
    t_reorder: float
    t_final: float
    total: float
    downtime: float      # app-visible stall (== total unless Async overlaps)
    steps: int
    groups: int

    def as_row(self) -> dict:
        return {
            "strategy": self.strategy.value,
            "method": self.method.value,
            "ns": self.ns,
            "nt": self.nt,
            "spawn_s": round(self.t_spawn, 6),
            "sync_s": round(self.t_sync, 6),
            "connect_s": round(self.t_connect, 6),
            "reorder_s": round(self.t_reorder, 6),
            "final_s": round(self.t_final, 6),
            "total_s": round(self.total, 6),
            "downtime_s": round(self.downtime, 6),
            "steps": self.steps,
            "groups": self.groups,
        }


@dataclass(frozen=True)
class ShrinkReport:
    kind: ShrinkKind
    total: float
    nodes_returned: int
    nodes_pinned: int
    detail: dict = field(default_factory=dict)


def _spawn_phase(plan: SpawnPlan, cm: CostModel) -> float:
    """Wall time of the spawn phase according to the plan's strategy."""
    if not plan.groups:
        return 0.0
    if plan.strategy is Strategy.SEQUENTIAL or plan.strategy is Strategy.SINGLE:
        g = plan.groups[0]
        t = cm.spawn_call(g.size, len(g.nodes_spanned()))
        if plan.strategy is Strategy.SINGLE:
            # rank 0 informs the rest afterwards (MaM Single strategy)
            t += cm.t_token * math.ceil(math.log2(max(plan.ns, 2)))
        return t
    if plan.strategy is Strategy.SEQUENTIAL_PER_NODE:
        return sum(cm.spawn_call(g.size, 1) for g in plan.groups)
    # Parallel strategies: rounds of concurrent single-node spawns.
    total = 0.0
    initial_nodes = sum(1 for r in plan.running if r > 0)
    for s in range(1, plan.steps + 1):
        round_groups = plan.groups_in_step(s)
        if not round_groups:
            continue
        oversub = plan.method is Method.BASELINE and any(
            g.node < initial_nodes for g in round_groups
        )
        total += cm.concurrent_round(
            [(g.size, 1) for g in round_groups], oversubscribed=oversub
        )
    return total


def _sync_phase(plan: SpawnPlan, cm: CostModel) -> float:
    """§4.3 three-stage synchronization along the spawn tree.

    Critical path: deepest leaf sends up through ``depth`` levels (token +
    per-group barrier each), source barriers, then the release token walks
    back down the same depth.
    """
    if plan.strategy not in (Strategy.PARALLEL_HYPERCUBE, Strategy.PARALLEL_DIFFUSIVE):
        return 0.0
    if not plan.groups:
        return 0.0
    depth = plan.steps
    max_group = max(plan.group_sizes)
    per_level = cm.t_token + cm.barrier(max_group) + cm.comm_split(max_group)
    ports = cm.t_port  # opened concurrently by all acceptor roots
    return ports + per_level + depth * 2 * (cm.t_token + cm.barrier(max_group))


def _connect_phase(plan: SpawnPlan, cm: CostModel) -> float:
    """§4.4 binary connection: ceil(log2 G) rounds of pairwise merges."""
    if plan.strategy not in (Strategy.PARALLEL_HYPERCUBE, Strategy.PARALLEL_DIFFUSIVE):
        return 0.0
    sizes = {g.gid: g.size for g in plan.groups}
    total = 0.0
    for rnd in binary_connection_schedule(len(plan.groups)):
        round_cost = 0.0
        for acc, conn in rnd.pairs:
            merged = sizes[acc] + sizes[conn]
            round_cost = max(round_cost, cm.connect_merge(merged))
            sizes[acc] = merged
            del sizes[conn]
        total += round_cost
    return total


def simulate_expansion(
    plan: SpawnPlan, cm: CostModel, asynchronous: bool = False
) -> ExpansionReport:
    t_spawn = _spawn_phase(plan, cm)
    t_sync = _sync_phase(plan, cm)
    t_connect = _connect_phase(plan, cm)
    parallel = plan.strategy in (
        Strategy.PARALLEL_HYPERCUBE,
        Strategy.PARALLEL_DIFFUSIVE,
    )
    t_reorder = cm.comm_split(sum(plan.group_sizes)) if parallel else 0.0
    # Final sources<->children intercomm (all strategies pay a merge of the
    # full target world; the classic strategies do it inside the spawn call
    # via the intercommunicator MPI_Comm_spawn returns).
    t_final = cm.connect_merge(plan.nt) if parallel else cm.beta_connect * plan.nt
    total = t_spawn + t_sync + t_connect + t_reorder + t_final
    # MaM's Async strategy overlaps the spawn phase with app compute; the
    # app only stalls for sync + connect + reorder + final.
    downtime = total - t_spawn if asynchronous else total
    return ExpansionReport(
        strategy=plan.strategy,
        method=plan.method,
        ns=plan.ns,
        nt=plan.nt,
        t_spawn=t_spawn,
        t_sync=t_sync,
        t_connect=t_connect,
        t_reorder=t_reorder,
        t_final=t_final,
        total=total,
        downtime=downtime,
        steps=plan.steps,
        groups=len(plan.groups),
    )


def simulate_shrink(
    kind: ShrinkKind,
    cm: CostModel,
    ns: int,
    nt: int,
    doomed_world_sizes: list[int] | None = None,
    respawn_plan: SpawnPlan | None = None,
    nodes_returned: int = 0,
    nodes_pinned: int = 0,
) -> ShrinkReport:
    """Cost of one shrink by mechanism.

    * TS — release tokens to doomed worlds; they exit; root updates its
      structure.  No spawning at all (this is the paper's headline).
    * ZS — same token path, but ranks only go to sleep; nodes stay pinned.
    * SS — the Baseline path: spawn the NT-sized world (optionally with a
      parallel strategy: pass ``respawn_plan``), tear the old world down.
    """
    if kind is ShrinkKind.TS:
        total = cm.ts_terminate(doomed_world_sizes or [1]) + cm.t_token
        detail = {"worlds_terminated": len(doomed_world_sizes or [])}
    elif kind is ShrinkKind.ZS:
        total = cm.t_token * 2  # mark + ack; zombies just stop progressing
        detail = {"zombified": ns - nt}
    else:  # SS
        if respawn_plan is not None:
            exp = simulate_expansion(respawn_plan, cm)
            total = exp.total + cm.t_teardown_per_proc * ns
            detail = {"respawn_total_s": exp.total}
        else:
            total = cm.ss_respawn(nt, max(1, nt // max(ns // max(ns, 1), 1)), ns)
            detail = {}
    return ShrinkReport(
        kind=kind,
        total=total,
        nodes_returned=nodes_returned,
        nodes_pinned=nodes_pinned,
        detail=detail,
    )


def simulate_redistribution(cm: CostModel, total_bytes: int) -> float:
    """Stage-3 data redistribution (sources -> targets)."""
    return cm.redistribution(total_bytes)
