"""Per-allocation step-time model: what an allocation *earns*.

``est_wall`` prices what a reconfiguration *costs*; nothing priced what
the resulting allocation *earns* per application step, so a cheap shrink
that halves step throughput looked like a good trade (ROADMAP item 1).
This module closes that gap with the same three-term roofline the
dry-run analysis uses (``benchmarks/roofline.py``, §Roofline):

* **compute** — ``global_batch x seq_len`` tokens at ``flops_per_token``
  (default ``6 x active params``, the training FLOP rule) over the
  allocation's chips at ``peak_flops``;
* **memory** — the parameter working set streamed once per step at the
  HBM bandwidth (allocation-independent: every chip holds/streams the
  full replicated pytree, matching the engine's replicated bytes model);
* **collective** — the gradient all-reduce, ``2 x param_bytes`` on the
  ICI link, degraded by ``contention x (n - 1)`` as more nodes share
  the fabric.  The base (zero-contention) term is charged at every
  allocation size, so under zero contention adding nodes NEVER
  increases the modeled step time — the monotonicity property
  ``tests/test_throughput.py`` pins.

**Width-weighted batch shares** (Iserte et al., arXiv:2506.14743): on an
uneven ``node_widths`` pool the compute term loads every *chip* equally
— a 4-chip node takes 4x the batch of a 1-chip node — so the step time
follows the pool's total width.  ``width_weighted=False`` reproduces
today's data plane instead (every *node* gets an equal share), where the
narrowest node is the straggler and adding a narrow node can genuinely
slow the step down — the contrast the weighted shares exist to fix.
:func:`batch_shares` is the matching integer data-plane split
(largest-remainder apportionment: shares sum EXACTLY to the global
batch).

The **contention hook** is calibrated, not guessed:
:meth:`ThroughputModel.calibrate` inverts the model against a measured
(overlapped) step time and returns the model with the implied
contention coefficient.

Coupling to the timeline: the ``run_scenario_*`` executors accept
``throughput=`` and accrue ``(steps since the last charged event) x
step_time(allocation)`` into each record's ``time_to_result_s`` (which
otherwise equals ``est_wall_s``);  :func:`time_to_result` sums a run
end to end, including the tail after the last reconfiguration — the
number :class:`~repro.malleability.optimizer.ScheduleObjective`
minimizes when the model is enabled.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Sequence, Tuple

from .scenarios import Scenario, ScenarioRecord, param_bytes_for_arch

#: TPU-class hardware constants (one chip), mirroring the dry-run
#: roofline's HW table (``benchmarks/roofline.py``).
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@functools.lru_cache(maxsize=None)
def flops_per_token_for_arch(arch: str) -> float:
    """Analytic training FLOPs per token: ``6 x active params``.

    The same rule the roofline's ``model_flops_per_device`` applies to
    the train shape.  Resolved lazily (importing the arch config pulls
    jax), so this module stays jax-free to import.
    """
    from repro.configs import arch_config  # local: keep the import device-free

    return 6.0 * arch_config(arch).active_param_count()


def batch_shares(global_batch: int, widths: Sequence[int]) -> Tuple[int, ...]:
    """Integer per-node batch shares, weighted by node width.

    Largest-remainder apportionment: node ``i`` gets
    ``global_batch x widths[i] / sum(widths)`` rounded down, and the
    leftover samples go to the largest fractional remainders (ties to
    the lowest node id — deterministic).  The shares sum EXACTLY to
    ``global_batch`` on every pool, even or uneven — the property the
    data plane needs to never drop or duplicate a sample.
    """
    if global_batch < 0:
        raise ValueError(f"global_batch must be >= 0, got {global_batch}")
    if not widths or min(widths) <= 0:
        raise ValueError(f"widths must be non-empty and positive: {widths!r}")
    total = sum(widths)
    quotas = [global_batch * w / total for w in widths]
    shares = [int(q) for q in quotas]
    leftover = global_batch - sum(shares)
    order = sorted(range(len(widths)),
                   key=lambda i: (shares[i] - quotas[i], i))
    for i in order[:leftover]:
        shares[i] += 1
    return tuple(shares)


@dataclass(frozen=True)
class ThroughputModel:
    """The per-allocation step-time model (hashable, pure data).

    ``flops_per_token=0`` / ``param_bytes=0`` resolve lazily from
    ``arch`` (importing jax); give both explicitly for a device-free
    model.  ``node_widths`` declares the pool's chip widths in node-id
    order; when empty, :meth:`widths_for` falls back to the scenario's
    ``core_pool`` / ``cores_per_node`` widths, so the model prices the
    same pool the executors run against.
    """

    arch: str = ""                      # config for the lazy defaults
    global_batch: int = 256             # the train_4k shape cell
    seq_len: int = 4096
    flops_per_token: float = 0.0        # 0 -> 6 x active params (arch)
    param_bytes: int = 0                # 0 -> param_bytes_for_arch(arch)
    node_widths: Tuple[int, ...] = ()   # uneven pool widths (chips/node)
    width_weighted: bool = True         # False: equal per-node shares
    contention: float = 0.0             # fabric-sharing degradation
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    def resolved_flops_per_token(self) -> float:
        if self.flops_per_token > 0.0:
            return self.flops_per_token
        if not self.arch:
            raise ValueError(
                "ThroughputModel needs flops_per_token or an arch")
        return flops_per_token_for_arch(self.arch)

    def resolved_param_bytes(self) -> int:
        if self.param_bytes > 0:
            return self.param_bytes
        if not self.arch:
            raise ValueError("ThroughputModel needs param_bytes or an arch")
        return param_bytes_for_arch(self.arch)

    def widths_for(self, count: int, core_pool: Sequence[int] = (),
                   default_width: int = 1) -> Tuple[int, ...]:
        """Chip widths of a ``count``-node allocation on this pool.

        Allocations are node-id prefixes in both executors (the greedy
        lowest-free-node order), so the width vector is the prefix of
        ``node_widths`` — or of the scenario's ``core_pool`` when the
        model doesn't pin its own — padded with ``default_width`` past
        the declared pool.
        """
        if count <= 0:
            raise ValueError(f"an allocation needs >= 1 node, got {count}")
        base = tuple(self.node_widths or core_pool)
        if count <= len(base):
            return base[:count]
        pad = default_width if default_width > 0 else 1
        return base + (pad,) * (count - len(base))

    def shares(self, widths: Sequence[int]) -> Tuple[int, ...]:
        """This model's integer data-plane split for an allocation."""
        return batch_shares(self.global_batch, widths)

    def step_time(self, widths: Sequence[int]) -> float:
        """Modeled seconds per application step on an allocation.

        ``compute + memory + collective``.  The compute term uses exact
        fractional width-weighted shares — every chip equally loaded,
        so the term is ``total tokens / total chip throughput`` and
        strictly shrinks as capacity is added.  (The integer
        :func:`batch_shares` split rounds per node; pricing the rounded
        shares would let a narrow added node *raise* the modeled time,
        which is a data-plane artifact, not a capacity statement.)
        ``width_weighted=False`` prices today's equal-per-node shares
        instead: the narrowest node is the straggler.
        """
        widths = tuple(widths)
        if not widths or min(widths) <= 0:
            raise ValueError(f"widths must be non-empty and positive: {widths!r}")
        n = len(widths)
        fpt = self.resolved_flops_per_token()
        pb = self.resolved_param_bytes()
        if self.width_weighted:
            t_compute = (self.global_batch * self.seq_len * fpt
                         / (sum(widths) * self.peak_flops))
        else:
            t_compute = ((self.global_batch / n) * self.seq_len * fpt
                         / (min(widths) * self.peak_flops))
        t_memory = pb / self.hbm_bw
        t_collective = (2.0 * pb / self.ici_bw) * (
            1.0 + self.contention * (n - 1))
        return t_compute + t_memory + t_collective

    def calibrate(self, measured_step_s: float,
                  widths: Sequence[int]) -> "ThroughputModel":
        """The model with ``contention`` fitted to a measured step.

        Inverts :meth:`step_time` against an overlapped run's measured
        step seconds on ``widths``: whatever the zero-contention model
        cannot explain is attributed to fabric sharing, clamped at 0
        (a measurement *faster* than the model calibrates to zero, not
        to a negative coefficient).  Single-node measurements carry no
        contention signal and calibrate to zero.
        """
        widths = tuple(widths)
        n = len(widths)
        base = replace(self, contention=0.0).step_time(widths)
        t_coll = 2.0 * self.resolved_param_bytes() / self.ici_bw
        if n <= 1 or t_coll <= 0.0:
            return replace(self, contention=0.0)
        rho = max(0.0, (measured_step_s - base) / (t_coll * (n - 1)))
        return replace(self, contention=rho)


def time_to_result(records: Sequence[ScenarioRecord], scenario: Scenario,
                   throughput: ThroughputModel) -> float:
    """Modeled end-to-end seconds for one scenario run.

    Charged reconfiguration walls (``est_wall_s``, QUEUE spans included)
    plus modeled compute for every application step of the horizon under
    the allocation in force at that step — the segment after the last
    event (through ``scenario.steps``) included, which is exactly where
    a cheap shrink keeps paying.  Works on records from any executor,
    accrued or not: when the executor already accrued ``throughput=``
    segments, ``sum(r.time_to_result_s for r in records)`` equals this
    value minus the tail segment.
    """
    memo: dict[int, float] = {}

    def st(count: int) -> float:
        t = memo.get(count)
        if t is None:
            t = memo[count] = throughput.step_time(throughput.widths_for(
                count, core_pool=scenario.core_pool,
                default_width=scenario.cores_per_node))
        return t

    total = 0.0
    last = 0
    count = scenario.initial_nodes
    for rec in sorted(records, key=lambda r: r.step):
        if rec.step > last:
            total += (rec.step - last) * st(rec.nodes_before)
            last = rec.step
        total += rec.est_wall_s
        count = rec.nodes_after
    total += max(0, scenario.steps - last) * st(count)
    return total
