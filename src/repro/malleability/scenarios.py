"""Declarative workload scenarios shared by every consumer layer.

A :class:`Scenario` is a pure-data trace of RMS events (grow / shrink /
fail / straggler / checkpoint / restart) against a node pool.  The SAME
object drives:

* the **simulator** — :func:`run_scenario_sim` walks the trace against a
  device-free :class:`ClusterState`, planning each reconfiguration
  through the :class:`~repro.core.engine.ReconfigEngine` and charging
  its event timeline;
* the **elastic runtime / trainer** — :meth:`repro.elastic.rms.SimulatedRMS
  .from_scenario` feeds the identical trace into the live NodeGroup
  backend (:func:`run_scenario_live` for bookkeeping-only runs,
  :class:`~repro.elastic.trainer.ElasticTrainer` for full training);
* the **benchmarks** — iterate :func:`registered_scenarios` instead of
  hard-coding event scripts.

Because both executors plan through the same engine and read cost off
the same timeline, their downtime numbers agree *exactly* — that is the
dedup the engine exists for.

Built-in scenarios model the paper's two testbeds: steady expand/shrink
cycles and burst arrivals (MN5-style homogeneous pools, §5.2), node
failures and straggler churn (the dynamic-awareness motivation, §1), and
heterogeneous-core pools (NASP-style alternating node widths, §5.3).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Callable, Iterable, Optional

if TYPE_CHECKING:  # circular at runtime: throughput imports this module
    from .throughput import ThroughputModel

from repro.core import (
    TOPO_KEY,
    ClusterState,
    Method,
    ReconfigEngine,
    ShrinkKind,
    Strategy,
    Topology,
    apply_shrink,
    strategy_key,
)
from repro.core.topology import split_bytes_by_class

from .cost_model import (
    MN5,
    NASP,
    CostModel,
    replicated_bytes_model,
    replicated_link_model,
)

# Event kinds (string-typed so scenarios stay pure data; they map 1:1 to
# repro.elastic.rms.EventKind values).
GROW = "grow"
SHRINK = "shrink"
FAIL = "fail"
STRAGGLER = "straggler"
CHECKPOINT = "checkpoint"
RESTART = "restart"


@dataclass(frozen=True)
class ScenarioEvent:
    """One RMS decision at a given application step.

    ``queue_delay_s`` is RMS arbitration wait: seconds this resize sat
    queued behind an in-flight reconfiguration (its own job's previous
    event in the same drain, or a co-scheduled job's — see
    :mod:`repro.malleability.policies`).  Both executors charge it as a
    leading QUEUE timeline event, so it raises ``est_wall`` (makespan)
    but never downtime.

    A SHRINK may name explicit victim ``nodes``, or instead give a
    ``target_nodes`` total with no victims: then victim choice is the
    engine's placement decision (highest-id nodes for the classics,
    whole racks first for topology-aware strategies), identically in
    both executors.

    A CHECKPOINT snapshots the full state in place (no allocation
    change); a RESTART is the rigid full-stop baseline — checkpoint,
    stop every world, respawn at ``target_nodes`` (the current count
    when 0), restore from the store.
    """

    step: int
    kind: str                       # grow | shrink | fail | straggler
    #                                 | checkpoint | restart
    target_nodes: int = 0           # GROW: new total; SHRINK: shrink-to
    #                                 total; RESTART: post-restart total
    nodes: tuple[int, ...] = ()     # SHRINK/FAIL/STRAGGLER: victim node ids
    queue_delay_s: float = 0.0      # RMS arbitration wait before stage 2


@functools.lru_cache(maxsize=None)
def param_bytes_for_arch(arch: str) -> int:
    """Total parameter-pytree bytes for a registered architecture config.

    Resolved from the model's abstract (shape-only) params — no weights
    are allocated.  Used by scenarios to size stage-3 redistribution.
    """
    import numpy as np  # local: keep the scenarios module jax-free to import

    from repro.configs import arch_config
    from repro.models import Model

    shapes, _ = Model(arch_config(arch)).abstract_params()
    import jax

    return int(sum(
        int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(shapes)
    ))


@dataclass(frozen=True)
class Scenario:
    """A declarative workload trace over a node pool.

    ``arch`` / ``param_bytes`` size the pytree the trace reshards: the
    default engine charges stage-3 data movement from them, so the same
    trace sweeps redistribution pressure as the model config changes.
    """

    name: str
    description: str
    initial_nodes: int
    events: tuple[ScenarioEvent, ...]
    cores_per_node: int = 1          # homogeneous node width (== devices/node
    #                                  when executed on the live runtime)
    core_pool: tuple[int, ...] = ()  # heterogeneous A vector; the live
    #                                  DevicePool partitions its devices with
    #                                  the same uneven widths (node_widths)
    steps: int = 20                  # application steps the trace spans
    profile: str = "mn5"             # default cost-model profile
    arch: str = ""                   # model config whose pytree the trace moves
    param_bytes: int = 0             # explicit pytree size (overrides arch)
    contention: float = 0.0          # >0 overrides the cost model's overlap
    #                                  contention (multi-job interference
    #                                  degrades how well ASYNC hides work)
    redist_bw_local: float = 0.0     # per-link stage-3 bandwidths; >0 splits
    redist_bw_cross: float = 0.0     # the profile's aggregate redist_bw and
    #                                  switches the default engine to the
    #                                  link-aware (stayed+moved) bytes model
    redist_bw_intra_rack: float = 0.0  # >0 additionally splits the moved
    #                                  bytes per rack distance: intra-rack
    #                                  transfers price here, rack-crossing
    #                                  ones at redist_bw_cross
    rack_sizes: tuple[int, ...] = ()  # nodes per rack (prefix node numbering,
    #                                  uneven allowed); non-empty makes the
    #                                  trace topology-aware: the default
    #                                  engine carries the Topology and the
    #                                  "topo" strategy places against it
    pod_sizes: tuple[int, ...] = ()  # optional racks per pod (prefix order)
    redist_bw_cross_pod: float = 0.0  # >0 prices the pod-crossing slice of
    #                                  the rack-crossing bytes on its own
    #                                  (slowest) link; 0 keeps cross_pod at
    #                                  the cross_rack bandwidth — the
    #                                  3-class numbers, bit for bit
    gamma_rack: float = 0.0          # >0 prices stages 1-2 by topology: per
    gamma_pod: float = 0.0           # launcher-tree edge, rack-crossing
    #                                  spawns pay +gamma_rack and pod-crossing
    #                                  ones +gamma_rack+gamma_pod on top of
    #                                  the flat latency; 0 keeps spawn flat
    restore_on_fail: bool = False    # FAIL recovery re-reads the dead nodes'
    #                                  shard of the last checkpoint: the
    #                                  recovery shrink carries a trailing
    #                                  RESTORE event (bytes_restored)

    @property
    def heterogeneous(self) -> bool:
        return bool(self.core_pool)

    @property
    def topology_aware(self) -> bool:
        """True when the trace declares a rack layout."""
        return bool(self.rack_sizes)

    @property
    def link_aware(self) -> bool:
        """True when the trace prices stage 3 per link (split bandwidths)."""
        return (self.redist_bw_local > 0.0 or self.redist_bw_cross > 0.0
                or self.redist_bw_intra_rack > 0.0
                or self.redist_bw_cross_pod > 0.0)

    def topology(self) -> Optional[Topology]:
        """The declared :class:`~repro.core.Topology`, or ``None``.

        The rack tree must cover the trace's peak node count — a
        smaller tree would leave placement/pricing undefined for the
        outer nodes — and on a heterogeneous trace it must match the
        ``core_pool`` width vector node for node (the live
        ``DevicePool`` enforces the same), so mismatches raise.
        """
        if not self.rack_sizes:
            return None
        topo = Topology(rack_sizes=self.rack_sizes, pod_sizes=self.pod_sizes)
        if topo.n_nodes < self.max_nodes():
            raise ValueError(
                f"scenario {self.name!r}: topology covers {topo.n_nodes} "
                f"nodes but the trace peaks at {self.max_nodes()}"
            )
        if self.core_pool and topo.n_nodes != len(self.core_pool):
            raise ValueError(
                f"scenario {self.name!r}: topology covers {topo.n_nodes} "
                f"nodes but core_pool has {len(self.core_pool)}"
            )
        return topo

    def pool_nodes(self) -> int:
        """Node count of the pool BOTH executors run against.

        This is exactly the pool :func:`scenario_pool` builds — the
        ``core_pool`` length, the topology's node count (spare whole
        racks beyond the trace's peak are legitimate), or the peak
        itself.  The simulator sizes its free set identically, so
        placement ranks the same candidate nodes as the live runtime
        (the sim == live invariant would silently break otherwise).
        """
        if self.core_pool:
            return len(self.core_pool)
        topo = self.topology()
        if topo is not None:
            return topo.n_nodes
        return self.max_nodes()

    def max_nodes(self) -> int:
        """Peak node count along the trace (sizes pools/device counts)."""
        count = peak = self.initial_nodes
        for ev in sorted(self.events, key=lambda e: e.step):
            if ev.kind == GROW:
                count = max(count, ev.target_nodes)
            elif ev.kind == RESTART:
                count = ev.target_nodes or count
            elif ev.kind == CHECKPOINT:
                pass  # snapshot in place: no allocation change
            else:
                count = max(1, count - len(ev.nodes))
            peak = max(peak, count)
        return peak

    def cost_model(self) -> CostModel:
        cm = NASP if self.profile == "nasp" else MN5
        if self.contention > 0.0:
            cm = cm.with_overlap(contention=self.contention)
        if self.link_aware:
            cm = cm.with_link_bandwidths(
                local=self.redist_bw_local or None,
                cross=self.redist_bw_cross or None,
            )
            if self.redist_bw_intra_rack > 0.0 or self.redist_bw_cross_pod > 0.0:
                # Three (or four) distance classes: intra-rack moves
                # price here, rack-crossing moves keep the (slower)
                # cross link, and pod-crossing ones the slowest link.
                cm = cm.with_class_bandwidths(
                    intra_rack=self.redist_bw_intra_rack or None,
                    cross_rack=self.redist_bw_cross or None,
                    cross_pod=self.redist_bw_cross_pod or None,
                )
        if self.gamma_rack > 0.0 or self.gamma_pod > 0.0:
            cm = replace(cm, gamma_rack=self.gamma_rack or None,
                         gamma_pod=self.gamma_pod or None)
        return cm

    def resolved_param_bytes(self) -> int:
        """Pytree bytes the trace reshards: explicit ``param_bytes``, or
        the ``arch`` config's parameter bytes, or 0 (no data movement)."""
        if self.param_bytes:
            return self.param_bytes
        if self.arch:
            return param_bytes_for_arch(self.arch)
        return 0

    def default_engine(self, strategy=None, method=None) -> ReconfigEngine:
        """Engine every executor uses for this trace (the dedup point).

        Topology-aware traces default to the ``topo`` strategy (their
        rack tree rides on the engine either way, so every strategy's
        stage-3 bytes resolve distance classes); heterogeneous pools
        require a vector-capable strategy (§4.2); a sized pytree wires
        the replicated analytic bytes model so each reconfiguration
        charges stage-3 data movement.  ``strategy`` / ``method``
        override the defaults for sweeps (e.g. the benchmark
        ``policy_sweep`` running each policy trace under every
        registered strategy).
        """
        if strategy is None:
            if self.topology_aware:
                strategy = TOPO_KEY
            elif self.heterogeneous:
                strategy = Strategy.PARALLEL_DIFFUSIVE
            else:
                strategy = Strategy.PARALLEL_HYPERCUBE
        pb = self.resolved_param_bytes()
        bytes_model = None
        if pb:
            # Per-link traces charge both transfer classes; aggregate
            # traces keep the moved-only model (bit-for-bit the
            # pre-split numbers).
            bytes_model = (replicated_link_model(pb) if self.link_aware
                           else replicated_bytes_model(pb))
        return ReconfigEngine(
            method=Method.MERGE if method is None else method,
            strategy=strategy,
            cost_model=self.cost_model(),
            bytes_model=bytes_model,
            topology=self.topology(),
            restore_on_fail=self.restore_on_fail,
        )

    def with_cores_per_node(self, cpn: int) -> "Scenario":
        return replace(self, cores_per_node=cpn, core_pool=())

    def with_model(self, arch: str = "", param_bytes: int = 0) -> "Scenario":
        """Same trace, different pytree size (sweeps redistribution)."""
        return replace(self, arch=arch, param_bytes=param_bytes)


# ================================================================ registry ==
_SCENARIO_REGISTRY: dict[str, Scenario] = {}


def register_scenario(scenario: Scenario, *, overwrite: bool = False) -> Scenario:
    if scenario.name in _SCENARIO_REGISTRY and not overwrite:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _SCENARIO_REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIO_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {sorted(_SCENARIO_REGISTRY)}"
        ) from None


def registered_scenarios() -> tuple[Scenario, ...]:
    return tuple(_SCENARIO_REGISTRY.values())


# ================================================================ builders ==
def steady_cycle(
    name: str = "steady-cycle",
    low: int = 1,
    high: int = 4,
    cycles: int = 2,
    period: int = 5,
    cores_per_node: int = 1,
    arch: str = "",
    param_bytes: int = 0,
) -> Scenario:
    """Steady expand/shrink cycles: low -> high -> low, repeated.

    The malleable-batch workload of §5: the job breathes with cluster
    load, exercising both the parallel expansion and the TS shrink path.
    ``arch`` / ``param_bytes`` size the pytree each cycle reshards.
    """
    events: list[ScenarioEvent] = []
    step = period
    for _ in range(cycles):
        events.append(ScenarioEvent(step=step, kind=GROW, target_nodes=high))
        step += period
        # shrink back down to `low` by releasing the highest node ids
        events.append(ScenarioEvent(
            step=step, kind=SHRINK, nodes=tuple(range(low, high))))
        step += period
    return Scenario(
        name=name,
        description=f"{cycles}x expand {low}->{high} then TS-shrink back",
        initial_nodes=low,
        cores_per_node=cores_per_node,
        events=tuple(events),
        steps=step + period,
        arch=arch,
        param_bytes=param_bytes,
    )


def burst_arrival(
    name: str = "burst-arrival",
    start: int = 1,
    burst: int = 8,
    cores_per_node: int = 1,
) -> Scenario:
    """A sudden grant of many nodes, then staged halving reclamation.

    Stresses exactly what the parallel strategies are for: one large
    expansion (log-depth spawn rounds) followed by staged TS shrinks as
    the RMS takes half the remaining nodes back per wave.  Halving keeps
    every settled node count a divisor of the burst width, so the trace
    also runs through live data-parallel training (batch % nodes == 0).
    """
    events = [ScenarioEvent(step=3, kind=GROW, target_nodes=burst)]
    step, count = 8, burst
    while count > start:
        nxt = max(start, count // 2)
        events.append(ScenarioEvent(
            step=step, kind=SHRINK, nodes=tuple(range(nxt, count))))
        count = nxt
        step += 3
    return Scenario(
        name=name,
        description=f"burst {start}->{burst}, then halving TS reclaim waves",
        initial_nodes=start,
        cores_per_node=cores_per_node,
        events=tuple(events),
        steps=step + 2,
    )


def node_failures(
    name: str = "node-failures",
    nodes: int = 8,
    failure_waves: tuple[tuple[int, ...], ...] = ((4, 5, 6, 7), (2, 3)),
    cores_per_node: int = 1,
) -> Scenario:
    """Grow once, then lose whole groups of nodes to correlated failures
    (a rack or switch dying takes several nodes at once).

    §1's dynamic-awareness case: node-confined worlds mean each failed
    node kills exactly one group, and recovery is a forced TS shrink of
    that wave.  The default waves settle at 8 -> 4 -> 2 nodes so the
    trace also runs through live data-parallel training.
    """
    events = [ScenarioEvent(step=2, kind=GROW, target_nodes=nodes)]
    for i, wave in enumerate(failure_waves):
        events.append(ScenarioEvent(step=6 + 4 * i, kind=FAIL, nodes=tuple(wave)))
    return Scenario(
        name=name,
        description=f"grow to {nodes}, then {len(failure_waves)} failure waves",
        initial_nodes=1,
        cores_per_node=cores_per_node,
        events=tuple(events),
        steps=6 + 4 * len(failure_waves) + 2,
    )


def straggler_churn(
    name: str = "straggler-churn",
    nodes: int = 4,
    churns: int = 2,
    cores_per_node: int = 1,
) -> Scenario:
    """Repeatedly drop the slowest node and immediately replace it.

    Straggler mitigation as continuous reconfiguration: at each churn
    step the slow group is TS-shrunk out AND the job grows back to the
    target width in the same reconfiguration drain (the settled node
    count never changes, so live training shards cleanly throughout).
    """
    events = [ScenarioEvent(step=2, kind=GROW, target_nodes=nodes)]
    step = 5
    for i in range(churns):
        # the live pool hands back the lowest free node id, so dropping
        # node (nodes-1-i) keeps sim and live trajectories identical
        events.append(ScenarioEvent(step=step, kind=STRAGGLER, nodes=(nodes - 1 - i,)))
        events.append(ScenarioEvent(step=step, kind=GROW, target_nodes=nodes))
        step += 3
    return Scenario(
        name=name,
        description=f"{churns}x same-step straggler drop + replacement at {nodes} nodes",
        initial_nodes=1,
        cores_per_node=cores_per_node,
        events=tuple(events),
        steps=step + 2,
    )


def heterogeneous_pool(
    name: str = "hetero-nasp",
    nodes: int = 8,
    widths: tuple[int, ...] = (20, 32),
    profile: str = "nasp",
    arch: str = "",
    param_bytes: int = 0,
    redist_bw_local: float = 0.0,
    redist_bw_cross: float = 0.0,
) -> Scenario:
    """NASP-style heterogeneous pool (§5.3): alternating node widths.

    Requires the diffusive strategy (§4.2).  Runs through BOTH executors:
    the live ``DevicePool`` partitions its devices with the same uneven
    ``node_widths`` vector, and because worlds stay node-confined,
    shrinks return complete uneven nodes to the pool.  ``arch`` /
    ``param_bytes`` size the pytree the trace reshards; split
    ``redist_bw_local`` / ``redist_bw_cross`` bandwidths price stage 3
    per link (see :func:`~repro.malleability.cost_model
    .replicated_link_model`).
    """
    pool = tuple(widths[i % len(widths)] for i in range(nodes))
    events = (
        ScenarioEvent(step=2, kind=GROW, target_nodes=nodes),
        ScenarioEvent(step=8, kind=SHRINK, nodes=tuple(range(nodes // 2, nodes))),
        ScenarioEvent(step=12, kind=GROW, target_nodes=nodes - 1),
    )
    return Scenario(
        name=name,
        description=f"heterogeneous {widths} pool, grow/shrink/regrow",
        initial_nodes=1,
        core_pool=pool,
        events=events,
        steps=16,
        profile=profile,
        arch=arch,
        param_bytes=param_bytes,
        redist_bw_local=redist_bw_local,
        redist_bw_cross=redist_bw_cross,
    )


def topology_nasp(name: str = "topo-nasp") -> Scenario:
    """2-rack uneven pool with placement-sensitive reconfigurations.

    Rack 0 holds nodes {0,1} (2 devices each), rack 1 holds {2,3,4}
    (1,1,2 devices) — uneven racks AND uneven widths.  The trace forces
    every placement decision the ``topo`` strategy exists for:

    * grow to the full pool, then a shrink **to a target count** (victim
      choice is the strategy's): ``topo`` vacates whole rack 0 and tops
      up from rack 1 — a shrink that must cross racks, returning
      rack-granular capacity to the RMS;
    * the regrow then lands **rack-local** (node 4, next to the
      survivors in rack 1) where the greedy classics would take node 0
      and re-fragment the vacated rack.

    Rank counts along the trace (2, 8, 2, 4) all divide a batch of 8,
    so the full ElasticTrainer loop runs it on 8 host devices.
    """
    return Scenario(
        name=name,
        description="2-rack uneven pool: rack-vacating shrink + "
                    "rack-local regrow (topo placement)",
        initial_nodes=1,
        core_pool=(2, 2, 1, 1, 2),
        rack_sizes=(2, 3),
        events=(
            ScenarioEvent(step=2, kind=GROW, target_nodes=5),
            ScenarioEvent(step=6, kind=SHRINK, target_nodes=2),
            ScenarioEvent(step=10, kind=GROW, target_nodes=3),
        ),
        steps=13,
        profile="nasp",
    )


def topology_redist(name: str = "topo-redist") -> Scenario:
    """Move a real pytree across racks under 3-class link pricing.

    The same 2-rack uneven pool as :func:`topology_nasp`, now resharding
    xlstm_125m's parameters with three distinct bandwidths: replicas
    re-validated in place ride the 25 GB/s intra-node link, rack-local
    copies the 10 GB/s intra-rack fabric, and rack-crossing copies the
    2.5 GB/s inter-rack Ethernet.  The burst grow ships 4 of its 6
    replicas across racks (rack 1 opens fresh), the rack-vacating shrink
    leaves the survivors' replicas in place (intra_node only), and the
    regrow is where placement pays: ``topo`` lands rack-local next to
    the survivors (intra_rack bytes) while the greedy classics reopen
    the vacated rack and pay cross_rack bandwidth for the same copies —
    the ``table_topology`` benchmark prints exactly that column.  Rank
    counts (2, 8, 2, 4) divide a batch of 8 on 8 host devices, so the
    full trainer loop replays it.
    """
    return Scenario(
        name=name,
        description="2-rack uneven pool resharding xlstm_125m under "
                    "intra_node/intra_rack/cross_rack pricing",
        initial_nodes=1,
        core_pool=(2, 2, 1, 1, 2),
        rack_sizes=(2, 3),
        events=(
            ScenarioEvent(step=2, kind=GROW, target_nodes=5),
            ScenarioEvent(step=6, kind=SHRINK, target_nodes=2),
            ScenarioEvent(step=10, kind=GROW, target_nodes=3),
        ),
        steps=13,
        arch="xlstm_125m",
        redist_bw_local=25.0e9,
        redist_bw_cross=2.5e9,
        redist_bw_intra_rack=10.0e9,
    )


def topology_pods(name: str = "topo-pods") -> Scenario:
    """Pod-aware pricing: 3 racks in 2 pods, 4-class links + priced spawn.

    Pod 0 holds racks 0-1 (nodes {0,1} and {2}), pod 1 holds rack 2
    (nodes {3,4}) — uniform 1-wide nodes so EVERY strategy (including
    the hypercube) runs the trace.  The burst grow from node 0 must open
    rack 1 (same pod) and rack 2 (the other pod), so its stage-3 shares
    split across all four distance classes and its stages 1-2 launcher
    tree pays per-edge ``gamma_rack`` / ``gamma_pod`` penalties; the
    shrink vacates the far pod whole (survivor replicas stay put); the
    regrow reopens it and pays the pod link again.
    """
    return Scenario(
        name=name,
        description="2-pod/3-rack pool: 4-class link pricing + "
                    "topology-priced spawn",
        initial_nodes=1,
        cores_per_node=1,
        rack_sizes=(2, 1, 2),
        pod_sizes=(2, 1),
        events=(
            ScenarioEvent(step=2, kind=GROW, target_nodes=5),
            ScenarioEvent(step=6, kind=SHRINK, nodes=(3, 4)),
            ScenarioEvent(step=10, kind=GROW, target_nodes=4),
        ),
        steps=13,
        arch="xlstm_125m",
        redist_bw_local=25.0e9,
        redist_bw_cross=2.5e9,
        redist_bw_intra_rack=10.0e9,
        redist_bw_cross_pod=1.0e9,
        gamma_rack=0.002,
        gamma_pod=0.004,
    )


def ckpt_cycle(
    name: str = "ckpt-cycle",
    nodes: int = 4,
    checkpoints: int = 3,
    period: int = 3,
    param_bytes: int = 1 << 30,
) -> Scenario:
    """Periodic full-state checkpoints riding a steady grow/shrink trace.

    The fault-tolerance cadence of a long malleable run: grow once,
    snapshot the pytree every ``period`` steps (a CHECKPOINT event
    prices the stream through the cost model's checkpoint link, hidden
    behind compute per its ``ckpt_overlap``), then TS-shrink back.
    Node counts (1, ``nodes``, ``nodes/2``) divide a batch of 8, so the
    full ElasticTrainer loop replays the trace and actually persists
    each snapshot through its :class:`~repro.checkpoint.CheckpointManager`.
    """
    events = [ScenarioEvent(step=2, kind=GROW, target_nodes=nodes)]
    step = 2 + period
    for _ in range(checkpoints):
        events.append(ScenarioEvent(step=step, kind=CHECKPOINT))
        step += period
    events.append(ScenarioEvent(
        step=step, kind=SHRINK, nodes=tuple(range(nodes // 2, nodes))))
    return Scenario(
        name=name,
        description=f"{checkpoints}x periodic checkpoint at {nodes} nodes, "
                    "then TS shrink",
        initial_nodes=1,
        events=tuple(events),
        steps=step + period,
        param_bytes=param_bytes,
    )


def node_fail_wave(
    name: str = "node-fail-wave",
    nodes: int = 8,
    failure_waves: tuple[tuple[int, ...], ...] = ((4, 5, 6, 7), (2, 3)),
    param_bytes: int = 1 << 30,
) -> Scenario:
    """Correlated failure waves recovered from the last checkpoint.

    :func:`node_failures` with the fault-tolerance story attached: a
    checkpoint lands before the first wave, and ``restore_on_fail``
    makes every recovery shrink re-read the dead nodes' shard of that
    snapshot — a trailing RESTORE event priced per distance class, so
    ``est_wall`` now includes recovery I/O, not just the TS teardown.
    The waves settle at 8 -> 4 -> 2 nodes (live-trainable widths).
    """
    events = [ScenarioEvent(step=2, kind=GROW, target_nodes=nodes),
              ScenarioEvent(step=4, kind=CHECKPOINT)]
    for i, wave in enumerate(failure_waves):
        events.append(ScenarioEvent(step=6 + 4 * i, kind=FAIL, nodes=tuple(wave)))
    return Scenario(
        name=name,
        description=f"grow to {nodes}, checkpoint, then "
                    f"{len(failure_waves)} failure waves restoring lost shards",
        initial_nodes=1,
        events=tuple(events),
        steps=6 + 4 * len(failure_waves) + 2,
        param_bytes=param_bytes,
        restore_on_fail=True,
    )


def restart_vs_shrink(
    name: str = "restart-vs-shrink",
    nodes: int = 4,
    param_bytes: int = 1 << 30,
) -> Scenario:
    """The same resize twice: full-stop restart, then malleable shrink.

    The paper's head-to-head in one trace: the job gives back half its
    nodes first as a rigid checkpoint/stop/respawn/restore cycle
    (RESTART), regrows, then does the identical resize as a malleable
    TS shrink.  Comparing the two records' ``est_wall_s`` shows what
    dynamic-awareness buys — the restart pays the full snapshot back
    through the checkpoint link while the shrink moves nothing (the
    replicated model keeps survivor state in place) — under every
    registered strategy, since both mechanisms are strategy-independent.
    Node counts (1, 4, 2) divide a batch of 8 for the live trainer.
    """
    return Scenario(
        name=name,
        description=f"the same {nodes}->{nodes // 2} resize as full-stop "
                    "restart, then as malleable TS shrink",
        initial_nodes=1,
        events=(
            ScenarioEvent(step=2, kind=GROW, target_nodes=nodes),
            ScenarioEvent(step=5, kind=RESTART, target_nodes=nodes // 2),
            ScenarioEvent(step=8, kind=GROW, target_nodes=nodes),
            ScenarioEvent(step=11, kind=SHRINK,
                          nodes=tuple(range(nodes // 2, nodes))),
        ),
        steps=14,
        param_bytes=param_bytes,
    )


# The fault-tolerance family: every scenario whose trace exercises the
# checkpoint/restore path (benchmarks' ``table_faults`` iterates this).
FAULT_SCENARIO_NAMES = ("ckpt-cycle", "node-fail-wave", "restart-vs-shrink")


def registered_fault_scenarios() -> tuple[Scenario, ...]:
    """The registered fault-tolerance scenarios, in table order."""
    return tuple(get_scenario(n) for n in FAULT_SCENARIO_NAMES)


for _sc in (
    steady_cycle(),
    burst_arrival(),
    node_failures(),
    straggler_churn(),
    heterogeneous_pool(),
    # The same steady cycle under redistribution pressure: stage-3 moves
    # a real model config's parameter pytree, so est_wall is dominated by
    # data movement rather than spawning — swap `arch` to sweep it.
    steady_cycle(name="redist-cycle", arch="stablelm_3b"),
    # Uneven widths x real pytree bytes x per-link pricing: a small
    # (2,1,2,1) pool — sized so the full ElasticTrainer loop can run it
    # on a handful of host devices — resharding xlstm_125m's parameters
    # with the local link 10x faster than the cross-group Ethernet, so
    # bytes_stayed and bytes_moved are charged at different bandwidths.
    heterogeneous_pool(
        name="hetero-redist", nodes=4, widths=(2, 1), arch="xlstm_125m",
        redist_bw_local=25.0e9, redist_bw_cross=2.5e9,
    ),
    # Topology-aware traces: placement becomes the strategy's decision
    # and stage-3 bytes price per rack distance class.
    topology_nasp(),
    topology_redist(),
    topology_pods(),
    # Fault-tolerance family: checkpoint cadence, checkpoint-backed
    # failure recovery, and the rigid restart-vs-malleable-shrink
    # head-to-head (see FAULT_SCENARIO_NAMES).
    ckpt_cycle(),
    node_fail_wave(),
    restart_vs_shrink(),
):
    register_scenario(_sc)


# =============================================================== executors ==
@dataclass(frozen=True)
class ScenarioRecord:
    """One reconfiguration along a scenario run (either executor)."""

    step: int
    kind: str                  # expand | shrink | fail | straggler
    #                            | checkpoint | restart
    mechanism: str             # strategy value, TS/ZS/SS value, or ckpt
    nodes_before: int
    nodes_after: int
    est_wall_s: float          # timeline total
    downtime_s: float          # timeline downtime
    bytes_moved: int = 0       # stage-3 cross-link bytes charged on the timeline
    queued_s: float = 0.0      # RMS arbitration wait charged (QUEUE span)
    bytes_stayed: int = 0      # stage-3 local-link bytes charged on the timeline
    bytes_cross_rack: int = 0  # rack-crossing portion of bytes_moved
    bytes_cross_pod: int = 0   # pod-crossing slice of bytes_cross_rack
    bytes_checkpointed: int = 0  # snapshot bytes streamed to the store
    bytes_restored: int = 0    # bytes read back from the store (RESTORE)
    restored_s: float = 0.0    # RESTORE span charged on the timeline
    time_to_result_s: float = -1.0  # est_wall_s + the modeled compute
    #                            segment since the previous charged event
    #                            (executors accrue it when run with
    #                            throughput=; sentinel -1 resolves to
    #                            est_wall_s, so without a model the sum
    #                            over a run IS the makespan, bit for bit)

    def __post_init__(self) -> None:
        if self.time_to_result_s < 0.0:
            object.__setattr__(self, "time_to_result_s", self.est_wall_s)

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class (sums to stayed + moved)."""
        return split_bytes_by_class(self.bytes_stayed, self.bytes_moved,
                                    self.bytes_cross_rack,
                                    self.bytes_cross_pod)


def record_parity_key(rec) -> tuple:
    """THE canonical per-event parity tuple for sim == live checks.

    Every agreement gate (the test suite, the example's smoke check)
    compares records through this one function, so adding a field to
    :class:`ScenarioRecord` extends every gate at once instead of
    silently weakening whichever copy was not updated.
    """
    return (rec.step, rec.kind, rec.mechanism, rec.nodes_before,
            rec.nodes_after, rec.est_wall_s, rec.downtime_s, rec.bytes_moved,
            rec.queued_s, rec.bytes_stayed, rec.bytes_cross_rack,
            rec.bytes_cross_pod, rec.bytes_checkpointed, rec.bytes_restored,
            rec.restored_s, rec.time_to_result_s)


@dataclass
class _SimCluster:
    """Device-free twin of the live runtime's bookkeeping.

    Mirrors :class:`repro.elastic.ElasticRuntime` exactly — same world
    creation order, same greedy lowest-free-node acquisition — so the
    engine sees identical plans and charges identical timelines.
    """

    scenario: Scenario
    engine: ReconfigEngine
    state: ClusterState = field(default_factory=ClusterState)

    def __post_init__(self) -> None:
        pool = self.scenario.pool_nodes()
        self._free = set(range(pool))
        initial = list(range(self.scenario.initial_nodes))
        self._free -= set(initial)
        cpn = [self._width(n) for n in initial]
        self.state.add_world(initial, cpn, is_initial=True)

    def _width(self, node: int) -> int:
        if self.scenario.core_pool:
            return self.scenario.core_pool[node]
        return self.scenario.cores_per_node

    @property
    def n_nodes(self) -> int:
        return len(self.state.nodes_in_use())

    def ranks_in_use(self) -> int:
        return sum(w.size for w in self.state.worlds.values())

    def expand(self, target_nodes: int,
               queue_delay_s: float = 0.0) -> ScenarioRecord:
        before = self.n_nodes
        ns = self.ranks_in_use()
        need = target_nodes - before
        if need > len(self._free):
            # Same error, same message shape as ElasticRuntime.expand:
            # an overcommitting trace must fail identically in both
            # executors, never silently truncate in one of them.
            raise RuntimeError(
                f"device pool exhausted: expand to {target_nodes} nodes "
                f"needs {need} free nodes, pool has {len(self._free)}"
            )
        used_sorted = sorted(self.state.nodes_in_use())
        # Placement mirrors the live runtime exactly: the engine picks
        # which free nodes the expansion lands on (greedy lowest-id for
        # the classics, rack-local-first for topology-aware strategies).
        new_nodes = self.engine.select_expansion_nodes(
            used_sorted, self._free, need)
        nodes_all = used_sorted + new_nodes
        nt = ns + sum(self._width(n) for n in new_nodes)
        cores = self._cores_arg(nodes_all)
        plan = self.engine.plan_expand(
            ns, nt, cores, queue_delay_s=queue_delay_s, node_ids=nodes_all)
        outcome = self.engine.execute(plan)
        assert plan.spawn is not None
        in_use = self.state.nodes_in_use()
        queue = [n for n in plan.node_ids if n not in in_use]
        for g in plan.spawn.groups:
            # The NodeGroup substrate keeps worlds node-confined even for
            # classic strategies whose plan spawns one multi-node group
            # (their cost timeline is unchanged — one big spawn call);
            # the group is split one world per node, exactly as the live
            # runtime's apply_expand does, taking nodes in the plan's
            # placement order.
            remaining = g.size
            while remaining > 0:
                node = queue.pop(0) if queue else min(self._free)
                self._free.discard(node)
                take = min(self._width(node), remaining)
                self.state.add_world([node], [take])
                remaining -= take
        self.state.expansions_done += 1
        return ScenarioRecord(
            step=-1, kind="expand",
            mechanism=strategy_key(plan.spawn.strategy),
            nodes_before=before, nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s, downtime_s=outcome.downtime_s,
            bytes_moved=outcome.bytes_moved, queued_s=outcome.queued_s,
            bytes_stayed=outcome.bytes_stayed,
            bytes_cross_rack=outcome.bytes_cross_rack,
            bytes_cross_pod=outcome.bytes_cross_pod,
            bytes_checkpointed=outcome.bytes_checkpointed,
            bytes_restored=outcome.bytes_restored,
            restored_s=outcome.restored_s,
        )

    def _cores_arg(self, nodes: list[int]):
        """Planner allocation argument in node order, normalized by the
        shared :meth:`ReconfigEngine.allocation_arg` rule both
        executors use."""
        return self.engine.allocation_arg([self._width(n) for n in nodes])

    def pick_release(self, n_release: int) -> list[int]:
        """Victims for a target-count shrink (the engine's decision)."""
        return self.engine.select_release_nodes(
            sorted(self.state.nodes_in_use()), n_release)

    def shrink_nodes(self, victims: list[int], kind: str,
                     queue_delay_s: float = 0.0) -> ScenarioRecord:
        before = self.n_nodes
        plan = self.engine.plan_shrink(self.state, release_nodes=victims,
                                       queue_delay_s=queue_delay_s,
                                       failed=(kind == FAIL))
        outcome = self.engine.execute(plan)
        assert plan.shrink is not None
        apply_shrink(self.state, plan.shrink)
        self._free.update(plan.shrink.nodes_returned)
        return ScenarioRecord(
            step=-1, kind=kind, mechanism=plan.shrink.kind.value,
            nodes_before=before, nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s, downtime_s=outcome.downtime_s,
            bytes_moved=outcome.bytes_moved, queued_s=outcome.queued_s,
            bytes_stayed=outcome.bytes_stayed,
            bytes_cross_rack=outcome.bytes_cross_rack,
            bytes_cross_pod=outcome.bytes_cross_pod,
            bytes_checkpointed=outcome.bytes_checkpointed,
            bytes_restored=outcome.bytes_restored,
            restored_s=outcome.restored_s,
        )

    def checkpoint(self, queue_delay_s: float = 0.0) -> ScenarioRecord:
        """Charge one full-state checkpoint (no allocation change),
        mirroring :meth:`repro.elastic.ElasticRuntime.checkpoint`."""
        before = self.n_nodes
        plan = self.engine.plan_checkpoint(self.ranks_in_use(),
                                           queue_delay_s=queue_delay_s)
        outcome = self.engine.execute(plan)
        return ScenarioRecord(
            step=-1, kind="checkpoint", mechanism="ckpt",
            nodes_before=before, nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s, downtime_s=outcome.downtime_s,
            queued_s=outcome.queued_s,
            bytes_checkpointed=outcome.bytes_checkpointed,
        )

    def restart(self, target_nodes: int,
                queue_delay_s: float = 0.0) -> ScenarioRecord:
        """Full-stop checkpoint/restart, mirroring
        :meth:`repro.elastic.ElasticRuntime.restart` exactly: same
        lowest-id-prefix placement over the momentarily-all-free pool,
        same error messages, same record fields."""
        before = self.n_nodes
        if target_nodes <= 0:
            raise ValueError("restart() requires target_nodes >= 1")
        candidates = sorted(set(self.state.nodes_in_use()) | self._free)
        if target_nodes > len(candidates):
            raise RuntimeError(
                f"device pool exhausted: restart to {target_nodes} nodes "
                f"exceeds the {len(candidates)} nodes available"
            )
        new_nodes = candidates[:target_nodes]
        ns = self.ranks_in_use()
        nt = sum(self._width(n) for n in new_nodes)
        plan = self.engine.plan_restart(ns, nt, queue_delay_s=queue_delay_s,
                                        node_ids=new_nodes)
        outcome = self.engine.execute(plan)
        # Full stop: every world dies and its nodes free up, then one
        # node-confined world per target node comes back — the same
        # rebuild ElasticRuntime.apply_restart performs.
        for wid in list(self.state.worlds):
            w = self.state.worlds.pop(wid)
            self._free.update(w.nodes)
        for node in new_nodes:
            self._free.discard(node)
            self.state.add_world([node], [self._width(node)])
        return ScenarioRecord(
            step=-1, kind="restart", mechanism="ss",
            nodes_before=before, nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s, downtime_s=outcome.downtime_s,
            queued_s=outcome.queued_s,
            bytes_checkpointed=outcome.bytes_checkpointed,
            bytes_restored=outcome.bytes_restored,
            restored_s=outcome.restored_s,
        )


def dispatch_event(
    cluster, kind: str, *, nodes: tuple[int, ...] = (), target_nodes: int = 0,
    queue_delay_s: float = 0.0,
) -> Iterable[ScenarioRecord]:
    """THE event-to-action mapping, shared by every executor.

    ``cluster`` is anything with ``n_nodes``, ``state``, ``expand``,
    ``shrink_nodes``, ``pick_release``, ``checkpoint`` and ``restart``
    — the device-free sim cluster,
    or a live runtime behind :class:`RuntimeAdapter` (used by both
    :func:`run_scenario_live` and :class:`repro.elastic.ElasticTrainer`).

    A SHRINK with no explicit victim ``nodes`` but a smaller
    ``target_nodes`` lets the engine choose the victims
    (``pick_release``): highest ids for the classics, whole racks first
    for topology-aware strategies."""
    if kind == GROW:
        if target_nodes > cluster.n_nodes:
            yield cluster.expand(target_nodes, queue_delay_s=queue_delay_s)
    elif kind == SHRINK:
        victims = [n for n in nodes if n in cluster.state.nodes_in_use()]
        if not victims and not nodes and 0 < target_nodes < cluster.n_nodes:
            victims = list(cluster.pick_release(cluster.n_nodes - target_nodes))
            vset = set(victims)
            blockers = sorted(
                w.wid for w in cluster.state.worlds.values()
                if set(w.nodes) & vset and not set(w.nodes) <= vset
            )
            if blockers:
                # A victim inside a multi-node world can only be
                # zombified (§4.7): its node stays pinned and the
                # declared target is silently missed.  Fail loudly —
                # identically in both executors — instead.
                raise ValueError(
                    f"shrink to {target_nodes} nodes cannot be met: "
                    f"victims {victims} partially overlap multi-node "
                    f"worlds {blockers} (ZS would pin their nodes); "
                    "name explicit victim nodes instead"
                )
        if victims:
            yield cluster.shrink_nodes(victims, kind="shrink",
                                       queue_delay_s=queue_delay_s)
    elif kind in (FAIL, STRAGGLER):
        for n in nodes:
            if n in cluster.state.nodes_in_use():
                yield cluster.shrink_nodes([n], kind=kind,
                                           queue_delay_s=queue_delay_s)
    elif kind == CHECKPOINT:
        yield cluster.checkpoint(queue_delay_s=queue_delay_s)
    elif kind == RESTART:
        yield cluster.restart(target_nodes or cluster.n_nodes,
                              queue_delay_s=queue_delay_s)
    else:
        raise ValueError(f"unknown scenario event kind {kind!r}")


def _dispatch(cluster, ev: ScenarioEvent) -> Iterable[ScenarioRecord]:
    return dispatch_event(cluster, ev.kind, nodes=ev.nodes,
                          target_nodes=ev.target_nodes,
                          queue_delay_s=ev.queue_delay_s)


class RuntimeAdapter:
    """Present a live :class:`~repro.elastic.ElasticRuntime` through the
    dispatch interface, converting its records to :class:`ScenarioRecord`."""

    def __init__(self, runtime) -> None:
        self._rt = runtime

    @property
    def state(self):
        return self._rt.state

    @property
    def n_nodes(self) -> int:
        return self._rt.n_nodes

    @staticmethod
    def _convert(rec) -> ScenarioRecord:
        return ScenarioRecord(
            step=-1, kind=rec.kind, mechanism=rec.mechanism,
            nodes_before=rec.nodes_before, nodes_after=rec.nodes_after,
            est_wall_s=rec.est_wall_s, downtime_s=rec.downtime_s,
            bytes_moved=rec.bytes_moved, queued_s=rec.queued_s,
            bytes_stayed=rec.bytes_stayed,
            bytes_cross_rack=rec.bytes_cross_rack,
            bytes_cross_pod=rec.bytes_cross_pod,
            bytes_checkpointed=rec.bytes_checkpointed,
            bytes_restored=rec.bytes_restored,
            restored_s=rec.restored_s,
        )

    def expand(self, target_nodes: int,
               queue_delay_s: float = 0.0) -> ScenarioRecord:
        return self._convert(
            self._rt.expand(target_nodes, queue_delay_s=queue_delay_s))

    def pick_release(self, n_release: int) -> list[int]:
        """Victims for a target-count shrink (the engine's decision)."""
        return self._rt.engine.select_release_nodes(
            sorted(self._rt.state.nodes_in_use()), n_release)

    def shrink_nodes(self, victims: list[int], kind: str,
                     queue_delay_s: float = 0.0) -> ScenarioRecord:
        if kind == FAIL and len(victims) == 1:
            rec = self._rt.fail_node(victims[0], queue_delay_s=queue_delay_s)
        elif kind == STRAGGLER and len(victims) == 1:
            rec = self._rt.drop_straggler(victims[0],
                                          queue_delay_s=queue_delay_s)
        else:
            rec = self._rt.shrink_nodes(victims, queue_delay_s=queue_delay_s)
        return self._convert(rec)

    def checkpoint(self, queue_delay_s: float = 0.0) -> ScenarioRecord:
        return self._convert(
            self._rt.checkpoint(queue_delay_s=queue_delay_s))

    def restart(self, target_nodes: int,
                queue_delay_s: float = 0.0) -> ScenarioRecord:
        return self._convert(
            self._rt.restart(target_nodes, queue_delay_s=queue_delay_s))


def resolve_engine(
    scenario: Scenario,
    engine: Optional[ReconfigEngine] = None,
    *,
    strategy=None,
    cost_model=None,
) -> ReconfigEngine:
    """THE executor-shared engine resolution (the normalized keywords).

    Every ``run_scenario_*`` executor accepts the same keyword-only
    ``strategy=`` / ``cost_model=`` overrides and resolves them here:
    no ``engine`` builds the scenario's default (with the strategy
    override applied); an explicit ``engine`` is re-targeted with
    ``dataclasses.replace``, so overrides compose identically whichever
    executor — or :func:`repro.malleability.policies.run_multijob_sim` —
    forwarded them.
    """
    if engine is None:
        engine = scenario.default_engine(strategy=strategy)
    elif strategy is not None:
        engine = replace(engine, strategy=strategy)
    if cost_model is not None:
        engine = replace(engine, cost_model=cost_model)
    return engine


def _segment_clock(
    scenario: Scenario, throughput: "Optional[ThroughputModel]",
) -> Optional[Callable[[int], float]]:
    """Memoized modeled step time per allocation node count.

    THE shared width resolution for segment accrual: every executor —
    object, vectorized, live — and :func:`~repro.malleability.throughput
    .time_to_result` price a ``count``-node allocation as the node-id
    prefix of the model's ``node_widths`` (falling back to the
    scenario's ``core_pool`` / ``cores_per_node``), so the accrued
    ``time_to_result_s`` agrees bit for bit across paths.  ``None``
    when no model is given (accrual off).
    """
    if throughput is None:
        return None
    memo: dict[int, float] = {}

    def step_time(count: int) -> float:
        t = memo.get(count)
        if t is None:
            t = memo[count] = throughput.step_time(throughput.widths_for(
                count, core_pool=scenario.core_pool,
                default_width=scenario.cores_per_node))
        return t

    return step_time


def run_scenario_sim(
    scenario: Scenario,
    engine: Optional[ReconfigEngine] = None,
    *,
    strategy=None,
    cost_model=None,
    throughput: "Optional[ThroughputModel]" = None,
) -> list[ScenarioRecord]:
    """Execute a scenario on the timeline-charging simulator backend.

    ``strategy=`` / ``cost_model=`` are the normalized keyword overrides
    (see :func:`resolve_engine`); passing ``engine`` positionally keeps
    working.  ``throughput=`` accrues each record's modeled compute
    segment — ``(steps since the last charged event) x
    step_time(allocation before the event)`` — into
    ``time_to_result_s`` on top of the charged wall.
    """
    engine = resolve_engine(scenario, engine, strategy=strategy,
                            cost_model=cost_model)
    cluster = _SimCluster(scenario=scenario, engine=engine)
    records: list[ScenarioRecord] = []
    step_time = _segment_clock(scenario, throughput)
    last = 0
    for ev in sorted(scenario.events, key=lambda e: e.step):
        for rec in _dispatch(cluster, ev):
            if step_time is None:
                records.append(replace(rec, step=ev.step))
            else:
                seg = (ev.step - last) * step_time(rec.nodes_before)
                last = ev.step
                records.append(replace(
                    rec, step=ev.step,
                    time_to_result_s=rec.time_to_result_s + seg))
    return records


# ==================================================== vectorized fast path ==
class TransitionCache:
    """Memoized transition charging for :func:`run_scenario_vectorized`.

    Keyed by ``(kind, nodes_before, nodes_after, queue_delay_s)``: under
    the fast path's eligibility gates (uniform node widths, no topology,
    prefix-range node usage) that tuple fully determines the charged
    record, so a churn trace that revisits the same resize pays for it
    once.  Sharing one cache across several runs is only valid when
    every run charges with the same cost context (same widths, cost
    model, strategy, method, bytes model) — :func:`repro.malleability
    .policies.monte_carlo_sweep` does exactly that for its seed
    replicas.
    """

    def __init__(self) -> None:
        self._fields: dict[tuple, dict] = {}
        self.hits = 0
        self.misses = 0

    def charge_fields(self, scenario: Scenario, engine: ReconfigEngine,
                      kind: str, before: int, after: int,
                      queue_delay_s: float) -> dict:
        """The cached record's field dict (``step`` pinned to ``-1``).

        The hot stamping loop binds a copy of it onto a bare
        ``ScenarioRecord.__new__`` instance and overwrites ``step`` —
        bypassing both ``dataclasses.replace`` and the frozen
        dataclass ``__init__`` (sixteen ``object.__setattr__`` calls),
        which together dominated the 100k-event profile.
        """
        key = (kind, before, after, queue_delay_s)
        fields = self._fields.get(key)
        if fields is not None:
            self.hits += 1
            return fields
        self.misses += 1
        rec = _charge_transition(scenario, engine, kind, before, after,
                                 queue_delay_s)
        fields = dict(rec.__dict__)
        fields["step"] = -1
        self._fields[key] = fields
        return fields

    def charge(self, scenario: Scenario, engine: ReconfigEngine, kind: str,
               before: int, after: int, queue_delay_s: float) -> ScenarioRecord:
        rec = ScenarioRecord.__new__(ScenarioRecord)
        rec.__dict__.update(self.charge_fields(
            scenario, engine, kind, before, after, queue_delay_s))
        return rec


def _charge_transition(scenario: Scenario, engine: ReconfigEngine, kind: str,
                       before: int, after: int,
                       queue_delay_s: float) -> ScenarioRecord:
    """Charge one uniform-width prefix-range transition (cache miss).

    Hot shapes take the closed-form chargers from
    :mod:`repro.core.vectorized` (a MERGE hypercube expansion, a TS
    shrink of single-node worlds) — the same event sequence the planner
    and builder would emit, without constructing the plan.  Everything
    else synthesizes the canonical prefix-range cluster at ``before``
    nodes and dispatches through the object path, so the cached record
    is the object path's record.
    """
    from repro.core.vectorized import (
        charge_stats,
        hypercube_expand_charges,
        queue_charge,
        redistribution_charge,
        ts_shrink_charges,
    )

    cm = engine.cost_model
    assert cm is not None  # resolved in ReconfigEngine.__post_init__
    C = scenario.cores_per_node
    ns, nt = before * C, after * C
    if kind == "expand":
        analytic = (engine.method is Method.MERGE
                    and strategy_key(engine.strategy) == "hypercube")
        mechanism = strategy_key(engine.strategy)
    else:
        # Tier-A victims are whole single-node worlds, so the shrink
        # planner always resolves to TS (§4.6) whatever the strategy.
        analytic = True
        mechanism = ShrinkKind.TS.value
    if analytic:
        if kind == "expand":
            mech = hypercube_expand_charges(cm, ns, nt, C)
        else:
            mech = ts_shrink_charges(cm, [C] * (before - after))
        stayed, moved = engine.redistribution_stats(ns, nt)
        charges = (queue_charge(queue_delay_s) + mech
                   + redistribution_charge(cm, moved, stayed))
        st = charge_stats(charges, contention=cm.overlap_contention,
                          asynchronous=engine.asynchronous)
        return ScenarioRecord(
            step=-1, kind=kind, mechanism=mechanism,
            nodes_before=before, nodes_after=after,
            est_wall_s=st.total, downtime_s=st.downtime,
            bytes_moved=st.bytes_moved, queued_s=st.queued,
            bytes_stayed=st.bytes_stayed,
            bytes_cross_rack=st.bytes_cross_rack,
            bytes_cross_pod=st.bytes_cross_pod,
        )
    cluster = _SimCluster(scenario=scenario, engine=engine)
    for n in range(scenario.initial_nodes, before):
        cluster._free.discard(n)
        cluster.state.add_world([n], [cluster._width(n)])
    if kind == "expand":
        return cluster.expand(after, queue_delay_s=queue_delay_s)
    return cluster.shrink_nodes(list(range(after, before)), kind=kind,
                                queue_delay_s=queue_delay_s)


def _vector_plan(scenario: Scenario,
                 engine: ReconfigEngine) -> Optional[list[tuple]]:
    """Compile a trace to ``(step, kind, before, after, qd)`` transitions.

    Returns None when the trace leaves the fast path's domain — uneven
    node widths, a topology-carrying engine (placement-priced plans),
    or any event whose node usage stops being the prefix range
    ``0..count-1`` (e.g. a mid-range failure) — in which case the caller
    must walk the object path.  The gates are exactly the invariants
    that make ``(kind, before, after, qd)`` determine the record.
    """
    if scenario.core_pool or engine.topology is not None:
        return None
    if engine.restore_on_fail:
        # FAIL recovery charges a trailing RESTORE leg the closed-form
        # chargers do not model; walk the object path.
        return None
    # Only a declared rack tree can cap the pool below the trace's peak
    # (pool_nodes() otherwise IS the peak, which no grow can exceed) —
    # checking topology() directly skips an O(events) max_nodes() scan.
    topo = scenario.topology()
    pool = topo.n_nodes if topo is not None else None
    floor = max(1, scenario.initial_nodes)
    count = scenario.initial_nodes
    out: list[tuple] = []
    for ev in sorted(scenario.events, key=lambda e: e.step):
        if ev.kind == GROW:
            if ev.target_nodes <= count:
                continue
            if pool is not None and ev.target_nodes > pool:
                return None  # object path raises "device pool exhausted"
            out.append((ev.step, "expand", count, ev.target_nodes,
                        ev.queue_delay_s))
            count = ev.target_nodes
        elif ev.kind == SHRINK:
            nodes = ev.nodes
            if nodes:
                lo = count - len(nodes)
                if lo >= floor and nodes == tuple(range(lo, count)):
                    after = lo  # contiguous top range, all in use
                else:
                    victims = [n for n in nodes if n < count]
                    if not victims:
                        continue
                    after = count - len(victims)
                    if (after < floor or min(victims) != after
                            or len(set(victims)) != len(victims)):
                        # Not exactly the top range {after..count-1}:
                        # the prefix invariant would break.
                        return None
            else:
                if not 0 < ev.target_nodes < count:
                    continue
                if ev.target_nodes < floor:
                    return None  # pick_release would split the initial world
                after = ev.target_nodes
            out.append((ev.step, "shrink", count, after, ev.queue_delay_s))
            count = after
        elif ev.kind in (FAIL, STRAGGLER):
            for n in ev.nodes:
                if n >= count:
                    continue
                if n != count - 1 or count - 1 < floor:
                    return None  # mid-range victim breaks the prefix
                out.append((ev.step, ev.kind, count, count - 1,
                            ev.queue_delay_s))
                count -= 1
        else:
            return None  # unknown kind: let the object path raise
    return out


def run_scenario_vectorized(
    scenario: Scenario, engine: Optional[ReconfigEngine] = None,
    cache: Optional[TransitionCache] = None,
    *,
    strategy=None,
    cost_model=None,
    throughput: "Optional[ThroughputModel]" = None,
) -> list[ScenarioRecord]:
    """Execute a scenario through the vectorized transition engine.

    Produces records **bit-for-bit identical** to
    :func:`run_scenario_sim` (pinned over the full registry by
    ``tests/test_vectorized.py``) by compiling the trace to count-state
    transitions, charging each distinct transition once (closed-form
    where the shape allows, object-path synthesis otherwise) and
    stamping cached records per event.  Traces outside the fast path's
    domain fall back to the object walk wholesale, so this is a safe
    drop-in for every scenario.

    Pass a shared :class:`TransitionCache` to amortize charging across
    runs that share a cost context (e.g. Monte-Carlo seed replicas).
    ``strategy=`` / ``cost_model=`` are the normalized keyword overrides
    (see :func:`resolve_engine`).  ``throughput=`` accrues modeled
    compute segments exactly as :func:`run_scenario_sim` does — the
    cached field dicts stay model-independent (they carry the sentinel
    ``time_to_result_s == est_wall_s``) and the segment is added at
    stamping time, so a shared cache stays valid across models.
    """
    engine = resolve_engine(scenario, engine, strategy=strategy,
                            cost_model=cost_model)
    plan = _vector_plan(scenario, engine)
    if plan is None:
        return run_scenario_sim(scenario, engine, throughput=throughput)
    cache = cache if cache is not None else TransitionCache()
    # Hot loop: hits read the cache dict directly (no method-call
    # overhead); only misses go through charge_fields for the full
    # charging + bookkeeping path.
    charge_fields = cache.charge_fields
    lookup = cache._fields.get
    new = ScenarioRecord.__new__
    out: list[ScenarioRecord] = []
    append = out.append
    hits = 0
    step_time = _segment_clock(scenario, throughput)
    if step_time is not None:
        # Plan steps are sorted and one record is stamped per tuple, so
        # each record's accrued segment is its step delta times the
        # step time of the allocation it left — vectorized as one
        # np.diff product.  ``tolist()`` matters: Python floats keep
        # record reprs (and the churn-trace parity digest) byte-stable.
        from repro.core.vectorized import segment_times

        seg = segment_times([p[0] for p in plan],
                            [step_time(p[2]) for p in plan]).tolist()
        for i, (step, kind, before, after, qd) in enumerate(plan):
            fields = lookup((kind, before, after, qd))
            if fields is None:
                fields = charge_fields(scenario, engine, kind, before,
                                       after, qd)
            else:
                hits += 1
            rec = new(ScenarioRecord)
            d = rec.__dict__
            d.update(fields)
            d["step"] = step
            d["time_to_result_s"] = fields["time_to_result_s"] + seg[i]
            append(rec)
        cache.hits += hits
        return out
    for step, kind, before, after, qd in plan:
        fields = lookup((kind, before, after, qd))
        if fields is None:
            fields = charge_fields(scenario, engine, kind, before, after, qd)
        else:
            hits += 1
        rec = new(ScenarioRecord)
        d = rec.__dict__
        d.update(fields)
        d["step"] = step
        append(rec)
    cache.hits += hits
    return out


def scenario_pool(scenario: Scenario, devices=None):
    """Build the live :class:`~repro.elastic.node_group.DevicePool` a
    scenario expects: uniform ``cores_per_node``-wide nodes, or the
    scenario's uneven ``core_pool`` width vector, carrying the trace's
    declared rack topology (if any).  ``devices=None`` fabricates
    bookkeeping-only fake device objects sized to the pool.
    """
    from repro.elastic.node_group import DevicePool

    topo = scenario.topology()
    if scenario.core_pool:
        if devices is None:
            devices = [object() for _ in range(sum(scenario.core_pool))]
        return DevicePool(devices=devices, node_widths=scenario.core_pool,
                          topology=topo)
    cpn = scenario.cores_per_node
    if topo is not None:
        # Uniform widths, but the rack tree fixes the node count (it may
        # exceed the trace's peak: spare whole racks are legitimate).
        widths = (cpn,) * topo.n_nodes
        if devices is None:
            devices = [object() for _ in range(sum(widths))]
        return DevicePool(devices=devices, node_widths=widths, topology=topo)
    if devices is None:
        devices = [object() for _ in range(scenario.max_nodes() * cpn)]
    return DevicePool(devices=devices, devices_per_node=cpn)


def check_scenario_pool(scenario: Scenario, pool) -> None:
    """Raise unless a caller-supplied pool can replay ``scenario`` in
    lockstep with the simulator.

    Both executors derive plans from node widths, so a pool whose
    widths disagree with the scenario's (``core_pool``, or the uniform
    ``cores_per_node``) would not error — it would silently produce
    different timelines and break the sim == live parity every consumer
    relies on.  Fail loudly instead.
    """
    n = scenario.max_nodes()
    if pool.n_nodes < n:
        raise ValueError(
            f"scenario {scenario.name!r} peaks at {n} nodes but the pool "
            f"only has {pool.n_nodes}"
        )
    widths = tuple(pool.node_widths[:n])
    expect = (tuple(scenario.core_pool[:n]) if scenario.core_pool
              else (scenario.cores_per_node,) * n)
    if widths != expect:
        raise ValueError(
            f"pool widths {widths} do not match scenario "
            f"{scenario.name!r} widths {expect}; the live runtime would "
            "plan different timelines than the simulator"
        )
    topo = scenario.topology()
    if topo is not None and pool.topology != topo:
        raise ValueError(
            f"pool topology {pool.topology} does not match scenario "
            f"{scenario.name!r} topology {topo}; placement and "
            "distance-class pricing would silently diverge from the "
            "simulator"
        )


def run_scenario_live(
    scenario: Scenario,
    pool=None,
    engine: Optional[ReconfigEngine] = None,
    *,
    strategy=None,
    cost_model=None,
    throughput: "Optional[ThroughputModel]" = None,
) -> list[ScenarioRecord]:
    """Execute a scenario against the live NodeGroup runtime.

    Bookkeeping-only (fake devices by default): exercises the identical
    engine/backend path the :class:`ElasticTrainer` uses, without JAX
    compilation, so tests can assert sim/live timeline agreement cheaply.
    Heterogeneous traces run too: the pool is partitioned with the
    scenario's uneven ``core_pool`` width vector.  ``strategy=`` /
    ``cost_model=`` are the normalized keyword overrides (see
    :func:`resolve_engine`); ``throughput=`` accrues modeled compute
    segments into ``time_to_result_s`` exactly as the simulator does.
    """
    from repro.elastic.runtime import ElasticRuntime

    engine = resolve_engine(scenario, engine, strategy=strategy,
                            cost_model=cost_model)
    if pool is None:
        pool = scenario_pool(scenario)
    else:
        check_scenario_pool(scenario, pool)
    rt = ElasticRuntime(pool=pool, initial_nodes=scenario.initial_nodes,
                        engine=engine)
    adapter = RuntimeAdapter(rt)
    records: list[ScenarioRecord] = []
    step_time = _segment_clock(scenario, throughput)
    last = 0
    for ev in sorted(scenario.events, key=lambda e: e.step):
        for rec in _dispatch(adapter, ev):
            if step_time is None:
                records.append(replace(rec, step=ev.step))
            else:
                seg = (ev.step - last) * step_time(rec.nodes_before)
                last = ev.step
                records.append(replace(
                    rec, step=ev.step,
                    time_to_result_s=rec.time_to_result_s + seg))
    return records
