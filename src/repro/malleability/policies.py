"""RMS policy engine: WHO grows, WHO shrinks, and WHEN.

The paper's contribution is the *mechanism* — parallel spawning makes a
resize cheap.  The policy literature (Iserte et al., "Resource
Optimization with MPI Process Malleability"; Chadha et al., "Extending
SLURM for Dynamic Resource-Aware Adaptive Batch Scheduling") shows the
makespan wins come from the scheduler exploiting that cheapness.  This
module is the policy side of the reproduction:

* :class:`ClusterState` — the RMS's ledger: one shared node pool plus
  per-job allocations (distinct from :class:`repro.core.ClusterState`,
  which is a single job's *world* bookkeeping).  Build it from a live
  :class:`~repro.elastic.node_group.DevicePool` via :meth:`from_pool` to
  schedule over the same pool the runtime partitions.
* :class:`RmsPolicy` implementations — :class:`BackfillPolicy` (idle
  nodes flow to malleable jobs and are reclaimed under queue pressure),
  :class:`PreemptionPolicy` (priority arrivals force-shrink
  lower-priority jobs, composing with in-flight reconfigurations), and
  :class:`ChurnPolicy` (seeded long-horizon grow/shrink cycling).
* :func:`arbitrate_jobs` — the multi-job path: several jobs' traces are
  charged against ONE pool; conflicts surface as queued RESIZE events
  (deferred steps + ``queue_delay_s`` QUEUE spans) and degraded overlap
  (the scenario's ``contention`` override).

Every policy *generates* a declarative
:class:`~repro.malleability.scenarios.Scenario`, so the existing
sim/live machinery consumes policy output unchanged — the parity the
rest of the repo pins (sim == live per event) holds for policy traces
for free.
"""
from __future__ import annotations

import math
import random
import warnings
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

from repro.core import Topology

from .cost_model import MN5, CostModel
from .scenarios import (
    CHECKPOINT,
    GROW,
    RESTART,
    SHRINK,
    Scenario,
    ScenarioEvent,
    TransitionCache,
    param_bytes_for_arch,
    register_scenario,
    run_scenario_sim,
    run_scenario_vectorized,
    steady_cycle,
)
from .throughput import ThroughputModel


# ============================================================= cluster view ==
@dataclass(frozen=True)
class JobSpec:
    """One job as the RMS sees it (limits + scheduling class)."""

    name: str
    min_nodes: int = 1               # guaranteed floor (never reclaimed below)
    max_nodes: int = 8               # grant ceiling
    priority: int = 0                # higher preempts lower
    malleable: bool = True           # rigid jobs neither grow nor shrink
    initial_nodes: int = 0           # 0 -> min_nodes
    arch: str = ""                   # pytree the job reshards on resize
    param_bytes: int = 0             # explicit pytree size (overrides arch)

    def __post_init__(self) -> None:
        if self.min_nodes < 1 or self.max_nodes < self.min_nodes:
            raise ValueError(
                f"job {self.name!r}: need 1 <= min_nodes <= max_nodes, "
                f"got [{self.min_nodes}, {self.max_nodes}]"
            )

    def start_nodes(self) -> int:
        return self.initial_nodes or self.min_nodes


@dataclass
class ClusterState:
    """RMS-side cluster ledger: a shared node pool + per-job allocations.

    NOT :class:`repro.core.ClusterState` (one job's world/rank
    bookkeeping): this is the scheduler's view ACROSS jobs.  Policies
    read it to decide who grows/shrinks; they never mutate it — a policy
    run is a pure function from this view to a trace.

    ``topology`` is the pool's node -> rack -> pod tree (when known):
    policy-generated scenarios inherit it, so their traces replay with
    topology-aware placement and distance-class stage-3 pricing — the
    dynamic-resource-aware-SLURM view where the scheduler knows the
    rack layout it is granting from.
    """

    total_nodes: int
    jobs: tuple[JobSpec, ...] = ()
    allocations: Dict[str, int] = field(default_factory=dict)
    topology: Optional[Topology] = None

    def __post_init__(self) -> None:
        if self.total_nodes <= 0:
            raise ValueError("total_nodes must be positive")
        if self.topology is not None and self.topology.n_nodes < self.total_nodes:
            raise ValueError(
                f"topology covers {self.topology.n_nodes} nodes but the "
                f"pool holds {self.total_nodes}"
            )
        names = [j.name for j in self.jobs]
        if len(names) != len(set(names)):
            raise ValueError(f"duplicate job names: {names}")
        for j in self.jobs:
            self.allocations.setdefault(j.name, j.start_nodes())
        if self.allocated() > self.total_nodes:
            raise ValueError(
                f"over-committed: {self.allocated()} nodes allocated on a "
                f"{self.total_nodes}-node pool"
            )

    @classmethod
    def from_pool(cls, pool, jobs: Sequence[JobSpec] = ()) -> "ClusterState":
        """Schedule over a live :class:`~repro.elastic.node_group.DevicePool`
        (or anything with ``n_nodes``): the policy layer then sees exactly
        the pool the elastic runtime partitions — its rack topology
        included, when the pool carries one."""
        return cls(total_nodes=pool.n_nodes, jobs=tuple(jobs),
                   topology=getattr(pool, "topology", None))

    # ---- queries -----------------------------------------------------------
    def spec(self, name: str) -> JobSpec:
        for j in self.jobs:
            if j.name == name:
                return j
        raise KeyError(f"unknown job {name!r}")

    def allocated(self) -> int:
        return sum(self.allocations.values())

    def idle_nodes(self) -> int:
        return self.total_nodes - self.allocated()

    def malleable_jobs(self) -> tuple[JobSpec, ...]:
        return tuple(j for j in self.jobs if j.malleable)

    def primary_malleable(self) -> JobSpec:
        mall = self.malleable_jobs()
        if not mall:
            raise ValueError("cluster has no malleable job to schedule")
        return mall[0]

    def clamp_grant(self, job: JobSpec, requested: int) -> int:
        """Clamp a grant to the job's limits AND the pool's capacity.

        A policy may *request* anything (e.g. backfill offering a job
        more nodes than the pool holds); the grant is what fits:
        ``[min_nodes, min(max_nodes, pool minus other jobs)]``.  Never
        raises — an oversized request clamps, it does not crash.
        """
        others = self.allocated() - self.allocations.get(job.name, 0)
        cap = min(job.max_nodes, self.total_nodes - others)
        return max(job.min_nodes, min(requested, cap))


# ============================================================ policy output ==
@dataclass
class PolicyTrace:
    """A policy run's output: per-job declarative event traces.

    ``scenario(job)`` materializes one job's trace as a plain
    :class:`Scenario` — the same object the simulator, the live runtime,
    the trainer, and the benchmarks already consume.
    """

    policy: str
    cluster_nodes: int
    initial: Dict[str, int]                       # job -> starting nodes
    events: Dict[str, Tuple[ScenarioEvent, ...]]  # job -> trace
    steps: int
    specs: Dict[str, JobSpec] = field(default_factory=dict)
    topology: Optional[Topology] = None           # pool layout, if known

    @property
    def primary_job(self) -> str:
        return next(iter(self.initial))

    def scenario(self, job: Optional[str] = None, *, name: str = "",
                 description: str = "", **overrides) -> Scenario:
        job = job if job is not None else self.primary_job
        if job not in self.events:
            raise KeyError(
                f"no trace for job {job!r}; traced: {sorted(self.events)}")
        spec = self.specs.get(job)
        kwargs: Dict[str, Any] = dict(
            arch=spec.arch if spec else "",
            param_bytes=spec.param_bytes if spec else 0,
        )
        if self.topology is not None:
            # The generated trace inherits the pool's rack layout, so
            # replays place and price against the real topology.
            kwargs["rack_sizes"] = self.topology.rack_sizes
            kwargs["pod_sizes"] = self.topology.pod_sizes
        kwargs.update(overrides)
        return Scenario(
            name=name or f"{self.policy}:{job}",
            description=description or (
                f"{self.policy} policy trace for job {job!r} on a "
                f"{self.cluster_nodes}-node pool"),
            initial_nodes=self.initial[job],
            events=self.events[job],
            steps=self.steps,
            **kwargs,
        )

    def scenarios(self) -> Dict[str, Scenario]:
        return {job: self.scenario(job) for job in self.events}


@runtime_checkable
class RmsPolicy(Protocol):
    """A scheduling policy: cluster view in, declarative traces out."""

    name: str

    def generate(self, cluster: ClusterState) -> PolicyTrace: ...


# ---- shared helpers ---------------------------------------------------------
def _resize(step: int, current: int, target: int) -> ScenarioEvent:
    """One RMS resize decision as a scenario event.

    Grows name the new total; shrinks name the victim node ids — always
    the TOP ids, which keeps sim and live node trajectories identical
    (both acquire lowest-free first) and live device order a prefix of
    ``jax.devices()``.
    """
    if target > current:
        return ScenarioEvent(step=step, kind=GROW, target_nodes=target)
    if target < current:
        return ScenarioEvent(step=step, kind=SHRINK,
                             nodes=tuple(range(target, current)))
    raise ValueError("resize to the current size is a no-op")


def _check_arrival_window(arrivals, start_step: int, horizon: int,
                          policy: str) -> None:
    """Reject arrivals the stepped walk would silently never see."""
    for a in arrivals:
        if not start_step <= a.step < horizon:
            raise ValueError(
                f"{policy}: arrival at step {a.step} is outside the "
                f"scheduled window [start_step={start_step}, "
                f"horizon={horizon}) and would be silently ignored")


def _trial_walls(events: Sequence[ScenarioEvent], template: Scenario) -> List[float]:
    """Per-event charged wall times (queue-free), via a throwaway sim run."""
    stripped = tuple(replace(e, queue_delay_s=0.0)
                     for e in sorted(events, key=lambda e: e.step))
    trial = replace(
        template,
        name=template.name + "__trial",
        events=stripped,
        steps=max((e.step for e in stripped), default=0) + 2,
    )
    recs = run_scenario_sim(trial)
    if len(recs) != len(stripped):
        raise ValueError(
            f"trace for {template.name!r} has ineffective events "
            f"({len(stripped)} events, {len(recs)} records); per-event "
            "walls are ambiguous")
    return [r.est_wall_s for r in recs]


def charge_in_flight_queueing(scenario: Scenario) -> Scenario:
    """Charge same-step successors as queued behind the in-flight event.

    When two events land on one application step (a preemption arriving
    mid-grow, a composed drop+regrow), the second cannot start until the
    first's reconfiguration drains: its ``queue_delay_s`` becomes the
    sum of the earlier same-step events' charged walls.  Single-event
    steps are untouched; a scenario without step collisions is returned
    unchanged.
    """
    events = tuple(sorted(scenario.events, key=lambda e: e.step))
    if len({e.step for e in events}) == len(events):
        return scenario
    walls = _trial_walls(events, scenario)
    out = []
    for i, ev in enumerate(events):
        acc = sum(walls[j] for j in range(i) if events[j].step == ev.step)
        out.append(replace(ev, queue_delay_s=acc) if acc > 0 else ev)
    return replace(scenario, events=tuple(out))


def _predicted_wall(template: Scenario, event: ScenarioEvent,
                    cost_model: Optional[CostModel] = None,
                    prelude: Tuple[ScenarioEvent, ...] = (),
                    throughput: Optional[ThroughputModel] = None,
                    horizon_steps: int = 0) -> float:
    """Charged wall of ONE candidate event via a throwaway sim run.

    The decision engine behind mechanism choices: the candidate is
    charged by the same engine both executors use, so "which path is
    cheaper" is answered with the numbers the timeline would actually
    show, not a side formula that could drift.  ``prelude`` events set
    up the cluster state the candidate fires from (e.g. a grow, so the
    job holds node-confined worlds like a real trace would); only the
    LAST record — the candidate's — is returned.  With a
    ``throughput=`` model, the remaining ``horizon_steps`` are priced
    at the candidate's landing allocation and added in, so candidates
    that end on different sizes compete on predicted time-to-result,
    not on reconfiguration wall alone.
    """
    events = tuple(prelude) + (replace(event, queue_delay_s=0.0),)
    trial = replace(
        template,
        name=template.name + "__decide",
        events=events,
        steps=max(e.step for e in events) + 2,
    )
    recs = run_scenario_sim(trial, cost_model=cost_model)
    wall = recs[-1].est_wall_s
    if throughput is not None and horizon_steps > 0:
        widths = throughput.widths_for(
            recs[-1].nodes_after, core_pool=template.core_pool,
            default_width=template.cores_per_node)
        wall += horizon_steps * throughput.step_time(widths)
    return wall


# ================================================================= policies ==
@dataclass(frozen=True)
class RigidArrival:
    """A rigid (non-malleable) batch job entering the queue."""

    step: int
    nodes: int
    duration: int
    priority: int = 0


@dataclass(frozen=True)
class BackfillPolicy:
    """Idle nodes flow to malleable jobs; queue pressure reclaims them.

    The EASY-backfill intuition under malleability (Iserte et al.): a
    malleable job soaks up whatever the rigid queue is not using, down
    to its guaranteed ``min_nodes`` floor when rigid jobs need the
    space.  A rigid arrival starts as soon as the pool minus that floor
    fits it (the malleable job is force-shrunk to make room); otherwise
    it waits in FIFO order.  Grants are clamped by
    :meth:`ClusterState.clamp_grant` — a job whose ``max_nodes`` exceeds
    the pool simply receives the pool, never an error.
    """

    arrivals: Tuple[RigidArrival, ...] = ()
    horizon: int = 40
    start_step: int = 2
    name: str = "backfill"

    def generate(self, cluster: ClusterState) -> PolicyTrace:
        job = cluster.primary_malleable()
        _check_arrival_window(self.arrivals, self.start_step, self.horizon,
                              self.name)
        alloc = cluster.allocations[job.name]
        events: List[ScenarioEvent] = []
        queue: List[RigidArrival] = []
        running: List[List[int]] = []          # [end_step, nodes]
        arrivals = sorted(self.arrivals, key=lambda a: a.step)
        for step in range(self.start_step, self.horizon):
            running = [r for r in running if r[0] > step]
            queue.extend(a for a in arrivals if a.step == step)
            rigid_used = sum(r[1] for r in running)
            waiting: List[RigidArrival] = []
            for a in queue:     # FIFO: start whatever fits above the floor
                if a.nodes <= cluster.total_nodes - rigid_used - job.min_nodes:
                    running.append([step + a.duration, a.nodes])
                    rigid_used += a.nodes
                else:
                    waiting.append(a)
            queue = waiting
            target = cluster.clamp_grant(job, cluster.total_nodes - rigid_used)
            if target != alloc:
                events.append(_resize(step, alloc, target))
                alloc = target
        return PolicyTrace(
            policy=self.name,
            cluster_nodes=cluster.total_nodes,
            initial={job.name: cluster.allocations[job.name]},
            events={job.name: tuple(events)},
            steps=self.horizon + 2,
            specs={job.name: job},
            topology=cluster.topology,
        )


@dataclass(frozen=True)
class PriorityArrival:
    """A high-priority job demanding nodes NOW (preemption source)."""

    step: int
    nodes: int
    duration: int
    priority: int = 100


@dataclass(frozen=True)
class PreemptionPolicy:
    """Priority arrivals force-shrink lower-priority malleable jobs.

    The malleable job grows opportunistically into idle nodes; when a
    higher-priority job arrives, the policy immediately reclaims down to
    whatever still fits beside the preemptor.  A preemption landing on a
    step where the victim already has a reconfiguration in flight (the
    opportunistic grow at the same step) COMPOSES with it instead of
    cancelling: the forced shrink is emitted at the same step, queued
    behind the in-flight event's charged wall
    (:func:`charge_in_flight_queueing`), so both executors see the grow
    drain first and the preemption pay its QUEUE span.

    ``mechanism`` picks HOW the victim gives nodes back: ``"shrink"``
    (the default — malleable TS shrink, the historical trace bit for
    bit), ``"restart"`` (rigid full-stop checkpoint/restart at the
    smaller size — what a non-malleable job would do), or ``"auto"``
    (charge both candidates through the engine and emit whichever
    predicts the smaller ``est_wall`` — the dynamic-awareness decision
    rule).  ``decision_cost_model`` overrides the cost model the
    ``"auto"`` comparison charges with (e.g. the actual cluster's
    measured constants), without touching the trace's replay pricing.
    With a ``throughput=`` model, the ``"auto"`` comparison prices the
    steps remaining to the horizon at each candidate's landing
    allocation on top of the reconfiguration wall — predicted
    time-to-result, not downtime alone.  (Both mechanisms currently
    land on the same target size, so the added term is symmetric and
    today's decisions are unchanged; it starts discriminating the
    moment a mechanism lands elsewhere, e.g. a restart that rounds to
    a power-of-two world.)
    """

    arrivals: Tuple[PriorityArrival, ...] = ()
    horizon: int = 24
    start_step: int = 2
    name: str = "preemption"
    mechanism: str = "shrink"        # shrink | restart | auto
    decision_cost_model: Optional[CostModel] = None
    throughput: Optional[ThroughputModel] = None

    def _preempt_event(self, job: JobSpec, step: int, alloc: int,
                       target: int) -> ScenarioEvent:
        """The reclaim event for one forced ``alloc -> target`` resize."""
        if self.mechanism == "shrink":
            return _resize(step, alloc, target)
        restart_ev = ScenarioEvent(step=step, kind=RESTART,
                                   target_nodes=target)
        if self.mechanism == "restart":
            return restart_ev
        if self.mechanism != "auto":
            raise ValueError(
                f"{self.name}: unknown mechanism {self.mechanism!r}; "
                "expected 'shrink', 'restart' or 'auto'")
        # The trial replays the job's actual shape at decision time: it
        # grew into ``alloc`` node-confined worlds, so the shrink
        # candidate prices as a real TS teardown, not a zombification
        # of one big initial world.
        template = Scenario(
            name=f"{self.name}:{job.name}",
            description="preemption mechanism decision trial",
            initial_nodes=1,
            events=(),
            steps=step + 2,
            arch=job.arch,
            param_bytes=job.param_bytes,
        )
        prelude = (
            (ScenarioEvent(step=max(0, step - 1), kind=GROW,
                           target_nodes=alloc),)
            if alloc > 1 else ()
        )
        shrink_ev = _resize(step, alloc, target)
        cm = self.decision_cost_model
        remaining = max(0, self.horizon - step)
        t_shrink = _predicted_wall(template, shrink_ev, cost_model=cm,
                                   prelude=prelude,
                                   throughput=self.throughput,
                                   horizon_steps=remaining)
        t_restart = _predicted_wall(template, restart_ev, cost_model=cm,
                                    prelude=prelude,
                                    throughput=self.throughput,
                                    horizon_steps=remaining)
        return shrink_ev if t_shrink <= t_restart else restart_ev

    def generate(self, cluster: ClusterState) -> PolicyTrace:
        job = cluster.primary_malleable()
        _check_arrival_window(self.arrivals, self.start_step, self.horizon,
                              self.name)
        alloc = cluster.allocations[job.name]
        events: List[ScenarioEvent] = []
        running: List[List[int]] = []          # [end_step, nodes]
        arrivals = sorted(self.arrivals, key=lambda a: a.step)
        for step in range(self.start_step, self.horizon):
            running = [r for r in running if r[0] > step]
            used = sum(r[1] for r in running)
            # Opportunistic growth first: the job is mid-cycle when a
            # same-step preemptor lands.
            target = cluster.clamp_grant(job, cluster.total_nodes - used)
            if target != alloc:
                events.append(_resize(step, alloc, target))
                alloc = target
            for a in (a for a in arrivals if a.step == step):
                if a.priority <= job.priority:
                    continue                   # not allowed to preempt us
                # Even a preemptor cannot take the victim's guaranteed
                # floor or more than the pool still holds: the grant is
                # trimmed so the ledger never over-commits.
                grant = min(a.nodes,
                            cluster.total_nodes - used - job.min_nodes)
                if grant <= 0:
                    continue                   # nothing reclaimable
                running.append([step + a.duration, grant])
                used += grant
                target = cluster.clamp_grant(job, cluster.total_nodes - used)
                if target < alloc:
                    events.append(self._preempt_event(job, step, alloc, target))
                    alloc = target
        trace = PolicyTrace(
            policy=self.name,
            cluster_nodes=cluster.total_nodes,
            initial={job.name: cluster.allocations[job.name]},
            events={job.name: tuple(events)},
            steps=self.horizon + 2,
            specs={job.name: job},
            topology=cluster.topology,
        )
        # Resolve mid-cycle compositions into QUEUE charges.
        queued = charge_in_flight_queueing(trace.scenario(job.name))
        trace.events[job.name] = queued.events
        return trace


@dataclass(frozen=True)
class ChurnPolicy:
    """Seeded long-horizon grow/shrink cycling (RMS allocation churn).

    Every ``period`` steps the RMS moves the malleable job to a fresh
    uniformly-drawn target in ``[min_nodes, min(max_nodes, pool)]``
    (never the current size, so every decision is a real RESIZE).  The
    trace is a pure function of ``seed`` — identical seeds yield
    identical traces, which is what lets a 200-event churn run be pinned
    by tests and replayed bit-for-bit in CI.
    """

    decisions: int = 200
    period: int = 1
    seed: int = 0
    start_step: int = 2
    name: str = "churn"

    def generate(self, cluster: ClusterState) -> PolicyTrace:
        job = cluster.primary_malleable()
        lo = job.min_nodes
        hi = min(job.max_nodes, cluster.total_nodes)
        if hi <= lo:
            raise ValueError(
                f"churn needs headroom: job {job.name!r} is pinned at "
                f"{lo} nodes on this pool")
        rng = random.Random(self.seed)
        alloc = cluster.allocations[job.name]
        events: List[ScenarioEvent] = []
        step = self.start_step
        for _ in range(self.decisions):
            # Stream-identical O(1) draw: ``random.choice(seq)`` consumes
            # exactly one ``_randbelow(len(seq))``, and ``randrange(n)``
            # is that same call, so indexing the ``hi - lo`` non-current
            # candidates and skipping past ``alloc`` reproduces the
            # historical list-based choice bit-for-bit without
            # materializing the list (``hi`` can be a 10k-node pod).
            if lo <= alloc <= hi:
                target = lo + rng.randrange(hi - lo)
                if target >= alloc:
                    target += 1
            else:  # alloc outside the band: every candidate is drawable
                target = lo + rng.randrange(hi - lo + 1)
            events.append(_resize(step, alloc, target))
            alloc = target
            step += self.period
        return PolicyTrace(
            policy=self.name,
            cluster_nodes=cluster.total_nodes,
            initial={job.name: cluster.allocations[job.name]},
            events={job.name: tuple(events)},
            steps=step + 2,
            specs={job.name: job},
            topology=cluster.topology,
        )


@dataclass(frozen=True)
class CheckpointIntervalPolicy:
    """Young/Daly checkpoint cadence: ``T_opt = sqrt(2 * C * MTBF)``.

    The adaptive fault-tolerance policy: instead of resizing, it decides
    WHEN to snapshot.  The checkpoint cost ``C`` is priced by the SAME
    cost model that charges the timeline (``cm.checkpoint`` over the
    job's pytree), so a bigger model or a slower store link directly
    stretches the interval, and a shorter MTBF tightens it — the
    classic first-order optimum balancing snapshot overhead against
    expected rework.  The generated trace is a pure CHECKPOINT cadence
    the existing sim/live machinery replays unchanged.

    ``step_time_s`` defaults to the historical 1 s/step; give the
    policy a ``throughput=`` model instead and the cadence tracks the
    job's actual allocation — a wide grant shortens the step, which
    stretches the interval in *steps* exactly as Young/Daly says it
    should.
    """

    mtbf_s: float = 3600.0           # mean time between failures
    step_time_s: float = 1.0         # seconds of compute per app step
    horizon: int = 40
    start_step: int = 2
    cost_model: Optional[CostModel] = None   # pricing for C (default MN5)
    name: str = "ckpt-interval"
    throughput: Optional[ThroughputModel] = None

    def resolved_step_time_s(self, nodes: int = 0) -> float:
        """Seconds per app step: modeled when a ``throughput`` model and
        a real allocation are given, the flat ``step_time_s`` otherwise.
        """
        if self.throughput is None or nodes <= 0:
            return self.step_time_s
        return self.throughput.step_time(self.throughput.widths_for(nodes))

    def interval_steps(self, job: JobSpec, nodes: int = 0) -> int:
        """Young/Daly optimum, floored at one step.

        A zero-byte pytree prices ``C = 0`` and degenerates to
        checkpointing every step — harmless, but callers sizing real
        jobs should give the spec an ``arch`` or ``param_bytes``.
        ``nodes`` is the job's current allocation, used to resolve the
        modeled step time when a ``throughput`` model is set.
        """
        cm = self.cost_model if self.cost_model is not None else MN5
        pb = job.param_bytes or (
            param_bytes_for_arch(job.arch) if job.arch else 0)
        cost = cm.checkpoint(pb)
        t_opt = math.sqrt(2.0 * cost * self.mtbf_s)
        return max(1, round(t_opt / self.resolved_step_time_s(nodes)))

    def generate(self, cluster: ClusterState) -> PolicyTrace:
        job = cluster.primary_malleable()
        every = self.interval_steps(job, cluster.allocations[job.name])
        events = tuple(
            ScenarioEvent(step=s, kind=CHECKPOINT)
            for s in range(self.start_step + every, self.horizon, every)
        )
        return PolicyTrace(
            policy=self.name,
            cluster_nodes=cluster.total_nodes,
            initial={job.name: cluster.allocations[job.name]},
            events={job.name: events},
            steps=self.horizon + 2,
            specs={job.name: job},
            topology=cluster.topology,
        )


@dataclass(frozen=True)
class TrafficPolicy:
    """Request-traffic autoscaler: a rate trace + SLO targets in,
    grow/shrink decisions out.

    The serving-plane policy (ROADMAP item 1): instead of batch RESIZE
    events, the RMS watches a **request-rate trace** (requests arriving
    per application step) and sizes the decode pool so the SLO holds.
    The demand model is Little's law plus a backlog-drain term:

    * each admitted request occupies one decode slot for ``hold_steps``
      steps, so steady-state demand is ``rate * hold_steps`` slots;
    * a worker serves ``slots_per_worker / hold_steps`` requests per
      step; arrivals beyond that accumulate as ``backlog``, and the SLO
      requires draining it within ``slo_queue_steps`` steps — an extra
      ``backlog * hold_steps / slo_queue_steps`` slots of demand.

    The slot demand is fitted UP the ``allowed_sizes`` ladder (decode
    worker counts that shard the service's batch — like trainer world
    sizes, powers of two here), then clamped by
    :meth:`ClusterState.clamp_grant`.  Grows fire **immediately** (an
    SLO breach is paid in tail latency every step it persists), carrying
    ``grant_delay_s`` as their QUEUE span — the RMS arbitration wait for
    the grant, charged on the timeline like every other queue delay.
    Shrinks wait for ``cooldown`` consecutive below-target steps, the
    standard anti-flapping hysteresis.

    A policy run is a pure function of the rate trace, so its
    :class:`PolicyTrace` — and the registered serve scenarios built from
    it — replay bit-identically through sim, live, and trainer
    executors.  The serving loop (:func:`repro.serving.run_serve`)
    replays the SAME rate trace for its arrivals, so latency and
    queueing are emergent from the decisions made here.
    """

    rates: Tuple[float, ...] = ()     # requests arriving per step
    slots_per_worker: int = 5         # concurrent decode slots per worker
    hold_steps: int = 8               # steps one request occupies a slot
    slo_queue_steps: float = 4.0      # drain backlog within this many steps
    allowed_sizes: Tuple[int, ...] = (1, 2, 4, 8)
    cooldown: int = 2                 # below-target steps before a shrink
    grant_delay_s: float = 0.0        # RMS arbitration wait per grow grant
    name: str = "traffic"

    def __post_init__(self) -> None:
        if not self.rates:
            raise ValueError("traffic policy needs a non-empty rate trace")
        if min(self.rates) < 0:
            raise ValueError("request rates cannot be negative")
        if self.slots_per_worker < 1 or self.hold_steps < 1:
            raise ValueError("slots_per_worker and hold_steps must be >= 1")
        if self.slo_queue_steps <= 0:
            raise ValueError("slo_queue_steps must be positive")
        if self.cooldown < 1:
            raise ValueError("cooldown must be >= 1")
        if not self.allowed_sizes or sorted(self.allowed_sizes) != list(
                self.allowed_sizes):
            raise ValueError("allowed_sizes must be ascending and non-empty")

    def demand_workers(self, rate: float, backlog: float) -> int:
        """Workers needed for one step's rate + backlog (before the ladder)."""
        slots = (rate * self.hold_steps
                 + backlog * self.hold_steps / self.slo_queue_steps)
        return max(1, math.ceil(slots / self.slots_per_worker))

    def generate(self, cluster: ClusterState) -> PolicyTrace:
        job = cluster.primary_malleable()
        alloc = cluster.allocations[job.name]
        backlog = 0.0
        below = 0
        events: List[ScenarioEvent] = []
        for step, rate in enumerate(self.rates):
            served = alloc * self.slots_per_worker / self.hold_steps
            backlog = max(0.0, backlog + rate - served)
            need = self.demand_workers(rate, backlog)
            fitted = next((s for s in self.allowed_sizes if s >= need),
                          self.allowed_sizes[-1])
            target = cluster.clamp_grant(job, fitted)
            if target > alloc:
                ev = _resize(step, alloc, target)
                if self.grant_delay_s > 0.0:
                    ev = replace(ev, queue_delay_s=self.grant_delay_s)
                events.append(ev)
                alloc = target
                below = 0
            elif target < alloc:
                below += 1
                if below >= self.cooldown:
                    events.append(_resize(step, alloc, target))
                    alloc = target
                    below = 0
            else:
                below = 0
        return PolicyTrace(
            policy=self.name,
            cluster_nodes=cluster.total_nodes,
            initial={job.name: cluster.allocations[job.name]},
            events={job.name: tuple(events)},
            steps=len(self.rates) + 2,
            specs={job.name: job},
            topology=cluster.topology,
        )


# ======================================================= multi-job arbiter ==
@dataclass(frozen=True)
class ArbitratedJob:
    """One job's share of an arbitrated multi-job workload."""

    name: str
    scenario: Scenario
    queued_events: int      # emitted with queue_delay_s > 0
    deferred_events: int    # pushed to a later step by capacity
    clamped_events: int     # grow target cut down to fit the pool
    dropped_events: int     # arbitration made them no-ops


@dataclass(frozen=True)
class MultiJobOutcome:
    """Arbitration result: per-job scenarios + interference accounting."""

    pool_nodes: int
    jobs: Tuple[ArbitratedJob, ...]
    interfered: Tuple[str, ...]

    @property
    def scenarios(self) -> Dict[str, Scenario]:
        return {j.name: j.scenario for j in self.jobs}

    def job(self, name: str) -> ArbitratedJob:
        for j in self.jobs:
            if j.name == name:
                return j
        raise KeyError(name)


def arbitrate_jobs(
    jobs: Sequence[Tuple[str, Scenario]],
    pool_nodes: int,
    *,
    contention: float = 1.25,
    defer_slack: int = 16,
) -> MultiJobOutcome:
    """Charge several jobs' timelines against ONE shared node pool.

    Walks the merged trace step by step, tracking every job's
    allocation.  Interference surfaces exactly the two ways a real RMS
    shows it:

    * **queued RESIZE events** — a grow that does not fit is deferred to
      the first step with capacity; an event landing on a step where
      another reconfiguration is already in flight is emitted with
      ``queue_delay_s`` equal to the in-flight events' charged wall
      (a QUEUE span on its timeline, raising makespan but not downtime);
    * **degraded overlap** — jobs that interfered get the ``contention``
      override on their scenario, so ASYNC hiding buys them less
      (the existing contention factor, per PR 2).

    Grow targets are clamped to the capacity the other jobs leave;
    within a step, scheduled events run in job order and deferred events
    retry after them.  Deferred grows still starved ``defer_slack``
    steps past the last scheduled event are dropped.  Queue delays the
    input traces already carry (e.g. a preemption composed by
    :func:`charge_in_flight_queueing`) are preserved; cross-job waits
    are added on top.

    Args:
        jobs: ``(name, scenario)`` pairs in arrival (priority) order.
        pool_nodes: the shared pool's node count.
        contention: overlap-contention assigned to interfered jobs.
        defer_slack: extra steps a starved grow may wait before dropping.
    Returns:
        A :class:`MultiJobOutcome`; each per-job scenario is standalone
        (private node numbering) and runs through the existing sim/live
        machinery unchanged.
    """
    names = [name for name, _ in jobs]
    if len(names) != len(set(names)):
        raise ValueError(f"duplicate job names: {names}")
    by_name = dict(jobs)
    allocs = {name: sc.initial_nodes for name, sc in jobs}
    if sum(allocs.values()) > pool_nodes:
        raise ValueError(
            f"over-committed: jobs start with {sum(allocs.values())} nodes "
            f"on a {pool_nodes}-node pool")

    sched: Dict[int, List[Tuple[str, ScenarioEvent]]] = {}
    for name, sc in jobs:
        for ev in sorted(sc.events, key=lambda e: e.step):
            sched.setdefault(ev.step, []).append((name, ev))
    last_step = (max(sched) if sched else 0) + defer_slack

    emitted: Dict[str, List[ScenarioEvent]] = {name: [] for name in names}
    emission_order: Dict[int, List[Tuple[str, int]]] = {}
    deferred: List[Tuple[str, ScenarioEvent, bool]] = []  # (job, ev, counted)
    stats = {name: {"queued": 0, "deferred": 0, "clamped": 0, "dropped": 0}
             for name in names}
    interfered: set[str] = set()

    def emit(step: int, name: str, ev: ScenarioEvent) -> None:
        emitted[name].append(ev)
        emission_order.setdefault(step, []).append((name, len(emitted[name]) - 1))

    step = 0
    while step <= last_step and (sched or deferred):
        retries, deferred = deferred, []
        todo = [(n, ev, False) for n, ev in sched.pop(step, [])] + retries
        for name, ev, counted in todo:
            alloc = allocs[name]
            if ev.kind == GROW:
                capacity = pool_nodes - (sum(allocs.values()) - alloc)
                target = min(ev.target_nodes, capacity)
                if ev.target_nodes <= alloc:
                    stats[name]["dropped"] += 1      # already satisfied
                    continue
                if target <= alloc:
                    # capacity-starved: the RESIZE queues for a later step
                    if not counted:
                        stats[name]["deferred"] += 1
                        interfered.add(name)
                    if step < last_step:
                        deferred.append((name, ev, True))
                    else:
                        stats[name]["dropped"] += 1
                    continue
                if target < ev.target_nodes:
                    stats[name]["clamped"] += 1
                    interfered.add(name)
                emit(step, name, ScenarioEvent(
                    step=step, kind=GROW, target_nodes=target,
                    queue_delay_s=ev.queue_delay_s))
                allocs[name] = target
            else:   # shrink / fail / straggler: victims are top private ids
                victims = tuple(n for n in ev.nodes if n < alloc)
                if not victims:
                    stats[name]["dropped"] += 1
                    continue
                emit(step, name, ScenarioEvent(
                    step=step, kind=ev.kind, nodes=victims,
                    queue_delay_s=ev.queue_delay_s))
                allocs[name] = alloc - len(victims)
        step += 1
    assert not deferred     # the step == last_step iteration drops inline

    # Charged walls per emitted event (queue-free), for QUEUE spans.
    walls = {
        name: (_trial_walls(emitted[name], by_name[name]) if emitted[name] else [])
        for name in names
    }
    for step, ems in emission_order.items():
        if len({name for name, _ in ems}) > 1:
            interfered.update(name for name, _ in ems)
        acc = 0.0
        for name, idx in ems:
            if acc > 0.0:
                # Added on top of any wait the input trace already carried
                # (e.g. a preemption composed by charge_in_flight_queueing).
                emitted[name][idx] = replace(
                    emitted[name][idx],
                    queue_delay_s=emitted[name][idx].queue_delay_s + acc)
                stats[name]["queued"] += 1
                interfered.add(name)
            acc += walls[name][idx]

    out = []
    for name, sc in jobs:
        evs = tuple(emitted[name])
        steps = max(sc.steps, max((e.step for e in evs), default=0) + 2)
        arb = replace(
            sc, events=evs, steps=steps,
            contention=(contention if name in interfered else sc.contention),
        )
        s = stats[name]
        out.append(ArbitratedJob(
            name=name, scenario=arb, queued_events=s["queued"],
            deferred_events=s["deferred"], clamped_events=s["clamped"],
            dropped_events=s["dropped"],
        ))
    return MultiJobOutcome(pool_nodes=pool_nodes, jobs=tuple(out),
                           interfered=tuple(sorted(interfered)))


def run_multijob_sim(
    jobs: Sequence[Tuple[str, Scenario]],
    pool_nodes: int,
    *,
    contention: float = 1.25,
    vectorized: bool = True,
    strategy=None,
    cost_model=None,
    throughput: Optional[ThroughputModel] = None,
):
    """Arbitrate and simulate a multi-job workload on one pool.

    Returns ``(records, outcome)``: per-job
    :class:`~repro.malleability.scenarios.ScenarioRecord` lists from the
    timeline-charging simulator, plus the :class:`MultiJobOutcome` whose
    scenarios produced them.  ``vectorized=True`` (the default) runs
    each arbitrated trace through :func:`~repro.malleability.scenarios
    .run_scenario_vectorized` — bit-for-bit the same records, charged
    through the memoizing transition engine; caches are per trace (each
    job carries its own cost context and contention override).
    ``strategy=`` / ``cost_model=`` are the normalized keyword overrides
    shared with every ``run_scenario_*`` executor
    (:func:`~repro.malleability.scenarios.resolve_engine`), applied to
    each arbitrated job's engine; ``throughput=`` accrues each job's
    modeled compute segments into its records' ``time_to_result_s``.
    """
    outcome = arbitrate_jobs(jobs, pool_nodes, contention=contention)
    runner = run_scenario_vectorized if vectorized else run_scenario_sim
    records = {
        name: runner(sc, strategy=strategy, cost_model=cost_model,
                     throughput=throughput)
        for name, sc in outcome.scenarios.items()
    }
    return records, outcome


# =================================================== Monte-Carlo sweeps ==
@dataclass(frozen=True)
class MonteCarloSweep:
    """Per-replica cost distributions of a seeded policy sweep."""

    policy: str
    n_replicas: int
    makespans: Tuple[float, ...]   # per replica: sum of est_wall_s
    downtimes: Tuple[float, ...]   # per replica: sum of downtime_s
    reconfigs: int                 # records charged across all replicas
    cache_hits: int
    cache_misses: int

    def summary(self) -> dict:
        """Distribution summary (mean/min/max) as a flat dict row."""
        def _stats(xs: Tuple[float, ...], tag: str) -> dict:
            return {
                f"{tag}_mean_s": sum(xs) / len(xs) if xs else 0.0,
                f"{tag}_min_s": min(xs, default=0.0),
                f"{tag}_max_s": max(xs, default=0.0),
            }

        row = {"policy": self.policy, "replicas": self.n_replicas,
               "reconfigs": self.reconfigs}
        row.update(_stats(self.makespans, "makespan"))
        row.update(_stats(self.downtimes, "downtime"))
        return row


def monte_carlo_sweep(
    policy, n_replicas: int, *args,
    cluster: Optional[ClusterState] = None, seed: int = 0,
) -> MonteCarloSweep:
    """Seeded Monte-Carlo sweep of a policy's cost distribution.

    Runs ``n_replicas`` replicas of ``policy`` — seeds ``seed ..
    seed + n - 1`` via ``dataclasses.replace(policy, seed=s)``, so the
    policy must carry a ``seed`` field (e.g. :class:`ChurnPolicy`) —
    against ``cluster`` (default: the 8-node single-malleable-job pool
    the registered churn trace uses).  Every replica's trace runs
    through :func:`~repro.malleability.scenarios.run_scenario_vectorized`
    with ONE shared :class:`~repro.malleability.scenarios
    .TransitionCache`: the replicas differ only in their event
    sequences, never in cost context, so transitions seen by any
    replica price the rest for free.  This is what makes 1000-replica
    sweeps over 10k-node pods finish in seconds.

    ``cluster`` and ``seed`` are keyword-only (the normalized executor
    signature); a positional third argument is still accepted as
    ``cluster`` for one release, with a :class:`DeprecationWarning`.
    """
    if args:
        if len(args) > 1 or cluster is not None:
            raise TypeError(
                "monte_carlo_sweep takes at most one positional cluster "
                "(deprecated); pass cluster= and seed= by keyword")
        warnings.warn(
            "passing cluster positionally to monte_carlo_sweep is "
            "deprecated; use monte_carlo_sweep(policy, n, cluster=...)",
            DeprecationWarning, stacklevel=2)
        cluster = args[0]
    if cluster is None:
        cluster = ClusterState(
            total_nodes=8,
            jobs=(JobSpec("train", min_nodes=1, max_nodes=8),),
        )
    job = cluster.primary_malleable().name
    cache = TransitionCache()
    makespans: List[float] = []
    downtimes: List[float] = []
    reconfigs = 0
    for s in range(seed, seed + n_replicas):
        trace = replace(policy, seed=s).generate(cluster)
        sc = trace.scenario(job, name=f"{policy.name}-mc-{s}")
        recs = run_scenario_vectorized(sc, cache=cache)
        reconfigs += len(recs)
        makespans.append(sum(r.est_wall_s for r in recs))
        downtimes.append(sum(r.downtime_s for r in recs))
    return MonteCarloSweep(
        policy=policy.name, n_replicas=n_replicas,
        makespans=tuple(makespans), downtimes=tuple(downtimes),
        reconfigs=reconfigs, cache_hits=cache.hits,
        cache_misses=cache.misses,
    )


# ================================================= registered policy traces ==
def backfill_pressure(name: str = "backfill-pressure") -> Scenario:
    """8-node pool: the malleable job soaks up idle nodes, two rigid
    arrivals reclaim them in waves, and the grant returns as they drain
    (2 -> 8 -> 4 -> 2 -> 6 -> 8)."""
    cluster = ClusterState(
        total_nodes=8,
        jobs=(JobSpec("train", min_nodes=2, max_nodes=8),),
    )
    policy = BackfillPolicy(
        arrivals=(RigidArrival(step=8, nodes=4, duration=8),
                  RigidArrival(step=12, nodes=2, duration=8)),
        horizon=26,
    )
    return policy.generate(cluster).scenario(
        "train", name=name,
        description="backfill grants + reclamation under rigid queue pressure",
    )


def priority_preempt(name: str = "priority-preempt") -> Scenario:
    """Two priority arrivals preempt the malleable job; the second lands
    on the same step as its regrow, so the forced shrink queues behind
    the in-flight reconfiguration (a QUEUE span on its timeline)."""
    cluster = ClusterState(
        total_nodes=8,
        jobs=(JobSpec("train", min_nodes=1, max_nodes=6, priority=0,
                      initial_nodes=2),),
    )
    policy = PreemptionPolicy(
        arrivals=(PriorityArrival(step=6, nodes=4, duration=6),
                  PriorityArrival(step=12, nodes=6, duration=6)),
        horizon=22,
    )
    return policy.generate(cluster).scenario(
        "train", name=name,
        description="priority preemption, incl. one mid-reconfiguration",
    )


def churn_trace(name: str = "churn-200", decisions: int = 200,
                seed: int = 7) -> Scenario:
    """Long-horizon seeded churn: 200 RESIZE decisions on an 8-node pool."""
    cluster = ClusterState(
        total_nodes=8,
        jobs=(JobSpec("train", min_nodes=1, max_nodes=8),),
    )
    policy = ChurnPolicy(decisions=decisions, seed=seed)
    return policy.generate(cluster).scenario(
        "train", name=name,
        description=f"{decisions} seeded grow/shrink churn decisions "
                    f"(seed={seed})",
    )


def two_job_interference(name: str = "two-job-interference") -> Scenario:
    """Two identical breathing jobs arbitrated on one 8-node pool.

    Job B's grows collide with job A's peak: they defer until A shrinks,
    then emit queued behind A's same-step reconfiguration — the
    registered scenario is B's arbitrated trace, carrying both a QUEUE
    span and the degraded-overlap contention override.
    """
    a = steady_cycle(name="ij-a", low=2, high=6, cycles=2, period=4)
    b = steady_cycle(name="ij-b", low=2, high=6, cycles=2, period=4)
    outcome = arbitrate_jobs([("a", a), ("b", b)], pool_nodes=8)
    sc = outcome.job("b").scenario
    return replace(
        sc, name=name,
        description="job B of a two-job pool: grows deferred + queued "
                    "behind job A, overlap degraded by contention",
    )


POLICY_SCENARIO_NAMES = (
    "backfill-pressure",
    "priority-preempt",
    "churn-200",
    "two-job-interference",
)

for _sc in (backfill_pressure(), priority_preempt(), churn_trace(),
            two_job_interference()):
    register_scenario(_sc)


def registered_policy_scenarios() -> tuple[Scenario, ...]:
    """The policy-generated traces in the scenario registry."""
    from .scenarios import get_scenario

    return tuple(get_scenario(n) for n in POLICY_SCENARIO_NAMES)


# ================================================ registered serve traces ==
# Nominal in-flight KV footprint for the registered traces' default
# engines (check_matrix, the nightly sweep, the trainer replay): a fixed
# pytree size so every resize charges stage-3 bytes deterministically.
# The serving loop (repro.serving.run_serve) swaps in the LIVE
# KVPageTable-backed bytes model instead, pricing the actual resident
# pages at each resize.
_SERVE_KV_BYTES = 48 << 20

# The three traffic traces, single-sourced: the TrafficPolicy sizes the
# pool from them AND repro.serving replays them as request arrivals, so
# policy decisions and serving-side queueing always see the same load.
SERVE_TRAFFIC: Dict[str, TrafficPolicy] = {
    # Diurnal breathing: overnight trickle -> morning ramp -> midday
    # peak -> evening decay.  2 -> 4 -> 8 -> 4 -> 2 workers.
    "serve-diurnal": TrafficPolicy(
        rates=(1.0,) * 6 + (2.0,) * 6 + (4.0,) * 8 + (2.0,) * 6 + (1.0,) * 6),
    # Flash crowd: an 8x spike out of nowhere.  One burst grow 2 -> 8
    # (the parallel-spawn story), held past the spike while the backlog
    # drains, then released.  Runs on a 2-rack pool, so the burst opens
    # rack 1 and KV migration pays cross-rack bytes.
    "serve-flashcrowd": TrafficPolicy(
        rates=(1.0,) * 5 + (8.0,) * 6 + (1.0,) * 10),
    # Tail-latency SLO breach: a slow climb that crosses the SLO line
    # twice (staged grows, each waiting grant_delay_s on the RMS
    # arbiter — a QUEUE span on the timeline), then a deep off-peak
    # shrink.  Longer cooldown: SLO pools shed capacity reluctantly.
    "serve-slo": TrafficPolicy(
        rates=(1.0,) * 4 + (1.5,) * 5 + (3.5,) * 6 + (0.5,) * 6,
        grant_delay_s=0.5, cooldown=3),
}


def _serve_cluster(topology: Optional[Topology] = None) -> ClusterState:
    """The 8-node pool every serve trace autoscales over.

    ``min_nodes=2``: the service starts as one two-node world, and a
    shrink below 2 would have to SPLIT that world — the victim node
    would be zombified (§4.7: pinned, not returned) and the engine's
    rank count would diverge from the page table's worker count.  The
    floor keeps every serve shrink on the clean whole-world TS path.
    """
    return ClusterState(
        total_nodes=8,
        jobs=(JobSpec("serve", min_nodes=2, max_nodes=8, initial_nodes=2,
                      param_bytes=_SERVE_KV_BYTES),),
        topology=topology,
    )


def serve_diurnal(name: str = "serve-diurnal") -> Scenario:
    """Diurnal decode-pool breathing: 2 -> 4 -> 8 -> 4 -> 2 workers."""
    trace = SERVE_TRAFFIC["serve-diurnal"].generate(_serve_cluster())
    return trace.scenario(
        "serve", name=name,
        description="decode pool breathing with diurnal request traffic "
                    "(2 -> 4 -> 8 -> 4 -> 2 workers)",
    )


def serve_flashcrowd(name: str = "serve-flashcrowd") -> Scenario:
    """Flash crowd on a 2-rack pool: burst grow 2 -> 8, backlog-drain
    hold, then release — KV migration priced per distance class."""
    trace = SERVE_TRAFFIC["serve-flashcrowd"].generate(
        _serve_cluster(topology=Topology(rack_sizes=(4, 4))))
    return trace.scenario(
        "serve", name=name,
        description="8x flash crowd on a 2-rack decode pool: burst grow "
                    "opens rack 1, KV pages pay cross-rack bandwidth",
        redist_bw_local=25.0e9,
        redist_bw_cross=2.5e9,
        redist_bw_intra_rack=10.0e9,
    )


def serve_slo(name: str = "serve-slo") -> Scenario:
    """Tail-latency SLO climb: two staged grows (each queued behind the
    RMS arbiter's grant delay), then a deep off-peak shrink."""
    trace = SERVE_TRAFFIC["serve-slo"].generate(_serve_cluster())
    return trace.scenario(
        "serve", name=name,
        description="SLO-breach climb 2 -> 4 -> 8 with queued grants, "
                    "then a deep off-peak shrink",
    )


SERVE_SCENARIO_NAMES = (
    "serve-diurnal",
    "serve-flashcrowd",
    "serve-slo",
)

for _sc in (serve_diurnal(), serve_flashcrowd(), serve_slo()):
    register_scenario(_sc)


def registered_serve_scenarios() -> tuple[Scenario, ...]:
    """The traffic-policy serve traces in the scenario registry."""
    from .scenarios import get_scenario

    return tuple(get_scenario(n) for n in SERVE_SCENARIO_NAMES)
