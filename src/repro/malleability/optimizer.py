"""Closed-loop scheduler optimizer: policies that *choose*, at SLURM scale.

The policy layer (:mod:`.policies`) emits traces; nothing in the repo
optimized over them (ROADMAP item 1).  This module closes the loop, in
the shape of Chadha et al.'s dynamic-resource-aware SLURM scheduler and
Iserte et al.'s DMR resource optimization:

* :class:`WorkloadTrace` / :func:`generate_workload` — seeded SLURM-like
  workloads: tens of mixed rigid/malleable jobs and hundreds of
  arrival/resize events on one shared pool.  Two generated workloads are
  registered as ordinary scenarios (``slurm-mix``, ``slurm-burst``), so
  the whole sim/live/vectorized parity machinery replays them unchanged;
* :class:`SchedulerKnobs` — the policy knobs a dynamic RMS tunes:
  backfill hysteresis, the preemption-priority cutoff, and the
  placement grant quantum;
* :func:`evaluate_schedule` — runs the closed scheduling loop for one
  knob setting, arbitrates the resulting per-job traces on the shared
  pool (:func:`~.policies.run_multijob_sim` — the N-job path), charges
  them through the vectorized fast path, and scores the
  :class:`ScheduleObjective` (weighted reconfiguration makespan + mean
  queue time + idle-capacity penalty, all in seconds);
* :func:`rigid_baseline` — the rigid-cluster control: every malleable
  job must request its peak (``max_nodes``) up front and hold it for
  the whole horizon, so rigid arrivals queue behind over-provisioned
  grants.  Zero reconfiguration cost, terrible queue time — the
  trade the paper's malleability case argues against;
* :func:`optimize_schedule` — the seeded search loop: a deterministic
  grid over the knob space plus seeded random restarts, every candidate
  evaluated through the vectorized chargers, first-best kept (same seed
  -> same chosen knobs -> same score, pinned by ``tests/test_api.py``).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from .scenarios import Scenario, ScenarioEvent, register_scenario
from .throughput import ThroughputModel, time_to_result
from .policies import (
    ClusterState,
    JobSpec,
    MultiJobOutcome,
    RigidArrival,
    _resize,
    run_multijob_sim,
)


# ============================================================== workloads ==
@dataclass(frozen=True)
class WorkloadTrace:
    """A SLURM-like workload: one pool, many jobs, a step horizon.

    ``step_s`` converts application steps to seconds (queue waits and
    the idle-capacity penalty are charged in seconds, so they compose
    with the engine's charged reconfiguration walls).  The trace is
    pure data — scheduling decisions live in :class:`SchedulerKnobs`.
    """

    name: str
    pool_nodes: int
    malleable: Tuple[JobSpec, ...]
    arrivals: Tuple[RigidArrival, ...]
    horizon: int
    step_s: float = 1.0
    start_step: int = 2

    def __post_init__(self) -> None:
        if not self.malleable:
            raise ValueError(f"workload {self.name!r} needs a malleable job")
        floors = sum(j.min_nodes for j in self.malleable)
        if floors > self.pool_nodes:
            raise ValueError(
                f"workload {self.name!r}: malleable floors ({floors}) "
                f"exceed the pool ({self.pool_nodes})")

    def horizon_s(self) -> float:
        return self.horizon * self.step_s

    def cluster(self) -> ClusterState:
        """The RMS ledger view of this workload's pool."""
        return ClusterState(total_nodes=self.pool_nodes,
                            jobs=self.malleable)


def generate_workload(
    name: str,
    *,
    pool_nodes: int = 32,
    n_malleable: int = 6,
    n_rigid: int = 24,
    horizon: int = 96,
    seed: int = 0,
    step_s: float = 1.0,
    burstiness: float = 0.0,
) -> WorkloadTrace:
    """Seeded SLURM-like workload generator (pure function of ``seed``).

    Malleable jobs draw floors/ceilings and priorities from the seeded
    stream; rigid arrivals draw size, duration, priority, and arrival
    step — uniformly over the horizon, or clumped into bursts as
    ``burstiness`` rises toward 1 (flash-crowd pressure).  Identical
    seeds yield identical workloads, which is what lets the registered
    workload scenarios and the bench rows be pinned in CI.
    """
    if not 0.0 <= burstiness <= 1.0:
        raise ValueError("burstiness must be in [0, 1]")
    rng = random.Random(seed)
    jobs: List[JobSpec] = []
    budget = pool_nodes
    for i in range(n_malleable):
        lo = rng.randint(1, 2)
        hi = min(pool_nodes, lo + rng.randint(2, max(3, pool_nodes // 3)))
        budget -= lo
        if budget < (n_malleable - i - 1):
            lo, hi = 1, max(2, hi // 2)  # keep floors feasible on the pool
        jobs.append(JobSpec(
            name=f"mall-{i}", min_nodes=lo, max_nodes=hi,
            priority=rng.randint(0, 40), malleable=True,
        ))
    window = max(1, horizon - 8)
    n_bursts = max(1, n_rigid // 6)
    burst_steps = sorted(rng.randint(2, window) for _ in range(n_bursts))
    arrivals: List[RigidArrival] = []
    for _ in range(n_rigid):
        if rng.random() < burstiness:
            step = min(window, rng.choice(burst_steps) + rng.randint(0, 2))
        else:
            step = rng.randint(2, window)
        arrivals.append(RigidArrival(
            step=step,
            nodes=rng.randint(1, max(2, pool_nodes // 5)),
            duration=rng.randint(3, max(4, horizon // 12)),
            priority=rng.randint(0, 100),
        ))
    arrivals.sort(key=lambda a: (a.step, -a.priority, a.nodes))
    return WorkloadTrace(
        name=name, pool_nodes=pool_nodes, malleable=tuple(jobs),
        arrivals=tuple(arrivals), horizon=horizon, step_s=step_s,
    )


# ================================================================== knobs ==
@dataclass(frozen=True)
class SchedulerKnobs:
    """The policy knobs the closed loop searches over.

    * ``backfill_threshold`` — grow hysteresis: an opportunistic grow is
      only emitted when it gains at least this many nodes (higher ->
      fewer, larger reconfigurations: less makespan, more idle);
    * ``preempt_priority`` — arrivals at or above this priority may
      force-shrink malleable jobs to start immediately (lower -> less
      queueing, more forced shrinks);
    * ``placement_quantum`` — grants move in multiples of this many
      nodes (the placement-weight coarsening: whole-chassis grants cut
      churn at some utilization cost).
    """

    backfill_threshold: int = 1
    preempt_priority: int = 80
    placement_quantum: int = 1

    def __post_init__(self) -> None:
        if self.backfill_threshold < 1 or self.placement_quantum < 1:
            raise ValueError("thresholds and quanta must be >= 1")


#: The deterministic grid :func:`optimize_schedule` always covers.
KNOB_GRID: Tuple[SchedulerKnobs, ...] = tuple(
    SchedulerKnobs(backfill_threshold=t, preempt_priority=p,
                   placement_quantum=q)
    for t in (1, 2, 4)
    for p in (50, 80, 1000)     # 1000: preemption effectively off
    for q in (1, 2, 4)
)


# ============================================================ the schedule ==
@dataclass(frozen=True)
class ScheduleObjective:
    """Weighted scheduling objective, every term in seconds (lower wins).

    ``makespan_s`` is the summed charged reconfiguration wall across all
    malleable jobs (QUEUE spans included), ``mean_queue_s`` the mean
    rigid-arrival wait, and the idle term prices unallocated capacity
    over the horizon.

    When :func:`evaluate_schedule` is given a ``throughput=`` model, the
    makespan term it scores is the modeled **time-to-result** instead —
    reconfiguration walls *plus* modeled compute for every horizon step
    under the allocation in force — so ``w_makespan`` starts pricing
    what an allocation earns, not just what resizing costs.  With the
    model disabled (the default) the scored number is the same summed
    ``est_wall_s`` as before, bit for bit.
    """

    w_makespan: float = 1.0
    w_queue: float = 1.0
    w_idle: float = 0.25

    def score(self, *, makespan_s: float, mean_queue_s: float,
              utilization: float, horizon_s: float) -> float:
        return (self.w_makespan * makespan_s
                + self.w_queue * mean_queue_s
                + self.w_idle * (1.0 - utilization) * horizon_s)


@dataclass(frozen=True)
class ScheduleOutcome:
    """One evaluated candidate: knobs -> charged schedule -> score."""

    workload: str
    knobs: Optional[SchedulerKnobs]     # None for the rigid baseline
    strategy: str
    score: float
    makespan_s: float                   # summed reconfiguration est_wall
    downtime_s: float                   # summed reconfiguration downtime
    expand_downtime_s: float            # the expansions' share of it
    mean_queue_s: float                 # mean rigid-arrival wait
    utilization: float                  # mean allocated fraction of the pool
    reconfigs: int                      # charged records across all jobs
    time_to_result_s: float = 0.0       # modeled; == makespan_s, no model
    scenarios: Dict[str, Scenario] = field(default_factory=dict)
    multijob: Optional[MultiJobOutcome] = None


def _walk_schedule(
    trace: WorkloadTrace, knobs: Optional[SchedulerKnobs]
) -> tuple[Dict[str, List[ScenarioEvent]], List[int], float, Dict[str, int]]:
    """The closed scheduling loop: one deterministic step walk.

    Returns ``(events per malleable job, rigid wait steps, utilization,
    initial allocations)``.  ``knobs=None`` runs the rigid-cluster
    control: malleable jobs are pinned at their peak request
    (``max_nodes``, greedily clamped to the pool) and never resize, and
    arrivals only start when capacity is free — no backfill, no
    preemption.
    """
    jobs = trace.malleable
    allocs: Dict[str, int] = {}
    if knobs is None:
        remaining = trace.pool_nodes
        for j in jobs:
            grant = max(j.min_nodes, min(
                j.max_nodes, remaining - sum(
                    k.min_nodes for k in jobs if k.name not in allocs
                    and k.name != j.name)))
            allocs[j.name] = grant
            remaining -= grant
    else:
        allocs = {j.name: j.start_nodes() for j in jobs}
    events: Dict[str, List[ScenarioEvent]] = {j.name: [] for j in jobs}
    by_prio = sorted(jobs, key=lambda j: (-j.priority, j.name))
    reclaim_order = sorted(jobs, key=lambda j: (j.priority, j.name))

    running: List[List[int]] = []            # [end_step, nodes]
    queue: List[RigidArrival] = []
    waits: List[int] = []
    used_steps = 0.0

    def free() -> int:
        return (trace.pool_nodes - sum(r[1] for r in running)
                - sum(allocs.values()))

    def reclaim(step: int, need: int, quantum: int) -> int:
        """Force-shrink malleables toward their floors; returns freed."""
        freed = 0
        for j in reclaim_order:
            if freed >= need:
                break
            surplus = allocs[j.name] - j.min_nodes
            take = min(surplus, need - freed)
            take -= take % quantum if take < surplus else 0
            if take <= 0:
                continue
            events[j.name].append(
                _resize(step, allocs[j.name], allocs[j.name] - take))
            allocs[j.name] -= take
            freed += take
        return freed

    for step in range(trace.start_step, trace.horizon):
        running = [r for r in running if r[0] > step]
        queue.extend(a for a in trace.arrivals if a.step == step)
        still_waiting: List[RigidArrival] = []
        for a in queue:                      # FIFO admission
            if a.nodes <= free():
                running.append([step + a.duration, a.nodes])
                waits.append(step - a.step)
                continue
            if knobs is not None and a.priority >= knobs.preempt_priority:
                deficit = a.nodes - free()
                reclaim(step, deficit, 1)
                if a.nodes <= free():
                    running.append([step + a.duration, a.nodes])
                    waits.append(step - a.step)
                    continue
            still_waiting.append(a)
        queue = still_waiting
        if knobs is not None:
            if queue:
                # Queue pressure: shed toward floors so the FIFO head
                # fits as soon as rigid capacity drains.
                reclaim(step, queue[0].nodes - free(),
                        knobs.placement_quantum)
            else:
                # Backfill: idle nodes flow to malleable jobs, highest
                # priority first, in placement-quantum multiples, only
                # past the hysteresis threshold.
                for j in by_prio:
                    idle = free()
                    if idle <= 0:
                        break
                    gain = min(j.max_nodes - allocs[j.name], idle)
                    gain -= gain % knobs.placement_quantum
                    if gain >= knobs.backfill_threshold:
                        events[j.name].append(
                            _resize(step, allocs[j.name],
                                    allocs[j.name] + gain))
                        allocs[j.name] += gain
        used_steps += sum(r[1] for r in running) + sum(allocs.values())
    waits.extend(trace.horizon - a.step for a in queue)  # never admitted
    span = max(1, trace.horizon - trace.start_step)
    utilization = used_steps / (trace.pool_nodes * span)
    initial = ({j.name: j.start_nodes() for j in jobs} if knobs is not None
               else allocs)
    return events, waits, utilization, initial


def _job_scenarios(trace: WorkloadTrace,
                   events: Dict[str, List[ScenarioEvent]],
                   initial: Dict[str, int],
                   tag: str) -> List[Tuple[str, Scenario]]:
    out = []
    for j in trace.malleable:
        out.append((j.name, Scenario(
            name=f"{trace.name}:{tag}:{j.name}",
            description=(f"malleable job {j.name!r} of workload "
                         f"{trace.name!r} ({tag} schedule)"),
            initial_nodes=initial[j.name],
            events=tuple(events[j.name]),
            steps=trace.horizon + 2,
        )))
    return out


def evaluate_schedule(
    trace: WorkloadTrace,
    knobs: Optional[SchedulerKnobs],
    *,
    strategy=None,
    cost_model=None,
    objective: ScheduleObjective = ScheduleObjective(),
    contention: float = 1.25,
    keep_scenarios: bool = False,
    throughput: Optional[ThroughputModel] = None,
) -> ScheduleOutcome:
    """Run the closed loop for one knob setting and score it.

    The walk's per-job traces are arbitrated on the shared pool
    (:func:`~.policies.run_multijob_sim` — cross-job QUEUE spans and
    contention degradation included) and charged through the vectorized
    fast path; ``strategy=`` / ``cost_model=`` are the normalized
    executor overrides.  ``knobs=None`` scores the rigid-cluster
    control (see :func:`rigid_baseline`).  ``throughput=`` switches the
    objective's makespan term to modeled time-to-result (each job's
    reconfiguration walls plus per-step modeled compute over the whole
    horizon — see :func:`~.throughput.time_to_result`); ``None`` keeps
    the old ``est_wall_s`` sum bit for bit.
    """
    from repro.core import strategy_key

    events, waits, utilization, initial = _walk_schedule(trace, knobs)
    tag = "rigid" if knobs is None else "dyn"
    jobs = _job_scenarios(trace, events, initial, tag)
    records, outcome = run_multijob_sim(
        jobs, trace.pool_nodes, contention=contention,
        strategy=strategy, cost_model=cost_model, throughput=throughput)
    makespan = sum(r.est_wall_s for recs in records.values() for r in recs)
    downtime = sum(r.downtime_s for recs in records.values() for r in recs)
    expand_down = sum(r.downtime_s for recs in records.values()
                      for r in recs if r.kind == "expand")
    reconfigs = sum(len(recs) for recs in records.values())
    mean_queue = (sum(waits) / len(waits) if waits else 0.0) * trace.step_s
    if throughput is None:
        ttr = makespan
    else:
        ttr = sum(
            time_to_result(records[name], outcome.scenarios[name], throughput)
            for name in records)
    score = objective.score(
        makespan_s=ttr, mean_queue_s=mean_queue,
        utilization=utilization, horizon_s=trace.horizon_s())
    strat = (strategy_key(strategy) if strategy is not None
             else jobs[0][1].default_engine().strategy)
    return ScheduleOutcome(
        workload=trace.name, knobs=knobs,
        strategy=strategy_key(strat),
        score=score, makespan_s=makespan, downtime_s=downtime,
        expand_downtime_s=expand_down, mean_queue_s=mean_queue,
        utilization=utilization, reconfigs=reconfigs,
        time_to_result_s=ttr,
        scenarios=(dict(outcome.scenarios) if keep_scenarios else {}),
        multijob=(outcome if keep_scenarios else None),
    )


def rigid_baseline(
    trace: WorkloadTrace,
    *,
    strategy=None,
    cost_model=None,
    objective: ScheduleObjective = ScheduleObjective(),
    throughput: Optional[ThroughputModel] = None,
) -> ScheduleOutcome:
    """Score the rigid-cluster control for a workload.

    Malleable jobs must request their peak (``max_nodes``) up front —
    a rigid cluster cannot grow a running job — and hold it for the
    whole horizon; rigid arrivals wait for free capacity with no
    backfill or preemption.  Reconfiguration cost is zero by
    construction; the queue and idle terms are what the closed loop is
    optimized against.  With ``throughput=``, the peak-pinned
    allocations still accrue modeled compute — the rigid control is
    fast per step but starves the queue.
    """
    return evaluate_schedule(trace, None, strategy=strategy,
                             cost_model=cost_model, objective=objective,
                             throughput=throughput)


# ================================================================= search ==
@dataclass(frozen=True)
class OptimizerResult:
    """The search's verdict for one workload x strategy."""

    workload: str
    strategy: str
    best: ScheduleOutcome
    baseline: ScheduleOutcome
    evaluated: int
    scores: Tuple[float, ...]          # every candidate, evaluation order

    @property
    def beats_baseline(self) -> bool:
        return self.best.score < self.baseline.score


def optimize_schedule(
    trace: WorkloadTrace,
    *,
    strategy=None,
    cost_model=None,
    objective: ScheduleObjective = ScheduleObjective(),
    grid: Sequence[SchedulerKnobs] = KNOB_GRID,
    n_random: int = 8,
    seed: int = 0,
    throughput: Optional[ThroughputModel] = None,
) -> OptimizerResult:
    """Grid + seeded random restarts over the knob space (deterministic).

    Every candidate is evaluated through :func:`evaluate_schedule`
    (arbitrated N-job traces, vectorized charging); the first-seen best
    score wins, so identical seeds choose identical knobs and scores.
    ``throughput=`` makes every candidate (and the rigid control) score
    modeled time-to-result instead of reconfiguration makespan — the
    search then optimizes the number the paper's malleability case
    rests on.
    """
    rng = random.Random(seed)
    candidates = list(grid)
    for _ in range(n_random):
        candidates.append(SchedulerKnobs(
            backfill_threshold=rng.randint(1, 6),
            preempt_priority=rng.choice((30, 50, 65, 80, 95, 1000)),
            placement_quantum=rng.choice((1, 2, 3, 4)),
        ))
    best: Optional[ScheduleOutcome] = None
    scores: List[float] = []
    for knobs in candidates:
        out = evaluate_schedule(
            trace, knobs, strategy=strategy, cost_model=cost_model,
            objective=objective, throughput=throughput)
        scores.append(out.score)
        if best is None or out.score < best.score:
            best = out
    assert best is not None
    baseline = rigid_baseline(trace, strategy=strategy,
                              cost_model=cost_model, objective=objective,
                              throughput=throughput)
    return OptimizerResult(
        workload=trace.name, strategy=best.strategy, best=best,
        baseline=baseline, evaluated=len(candidates),
        scores=tuple(scores),
    )


# ================================================= registered workloads ==
#: The generated workloads the bench gate and check_matrix replay.
WORKLOAD_TRACES: Dict[str, WorkloadTrace] = {
    # Steady mixed pressure: 8 malleable jobs breathing around 64 rigid
    # arrivals spread over the horizon (~100 resize decisions under the
    # default knobs — the SLURM-scale trace).
    "slurm-mix": generate_workload(
        "slurm-mix", pool_nodes=32, n_malleable=8, n_rigid=64,
        horizon=160, seed=11),
    # Flash-crowd pressure: arrivals clump into bursts, so admission
    # leans on preemptive reclamation.
    "slurm-burst": generate_workload(
        "slurm-burst", pool_nodes=16, n_malleable=5, n_rigid=40,
        horizon=96, seed=23, burstiness=0.8),
}

WORKLOAD_SCENARIO_NAMES = tuple(WORKLOAD_TRACES)


def _register_workload_scenarios() -> None:
    """Register each workload's busiest arbitrated job trace.

    The default-knob schedule is walked once at import (same pattern as
    the policy traces); the malleable job with the most resize events
    becomes the registered scenario, so check_matrix and the nightly
    sim == live sweep replay SLURM-scale traces under every strategy.
    """
    for name, trace in WORKLOAD_TRACES.items():
        out = evaluate_schedule(trace, SchedulerKnobs(),
                                keep_scenarios=True)
        busiest = max(out.scenarios.values(), key=lambda s: len(s.events))
        register_scenario(replace(
            busiest, name=name,
            description=(f"busiest malleable job of the {name!r} "
                         f"workload ({len(trace.malleable)} malleable "
                         f"jobs, {len(trace.arrivals)} rigid arrivals "
                         f"on {trace.pool_nodes} nodes), arbitrated"),
        ))


_register_workload_scenarios()


def registered_workload_scenarios() -> tuple[Scenario, ...]:
    """The workload-derived traces in the scenario registry."""
    from .scenarios import get_scenario

    return tuple(get_scenario(n) for n in WORKLOAD_SCENARIO_NAMES)
