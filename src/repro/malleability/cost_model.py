"""Latency model for MPI process-management primitives.

The constants are calibrated so the simulated §5 experiments land inside
the paper's reported envelopes (MN5 112-core nodes over InfiniBand,
NASP 20/52-core nodes over Ethernet):

  * parallel Merge expansion overhead  <= 1.13x (MN5) / 1.25x (NASP)
  * parallel Baseline expansion        up to ~1.73x (MN5)
  * TS shrink speedup                  >= 1387x (MN5) / >= 20x (NASP)

The *structure* of each formula is what matters for the reproduction —
`MPI_Comm_spawn` setup dominated by a per-call constant, per-node tree
launch, contention between concurrent calls at the launcher daemon,
log-depth connect phase — the constants just place us in the measured
regime.  All times in seconds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from functools import cached_property
from typing import Mapping


@dataclass(frozen=True)
class CostModel:
    # -- MPI_Comm_spawn ------------------------------------------------------
    alpha_spawn: float = 0.20       # per spawn-call setup (PMIx exchange)
    beta_proc_local: float = 8.0e-4  # per process launched on one node
    gamma_tree: float = 5.0e-3      # per tree-hop of the daemon broadcast
    delta_contend: float = 8.0e-4   # serialization between concurrent calls
    oversub_penalty: float = 1.6    # slowdown while procs > cores on a node
    # Topology-priced spawn: optional per-call surcharges when the
    # launcher tree crosses a rack (gamma_rack) or additionally a pod
    # (gamma_pod, on top of the rack hop) between the spawning rank's
    # node and the spawned group's node.  Both default to None = the
    # historical flat-latency spawn charge, bit for bit; engines only
    # take the priced path when at least one is set.
    gamma_rack: float | None = None
    gamma_pod: float | None = None

    # -- ports / name service --------------------------------------------------
    t_port: float = 2.0e-3          # MPI_Open_port + MPI_Publish_name
    t_lookup: float = 1.0e-3        # MPI_Lookup_name

    # -- point-to-point / collectives -------------------------------------------
    t_token: float = 5.0e-6         # one sync token (send/recv)
    t_barrier_hop: float = 1.0e-5   # MPI_Barrier per log2(p) hop

    # -- connect / merge / split --------------------------------------------------
    alpha_connect: float = 2.0e-3   # MPI_Comm_accept/connect handshake
    beta_connect: float = 1.0e-6    # MPI_Intercomm_merge per rank
    alpha_split: float = 2.0e-3     # MPI_Comm_split setup
    beta_split: float = 5.0e-7      # per rank

    # -- termination paths ---------------------------------------------------------
    t_term_base: float = 2.0e-4     # TS: terminate token + world exit
    t_term_per_proc: float = 1.0e-7
    t_teardown_per_proc: float = 1.0e-3  # SS: old-world MPI_Finalize + RMS dealloc

    # -- data redistribution --------------------------------------------------------
    redist_bw: float = 10.0e9       # aggregate bytes/s between old and new ranks
    redist_alpha: float = 5.0e-3    # per-event setup (plan exchange, buffer pin)
    # Per-distance-class bandwidths generalizing the PR-4 local/cross
    # split (see repro.core.topology.DISTANCE_CLASSES).  Bytes that stay
    # on a surviving device (``bytes_stayed``) ride the ``intra_node``
    # link (``redist_bw_local``); bytes that cross devices split between
    # ``intra_rack`` and ``cross_rack`` by the topology distance between
    # their source and destination nodes.  The class-specific bandwidths
    # fall back ``intra_rack``/``cross_rack`` -> ``redist_bw_cross`` ->
    # aggregate ``redist_bw`` (and ``cross_pod`` -> ``cross_rack``), so
    # the 2- and 3-class defaults (and the fully unset model) reproduce
    # the pre-topology numbers bit for bit.
    redist_bw_local: float | None = None
    redist_bw_cross: float | None = None
    redist_bw_intra_rack: float | None = None
    redist_bw_cross_rack: float | None = None
    redist_bw_cross_pod: float | None = None

    # -- checkpoint / restore ---------------------------------------------------
    # The full-stop alternative the malleable paths beat: a CHECKPOINT
    # stage writes the job's snapshot to the store, a RESTORE stage reads
    # it back.  Writes stream to a shared store at ``ckpt_bw`` (falls
    # back to the aggregate ``redist_bw``) after a per-snapshot setup
    # ``ckpt_alpha`` (falls back to ``redist_alpha``); restores are
    # priced through :meth:`redistribution` — per distance class, like
    # any stage-3 transfer.  ``ckpt_overlap`` is the async-checkpoint
    # fraction: snapshots are host copies written behind compute, so the
    # default 1.0 hides the whole write when the job runs ASYNC (restores
    # never hide — the app is down until its state is back).
    ckpt_bw: float | None = None
    ckpt_alpha: float | None = None
    ckpt_overlap: float = 1.0

    # -- partial overlap (stage x compute) -------------------------------------------
    # Fraction of each stage that can proceed under application compute when
    # the job runs ASYNC.  The defaults reproduce MaM's binary model (the
    # whole spawn phase hides, nothing else does); DMR-style partial overlap
    # is expressed by lowering spawn_overlap / raising the others.
    spawn_overlap: float = 1.0
    sync_overlap: float = 0.0
    connect_overlap: float = 0.0
    redist_overlap: float = 0.0
    # Contention factor for overlapped work: the hidden portion shares the
    # network/daemons with compute, so hiding a fraction f of an event still
    # costs f*(overlap_contention - 1) of its duration in lost app progress.
    # 1.0 = perfect hiding (the binary model); 2.0 = overlap buys nothing.
    overlap_contention: float = 1.0

    # ---------------------------------------------------------------- primitives --
    def spawn_call(self, procs: int, nodes: int) -> float:
        """One MPI_Comm_spawn launching ``procs`` over ``nodes`` nodes.

        The RMS launcher fans out over nodes in a tree and starts each
        node's processes locally, so per-node process count (not the
        total) is the linear term.
        """
        if procs <= 0:
            return 0.0
        per_node = math.ceil(procs / max(nodes, 1))
        return (
            self.alpha_spawn
            + self.beta_proc_local * per_node
            + self.gamma_tree * math.ceil(math.log2(nodes + 1))
        )

    def concurrent_round(self, calls: list[tuple[int, int]], oversubscribed: bool = False) -> float:
        """Spawn calls issued simultaneously by different parents.

        Calls proceed in parallel; the shared launcher daemon serializes a
        small per-call slice (delta_contend).
        """
        if not calls:
            return 0.0
        slowest = max(self.spawn_call(p, k) for p, k in calls)
        if oversubscribed:
            slowest *= self.oversub_penalty
        return slowest + self.delta_contend * (len(calls) - 1)

    @property
    def spawn_topology_priced(self) -> bool:
        """True when spawn calls carry distance-class surcharges."""
        return self.gamma_rack is not None or self.gamma_pod is not None

    def spawn_distance_penalty(self, distance_class: str) -> float:
        """Launcher-tree surcharge for one spawn call by distance class.

        ``intra_node`` / ``intra_rack`` spawns stay at the flat charge;
        a ``cross_rack`` spawn pays ``gamma_rack``; a ``cross_pod``
        spawn pays ``gamma_rack + gamma_pod`` (the pod hop rides on top
        of the rack hop).  Unset gammas contribute 0.0.
        """
        if distance_class in ("intra_node", "intra_rack"):
            return 0.0
        rack = self.gamma_rack or 0.0
        if distance_class == "cross_rack":
            return rack
        if distance_class == "cross_pod":
            return rack + (self.gamma_pod or 0.0)
        raise ValueError(f"unknown distance class {distance_class!r}")

    def concurrent_round_priced(
        self, calls: list[tuple[int, int, float]],
        oversubscribed: bool = False,
    ) -> float:
        """`concurrent_round` with a per-call distance surcharge.

        Each call is ``(procs, nodes, penalty_s)``.  With every penalty
        at 0.0 this reproduces :meth:`concurrent_round` exactly
        (``x + 0.0 == x`` for the non-negative charges involved).
        """
        if not calls:
            return 0.0
        slowest = max(self.spawn_call(p, k) + pen for p, k, pen in calls)
        if oversubscribed:
            slowest *= self.oversub_penalty
        return slowest + self.delta_contend * (len(calls) - 1)

    def barrier(self, procs: int) -> float:
        return self.t_barrier_hop * max(1, math.ceil(math.log2(max(procs, 2))))

    def connect_merge(self, merged_ranks: int) -> float:
        return self.alpha_connect + self.beta_connect * merged_ranks + self.t_lookup

    def comm_split(self, ranks: int) -> float:
        return self.alpha_split + self.beta_split * ranks

    def ts_terminate(self, worlds: list[int]) -> float:
        """TS: one release token per doomed world, worlds exit in parallel."""
        if not worlds:
            return 0.0
        return self.t_token + self.t_term_base + self.t_term_per_proc * max(worlds)

    def ss_respawn(self, nt: int, nodes: int, ns: int) -> float:
        """SS: spawn the smaller world, tear the old one down."""
        return (
            self.spawn_call(nt, nodes)
            + self.t_teardown_per_proc * ns
            + self.comm_split(nt)
        )

    # Bandwidth resolution is cached per instance: timeline charging
    # asks for the same resolved links on every event, and the fallback
    # chains below would otherwise be re-walked per event.  The model is
    # frozen, so a cached value can never go stale (``replace()`` makes
    # a fresh instance with an empty cache); ``functools.cached_property``
    # writes straight into ``__dict__``, bypassing the frozen guard.
    @cached_property
    def bw_local(self) -> float:
        """Resolved intra_node bandwidth (aggregate unless split)."""
        return self.redist_bw if self.redist_bw_local is None else self.redist_bw_local

    @cached_property
    def bw_cross(self) -> float:
        """Resolved cross-group bandwidth (aggregate unless split)."""
        return self.redist_bw if self.redist_bw_cross is None else self.redist_bw_cross

    @cached_property
    def bw_intra_rack(self) -> float:
        """Resolved intra_rack bandwidth (cross link unless split further)."""
        return (self.bw_cross if self.redist_bw_intra_rack is None
                else self.redist_bw_intra_rack)

    @cached_property
    def bw_cross_rack(self) -> float:
        """Resolved cross_rack bandwidth (cross link unless split further)."""
        return (self.bw_cross if self.redist_bw_cross_rack is None
                else self.redist_bw_cross_rack)

    @cached_property
    def bw_cross_pod(self) -> float:
        """Resolved cross_pod bandwidth (cross_rack link unless split)."""
        return (self.bw_cross_rack if self.redist_bw_cross_pod is None
                else self.redist_bw_cross_pod)

    @cached_property
    def class_bandwidths(self) -> dict[str, float]:
        """All four distance classes resolved once (cached)."""
        return {
            "intra_node": self.bw_local,
            "intra_rack": self.bw_intra_rack,
            "cross_rack": self.bw_cross_rack,
            "cross_pod": self.bw_cross_pod,
        }

    def bw_for_class(self, distance_class: str) -> float:
        """Bandwidth pricing one :data:`~repro.core.topology
        .DISTANCE_CLASSES` entry (unknown classes raise)."""
        try:
            return self.class_bandwidths[distance_class]
        except KeyError:
            raise ValueError(
                f"unknown distance class {distance_class!r}"
            ) from None

    def redistribution_by_class(self, bytes_by_class: Mapping[str, int]) -> float:
        """Stage-3 wall time: each byte priced on its distance class.

        Zero bytes across every class means no redistribution event at
        all (no setup charge).  The *moved* classes (``intra_rack`` /
        ``cross_rack`` / ``cross_pod``) collapse into fewer divisions
        whenever their bandwidths are equal — floating-point
        associativity would otherwise make a cost-neutral rack or pod
        split drift in the last ulp, and the 2-class (and 3-class)
        models must reproduce the pre-generalization charges bit for
        bit.  The collapse merges *integer* byte counts, so it is
        exact.
        """
        for cls in bytes_by_class:
            if cls not in ("intra_node", "intra_rack", "cross_rack",
                           "cross_pod"):
                self.bw_for_class(cls)      # unknown classes always raise
        if all(b <= 0 for b in bytes_by_class.values()):
            return 0.0
        stayed = max(0, bytes_by_class.get("intra_node", 0))
        intra = max(0, bytes_by_class.get("intra_rack", 0))
        cross = max(0, bytes_by_class.get("cross_rack", 0))
        pod = max(0, bytes_by_class.get("cross_pod", 0))
        total = self.redist_alpha + stayed / self.bw_local
        if self.bw_cross_pod == self.bw_cross_rack:
            cross += pod        # exact int merge: pod rides the rack link
            pod = 0
        if self.bw_intra_rack == self.bw_cross_rack:
            total += (intra + cross) / self.bw_cross_rack
        else:
            total += intra / self.bw_intra_rack + cross / self.bw_cross_rack
        if pod:
            total += pod / self.bw_cross_pod
        return total

    @cached_property
    def bw_ckpt(self) -> float:
        """Resolved checkpoint-store bandwidth (aggregate unless split)."""
        return self.redist_bw if self.ckpt_bw is None else self.ckpt_bw

    @cached_property
    def alpha_ckpt(self) -> float:
        """Resolved per-snapshot setup charge."""
        return self.redist_alpha if self.ckpt_alpha is None else self.ckpt_alpha

    def checkpoint(self, snapshot_bytes: int) -> float:
        """CHECKPOINT wall time: stream one snapshot to the store.

        Zero bytes means no event at all (no setup charge), mirroring
        :meth:`redistribution_by_class`.
        """
        if snapshot_bytes <= 0:
            return 0.0
        return self.alpha_ckpt + snapshot_bytes / self.bw_ckpt

    def restore(self, moved_bytes: int, stayed_bytes: int = 0,
                cross_rack_bytes: int = 0, cross_pod_bytes: int = 0) -> float:
        """RESTORE wall time: read a snapshot back from the store.

        Restores are stage-3 transfers in reverse — shards stream from
        the store onto the surviving (or respawned) ranks — so they are
        priced through :meth:`redistribution`, per distance class.  The
        default call charges everything on the cross link (the store is
        a shared filesystem outside the rack tree); callers that resolve
        store locality can pass the class split.
        """
        return self.redistribution(moved_bytes, stayed_bytes,
                                   cross_rack_bytes, cross_pod_bytes)

    def redistribution(self, moved_bytes: int, stayed_bytes: int = 0,
                       cross_rack_bytes: int = 0,
                       cross_pod_bytes: int = 0) -> float:
        """Stage-3 wall time: per-class pricing of one redistribution.

        ``moved_bytes`` cross device boundaries; the ``cross_rack_bytes``
        portion of them additionally crosses racks and is charged on the
        ``cross_rack`` link, the rest on ``intra_rack``; the
        ``cross_pod_bytes`` slice of the rack-crossing portion further
        leaves its pod and rides the ``cross_pod`` link.  ``stayed_bytes``
        are shards a surviving device already holds, re-validated over
        the (usually much faster) ``intra_node`` link.  With the default
        2-class model (no per-rack split) the moved classes all price at
        the cross-link bandwidth, so ``cross_rack_bytes`` /
        ``cross_pod_bytes`` splits are cost-neutral there and the charge
        is bit-for-bit the PR-4 local/cross number — and with
        ``stayed_bytes == 0``, the original aggregate charge
        ``redist_alpha + moved / redist_bw``.
        """
        xrack = min(max(0, cross_rack_bytes), max(0, moved_bytes))
        xpod = min(max(0, cross_pod_bytes), xrack)
        return self.redistribution_by_class({
            "intra_node": max(0, stayed_bytes),
            "intra_rack": max(0, moved_bytes) - xrack,
            "cross_rack": xrack - xpod,
            "cross_pod": xpod,
        })

    def with_link_bandwidths(
        self, *, local: float | None = None, cross: float | None = None
    ) -> "CostModel":
        """Copy of this model with split per-link redistribution bandwidths."""
        return replace(
            self,
            redist_bw_local=self.redist_bw_local if local is None else local,
            redist_bw_cross=self.redist_bw_cross if cross is None else cross,
        )

    def with_class_bandwidths(
        self,
        *,
        intra_node: float | None = None,
        intra_rack: float | None = None,
        cross_rack: float | None = None,
        cross_pod: float | None = None,
    ) -> "CostModel":
        """Copy of this model with per-distance-class stage-3 bandwidths."""
        return replace(
            self,
            redist_bw_local=(self.redist_bw_local if intra_node is None
                             else intra_node),
            redist_bw_intra_rack=(self.redist_bw_intra_rack if intra_rack is None
                                  else intra_rack),
            redist_bw_cross_rack=(self.redist_bw_cross_rack if cross_rack is None
                                  else cross_rack),
            redist_bw_cross_pod=(self.redist_bw_cross_pod if cross_pod is None
                                 else cross_pod),
        )

    def with_overlap(
        self,
        *,
        spawn: float | None = None,
        sync: float | None = None,
        connect: float | None = None,
        redistribution: float | None = None,
        contention: float | None = None,
    ) -> "CostModel":
        """Copy of this model with different partial-overlap parameters."""
        return replace(
            self,
            spawn_overlap=self.spawn_overlap if spawn is None else spawn,
            sync_overlap=self.sync_overlap if sync is None else sync,
            connect_overlap=self.connect_overlap if connect is None else connect,
            redist_overlap=(
                self.redist_overlap if redistribution is None else redistribution
            ),
            overlap_contention=(
                self.overlap_contention if contention is None else contention
            ),
        )

    def scaled(self, factor: float) -> "CostModel":
        """Uniformly slower interconnect/daemons (used for NASP)."""
        return replace(
            self,
            alpha_spawn=self.alpha_spawn * factor,
            beta_proc_local=self.beta_proc_local * factor,
            gamma_tree=self.gamma_tree * factor,
            delta_contend=self.delta_contend * factor,
            alpha_connect=self.alpha_connect * factor,
            beta_connect=self.beta_connect * factor,
            t_port=self.t_port * factor,
            t_lookup=self.t_lookup * factor,
            t_token=self.t_token * factor,
            t_barrier_hop=self.t_barrier_hop * factor,
            t_term_base=self.t_term_base * factor,
            redist_bw=self.redist_bw / factor,
            redist_bw_local=(
                None if self.redist_bw_local is None
                else self.redist_bw_local / factor
            ),
            redist_bw_cross=(
                None if self.redist_bw_cross is None
                else self.redist_bw_cross / factor
            ),
            redist_bw_intra_rack=(
                None if self.redist_bw_intra_rack is None
                else self.redist_bw_intra_rack / factor
            ),
            redist_bw_cross_rack=(
                None if self.redist_bw_cross_rack is None
                else self.redist_bw_cross_rack / factor
            ),
            redist_bw_cross_pod=(
                None if self.redist_bw_cross_pod is None
                else self.redist_bw_cross_pod / factor
            ),
            gamma_rack=(
                None if self.gamma_rack is None else self.gamma_rack * factor
            ),
            gamma_pod=(
                None if self.gamma_pod is None else self.gamma_pod * factor
            ),
            redist_alpha=self.redist_alpha * factor,
            ckpt_bw=(None if self.ckpt_bw is None else self.ckpt_bw / factor),
            ckpt_alpha=(
                None if self.ckpt_alpha is None else self.ckpt_alpha * factor
            ),
        )


# ---------------------------------------------------------------------------
# Analytic stage-3 bytes models (device-free).
#
# A *bytes model* maps one reconfiguration (ns source ranks -> nt target
# ranks) to the bytes that cross rank boundaries during stage 3.  The
# :class:`~repro.core.engine.ReconfigEngine` charges the result as a
# REDISTRIBUTION timeline event.  These two closed forms bracket the real
# placements; :class:`repro.elastic.reshard.PytreeBytesModel` computes the
# exact value for a live model's sharded pytree.
# ---------------------------------------------------------------------------
def replicated_bytes_model(param_bytes: int):
    """Bytes model for fully replicated state (pure data parallelism).

    Every target rank holds the full ``param_bytes`` replica, so a grow
    ships one copy to each new rank and a shrink moves nothing (survivor
    replicas already suffice).

    Args:
        param_bytes: total size of the replicated pytree in bytes.
    Returns:
        ``f(ns, nt) -> int`` usable as ``ReconfigEngine.bytes_model``.
    """

    def bytes_moved(ns: int, nt: int) -> int:
        if ns <= 0 or nt <= ns:
            return 0
        return param_bytes * (nt - ns)

    # Checkpoint snapshot size: one full replica, regardless of rank count.
    bytes_moved.total_bytes = lambda ranks: max(0, param_bytes)  # type: ignore[attr-defined]
    return bytes_moved


def fsdp_bytes_model(param_bytes: int):
    """Bytes model for fully sharded state (ZeRO-3/FSDP over all ranks).

    Every rank holds 1/ranks of the state; any resize redraws every shard
    boundary, so (conservatively) the whole pytree is in flight for both
    grows and shrinks.

    Args:
        param_bytes: total size of the sharded pytree in bytes.
    Returns:
        ``f(ns, nt) -> int`` usable as ``ReconfigEngine.bytes_model``.
    """

    def bytes_moved(ns: int, nt: int) -> int:
        if ns <= 0 or nt <= 0 or nt == ns:
            return 0
        return param_bytes

    # Checkpoint snapshot size: the shards cover the pytree exactly once.
    bytes_moved.total_bytes = lambda ranks: max(0, param_bytes)  # type: ignore[attr-defined]
    return bytes_moved


def replicated_link_model(param_bytes: int):
    """Link-aware replicated model: reports *both* transfer classes.

    Same placement assumptions as :func:`replicated_bytes_model`, but the
    returned callable yields a ``{"bytes_stayed", "bytes_moved"}`` dict
    (the :func:`repro.elastic.reshard.predicted_transfer_stats` shape):
    a grow ships one replica to each new rank (moved, cross link) while
    every survivor re-validates its own replica (stayed, local link);
    a shrink leaves the survivors' replicas in place (stayed only).

    Use this with split ``redist_bw_local`` / ``redist_bw_cross``
    bandwidths; with the default single-bandwidth model, prefer
    :func:`replicated_bytes_model`, which charges moved bytes only and
    reproduces the pre-split aggregate numbers bit-for-bit.

    Args:
        param_bytes: total size of the replicated pytree in bytes.
    Returns:
        ``f(ns, nt) -> dict`` usable as ``ReconfigEngine.bytes_model``.
    """

    def transfer(ns: int, nt: int) -> dict:
        if ns <= 0 or nt <= 0 or nt == ns:
            return {"bytes_stayed": 0, "bytes_moved": 0}
        if nt > ns:
            return {
                "bytes_stayed": param_bytes * ns,
                "bytes_moved": param_bytes * (nt - ns),
            }
        return {"bytes_stayed": param_bytes * nt, "bytes_moved": 0}

    # Checkpoint snapshot size: one full replica, regardless of rank count.
    transfer.total_bytes = lambda ranks: max(0, param_bytes)  # type: ignore[attr-defined]
    return transfer


# MareNostrum 5: 112-core nodes, MPICH 4.2 over InfiniBand (CH4:OFI).
MN5 = CostModel()

# NASP: 20/52-core nodes, MPICH 3.4 over 10 Gbit Ethernet (CH3:Nemesis) —
# slower launcher and transport, and a much slower termination path (CH3
# progress engine + Ethernet name service), which is why the paper's TS
# speedup bound drops from 1387x (MN5) to 20x.
NASP = replace(CostModel().scaled(4.0), t_term_base=3.0e-2)
