"""Malleability runtime: event-driven reconfiguration simulator.

Executes :class:`repro.core.SpawnPlan` / :class:`repro.core.ShrinkPlan`
objects against a calibrated MPI cost model to estimate reconfiguration
wall time, reproducing the paper's §5 experiments on this CPU-only host.
"""
from .cost_model import MN5, NASP, CostModel
from .simulator import (
    ExpansionReport,
    ShrinkReport,
    simulate_expansion,
    simulate_redistribution,
    simulate_shrink,
)

__all__ = [
    "MN5",
    "NASP",
    "CostModel",
    "ExpansionReport",
    "ShrinkReport",
    "simulate_expansion",
    "simulate_redistribution",
    "simulate_shrink",
]
