"""Malleability runtime: event-driven reconfiguration simulator.

Executes :class:`repro.core.SpawnPlan` / :class:`repro.core.ShrinkPlan`
objects against a calibrated MPI cost model to estimate reconfiguration
wall time, reproducing the paper's §5 experiments on this CPU-only host.
"""
from .cost_model import MN5, NASP, CostModel
from .scenarios import (
    RuntimeAdapter,
    Scenario,
    ScenarioEvent,
    ScenarioRecord,
    burst_arrival,
    dispatch_event,
    get_scenario,
    heterogeneous_pool,
    node_failures,
    register_scenario,
    registered_scenarios,
    run_scenario_live,
    run_scenario_sim,
    steady_cycle,
    straggler_churn,
)
from .simulator import (
    ExpansionReport,
    ShrinkReport,
    simulate_expansion,
    simulate_redistribution,
    simulate_shrink,
)

__all__ = [
    "MN5",
    "NASP",
    "CostModel",
    "ExpansionReport",
    "RuntimeAdapter",
    "Scenario",
    "ScenarioEvent",
    "ScenarioRecord",
    "ShrinkReport",
    "burst_arrival",
    "dispatch_event",
    "get_scenario",
    "heterogeneous_pool",
    "node_failures",
    "register_scenario",
    "registered_scenarios",
    "run_scenario_live",
    "run_scenario_sim",
    "simulate_expansion",
    "simulate_redistribution",
    "simulate_shrink",
    "steady_cycle",
    "straggler_churn",
]
