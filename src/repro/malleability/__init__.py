"""Malleability runtime: event-driven reconfiguration simulation.

Executes :class:`repro.core.SpawnPlan` / :class:`repro.core.ShrinkPlan`
objects against a calibrated MPI cost model to estimate reconfiguration
wall time, reproducing the paper's §5 experiments on this CPU-only host.

Three submodules:

* :mod:`.cost_model` — :class:`CostModel` latency/bandwidth constants
  (profiles :data:`MN5` / :data:`NASP`), the partial-overlap knobs
  (per-stage overlap fractions + contention factor), and the analytic
  stage-3 bytes models (:func:`replicated_bytes_model` /
  :func:`fsdp_bytes_model`);
* :mod:`.simulator` — report-shaped views (:class:`ExpansionReport` /
  :class:`ShrinkReport`) over the engine's charged timelines;
* :mod:`.scenarios` — declarative workload traces (:class:`Scenario`),
  their registry, and the sim/live executors that agree exactly on
  every timeline-derived number, bytes included;
* :mod:`.policies` — the RMS policy engine (backfill / preemption /
  churn + the multi-job arbiter) whose generated traces land in the
  same registry (re-exported by :mod:`repro.elastic.rms`);
* :mod:`.optimizer` — the closed scheduling loop: SLURM-scale
  :class:`WorkloadTrace` generation, the weighted
  :class:`ScheduleObjective`, and the seeded knob search
  (:func:`optimize_schedule`) against the rigid-cluster baseline;
* :mod:`.throughput` — the per-allocation step-time model
  (:class:`ThroughputModel`: roofline compute/memory/collective terms,
  width-weighted batch shares on uneven pools, calibrated contention)
  the executors accrue into ``time_to_result_s`` and the optimizer
  scores instead of reconfiguration makespan.

See ``docs/cost-model.md`` and ``docs/scenarios.md`` for guides.
"""
from .cost_model import (
    MN5,
    NASP,
    CostModel,
    fsdp_bytes_model,
    replicated_bytes_model,
    replicated_link_model,
)
from .optimizer import (
    KNOB_GRID,
    WORKLOAD_SCENARIO_NAMES,
    WORKLOAD_TRACES,
    OptimizerResult,
    ScheduleObjective,
    ScheduleOutcome,
    SchedulerKnobs,
    WorkloadTrace,
    evaluate_schedule,
    generate_workload,
    optimize_schedule,
    registered_workload_scenarios,
    rigid_baseline,
)
from .policies import (
    SERVE_SCENARIO_NAMES,
    SERVE_TRAFFIC,
    ArbitratedJob,
    BackfillPolicy,
    CheckpointIntervalPolicy,
    ChurnPolicy,
    JobSpec,
    MonteCarloSweep,
    MultiJobOutcome,
    PolicyTrace,
    PreemptionPolicy,
    PriorityArrival,
    RigidArrival,
    RmsPolicy,
    TrafficPolicy,
    arbitrate_jobs,
    backfill_pressure,
    charge_in_flight_queueing,
    churn_trace,
    monte_carlo_sweep,
    priority_preempt,
    registered_policy_scenarios,
    registered_serve_scenarios,
    run_multijob_sim,
    serve_diurnal,
    serve_flashcrowd,
    serve_slo,
    two_job_interference,
)
from .scenarios import (
    FAULT_SCENARIO_NAMES,
    RuntimeAdapter,
    Scenario,
    ScenarioEvent,
    ScenarioRecord,
    TransitionCache,
    burst_arrival,
    ckpt_cycle,
    dispatch_event,
    get_scenario,
    heterogeneous_pool,
    node_fail_wave,
    node_failures,
    param_bytes_for_arch,
    record_parity_key,
    register_scenario,
    registered_fault_scenarios,
    registered_scenarios,
    resolve_engine,
    restart_vs_shrink,
    run_scenario_live,
    run_scenario_sim,
    run_scenario_vectorized,
    scenario_pool,
    steady_cycle,
    straggler_churn,
    topology_nasp,
    topology_pods,
    topology_redist,
)
from .simulator import (
    ExpansionReport,
    ShrinkReport,
    simulate_expansion,
    simulate_redistribution,
    simulate_shrink,
)
from .throughput import (
    ThroughputModel,
    batch_shares,
    flops_per_token_for_arch,
    time_to_result,
)

__all__ = [
    "FAULT_SCENARIO_NAMES",
    "KNOB_GRID",
    "MN5",
    "NASP",
    "SERVE_SCENARIO_NAMES",
    "SERVE_TRAFFIC",
    "WORKLOAD_SCENARIO_NAMES",
    "WORKLOAD_TRACES",
    "ArbitratedJob",
    "BackfillPolicy",
    "CheckpointIntervalPolicy",
    "ChurnPolicy",
    "CostModel",
    "ExpansionReport",
    "JobSpec",
    "MonteCarloSweep",
    "MultiJobOutcome",
    "OptimizerResult",
    "PolicyTrace",
    "PreemptionPolicy",
    "PriorityArrival",
    "RigidArrival",
    "RmsPolicy",
    "RuntimeAdapter",
    "Scenario",
    "ScenarioEvent",
    "ScenarioRecord",
    "ScheduleObjective",
    "ScheduleOutcome",
    "SchedulerKnobs",
    "ShrinkReport",
    "ThroughputModel",
    "TrafficPolicy",
    "TransitionCache",
    "WorkloadTrace",
    "arbitrate_jobs",
    "backfill_pressure",
    "batch_shares",
    "burst_arrival",
    "charge_in_flight_queueing",
    "churn_trace",
    "ckpt_cycle",
    "dispatch_event",
    "evaluate_schedule",
    "flops_per_token_for_arch",
    "fsdp_bytes_model",
    "generate_workload",
    "get_scenario",
    "heterogeneous_pool",
    "monte_carlo_sweep",
    "node_fail_wave",
    "node_failures",
    "optimize_schedule",
    "param_bytes_for_arch",
    "priority_preempt",
    "record_parity_key",
    "register_scenario",
    "registered_fault_scenarios",
    "registered_policy_scenarios",
    "registered_scenarios",
    "registered_serve_scenarios",
    "registered_workload_scenarios",
    "replicated_bytes_model",
    "replicated_link_model",
    "resolve_engine",
    "restart_vs_shrink",
    "rigid_baseline",
    "run_multijob_sim",
    "run_scenario_live",
    "run_scenario_sim",
    "run_scenario_vectorized",
    "scenario_pool",
    "serve_diurnal",
    "serve_flashcrowd",
    "serve_slo",
    "simulate_expansion",
    "simulate_redistribution",
    "simulate_shrink",
    "steady_cycle",
    "straggler_churn",
    "time_to_result",
    "topology_nasp",
    "topology_pods",
    "topology_redist",
    "two_job_interference",
]
