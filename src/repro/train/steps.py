"""Sharded step builders.

``build_train_step`` returns a jit-able ``step(state, batch) -> (state,
metrics)`` plus the in/out shardings derived from the logical rules —
both for live execution and for the ``.lower().compile()`` dry-run.
``build_serve_step`` does the same for one decode step over a KV cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import batch_spec
from repro.models import Model
from repro.models.common import ModelConfig
from repro.models.transformer import init_cache_shapes
from repro.optim import AdamWState, adamw_init, adamw_update
from repro.parallel.sharding import (
    ShardingContext,
    param_sharding_abstract,
    resolve_spec,
    use_sharding,
)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def train_state_shardings(
    model: Model, ctx: ShardingContext
) -> tuple[TrainState, TrainState]:
    """(abstract_state, sharding_tree) for the model under ``ctx``."""
    shapes, specs = model.abstract_params()
    p_shard = param_sharding_abstract(shapes, specs, ctx)
    scalar = NamedSharding(ctx.mesh, P())
    opt_shapes = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=shapes, nu=shapes
    )
    opt_shard = AdamWState(step=scalar, mu=dict(p_shard), nu=dict(p_shard))
    abstract = TrainState(
        params=shapes, opt=opt_shapes, step=jax.ShapeDtypeStruct((), jnp.int32)
    )
    shardings = TrainState(params=p_shard, opt=opt_shard, step=scalar)
    return abstract, shardings


def batch_shardings(cfg: ModelConfig, ctx: ShardingContext, batch: int, seq: int) -> dict:
    names, spec_for = batch_spec(cfg, ctx)
    out = {}
    for name, ndim in names.items():
        if name == "positions":
            shape = (3, batch, seq)
        elif name == "embeds":
            shape = (batch, seq, cfg.d_model)
        else:
            shape = (batch, seq)
        axes = spec_for(name, ndim)
        out[name] = NamedSharding(ctx.mesh, resolve_spec(tuple(axes), shape, ctx, "act"))
    return out


def build_train_step(model: Model, ctx: ShardingContext, lr: float = 3e-4):
    """Returns (train_step_fn, state_shardings, abstract_state)."""
    cfg = model.cfg

    def train_step(state: TrainState, batch: dict):
        with use_sharding(ctx):
            loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
            params, opt = adamw_update(grads, state.opt, state.params, lr)
        metrics = {"loss": loss, "step": state.step + 1}
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    abstract, shardings = train_state_shardings(model, ctx)
    return train_step, shardings, abstract


def build_init_fn(model: Model, ctx: ShardingContext):
    """Sharded-init: params materialize directly on the mesh."""
    _, shardings = train_state_shardings(model, ctx)

    def init_fn(key) -> TrainState:
        params, _ = model.init(key)
        opt = adamw_init(params)
        return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))

    return jax.jit(init_fn, out_shardings=shardings), shardings


def cache_shardings(model: Model, ctx: ShardingContext, batch: int, max_len: int) -> dict:
    shapes = init_cache_shapes(model.cfg, batch, max_len)
    return {
        name: NamedSharding(ctx.mesh, resolve_spec(tuple(axes), shape, ctx, "act"))
        for name, (shape, _dt, axes, _f) in shapes.items()
    }


def abstract_cache(model: Model, batch: int, max_len: int) -> dict:
    shapes = init_cache_shapes(model.cfg, batch, max_len)
    return {
        name: jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
        for name, (shape, dt, _axes, _f) in shapes.items()
    }


def build_serve_step(model: Model, ctx: ShardingContext):
    """One-token decode step: (params, cache, tok_batch) -> (logits, cache)."""

    def serve_step(params: dict, cache: dict, tok: dict):
        with use_sharding(ctx):
            return model.decode_step(params, cache, tok)

    return serve_step


def serving_param_shapes(model: Model) -> tuple[dict, dict]:
    """Abstract params cast to the compute dtype (inference keeps no
    fp32 master copy)."""
    shapes, specs = model.abstract_params()
    dt = model.cfg.compute_dtype
    cast = {
        k: jax.ShapeDtypeStruct(s.shape, dt if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype)
        for k, s in shapes.items()
    }
    return cast, specs
