"""Step builders: sharded train_step / serve_step factories."""
from .steps import (
    build_serve_step,
    build_train_step,
    train_state_shardings,
)

__all__ = ["build_serve_step", "build_train_step", "train_state_shardings"]
