"""Continuous batching over an elastic decode pool.

The scheduler half of the serving plane: an **admission queue** feeding
per-worker **decode slots**, one token decoded per active request per
application step, KV pages tracked by a :class:`~repro.serving.kv_cache
.KVPageTable`.  What makes it the serving counterpart of the trainer's
drain-and-reshard is :meth:`ContinuousBatcher.resize` — the
**drain-and-remap** path with one hard invariant:

    a resize NEVER drops (or duplicates) an in-flight request.

Requests on evicted workers keep their KV pages — the page table
migrates them to the remaining workers, and those bytes are exactly
what the :class:`~repro.serving.kv_cache.KVBytesModel` charged the
engine as REDISTRIBUTION — and either stay active on the worker now
holding their pages (a free decode slot there: *migrated*) or go back
to the FRONT of the admission queue in request order (*requeued*),
resuming from their decoded position once a slot frees.  Nothing is
restarted, nothing is lost; ``tests/test_serving.py`` drives random
arrival/decode/resize interleavings through
:meth:`ContinuousBatcher.check_invariants` to pin it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .kv_cache import KVPageTable, ResizeResult


@dataclass(frozen=True)
class Request:
    """One decode request: prompt in, ``gen_tokens`` tokens out."""

    rid: int
    arrival_step: int
    prompt_tokens: int
    gen_tokens: int

    def __post_init__(self) -> None:
        if self.prompt_tokens < 1 or self.gen_tokens < 1:
            raise ValueError(
                f"request {self.rid}: prompt and generation must be "
                f"at least one token")

    def total_tokens(self) -> int:
        return self.prompt_tokens + self.gen_tokens


class ContinuousBatcher:
    """Admission queue + decode slots over the elastic worker pool.

    * :meth:`submit` enqueues (FIFO);
    * :meth:`admit` fills free slots in queue order — a request whose
      pages already sit on some worker (a requeued survivor of a
      resize) only re-admits where its pages are, so re-admission moves
      zero bytes; fresh requests take the worker with the most free
      slots (most free pages, then lowest id, on ties) and allocate
      their prompt's pages there (prefill);
    * :meth:`decode` advances every active request one token, growing
      its page list across page boundaries, completing and freeing at
      ``gen_tokens``;
    * :meth:`resize` applies a worker-set change via the page table's
      migration plan and remaps/requeues the affected requests.

    Admission is strict about the page budget; decode growth and
    migration may overcommit it (the soft-capacity contract documented
    on :class:`~repro.serving.kv_cache.KVPageTable`).
    """

    def __init__(self, table: KVPageTable, slots_per_worker: int) -> None:
        if slots_per_worker <= 0:
            raise ValueError("slots_per_worker must be positive")
        self.table = table
        self.slots_per_worker = slots_per_worker
        self.queue: Deque[int] = deque()
        self.requests: Dict[int, Request] = {}
        self.active: Dict[int, int] = {}          # rid -> worker
        self.progress: Dict[int, int] = {}        # rid -> tokens generated
        self.completed: Dict[int, int] = {}       # rid -> completion step
        self.tokens_decoded = 0
        self.requeued = 0                         # resize -> back to queue
        self.migrated = 0                         # resize -> stayed active
        self.dropped = 0                          # MUST stay 0, forever

    # ------------------------------------------------------------- queries --
    def workers(self) -> Tuple[int, ...]:
        return self.table.worker_ids()

    def slots_free(self, worker: int) -> int:
        used = sum(1 for w in self.active.values() if w == worker)
        return self.slots_per_worker - used

    def in_flight(self) -> Tuple[int, ...]:
        """Submitted but not completed, in request order."""
        return tuple(sorted(set(self.queue) | set(self.active)))

    def utilization(self) -> float:
        total = self.slots_per_worker * self.table.n_workers
        return len(self.active) / total if total else 0.0

    # ------------------------------------------------------------ pipeline --
    def submit(self, request: Request) -> None:
        if request.rid in self.requests:
            raise ValueError(f"request {request.rid} already submitted")
        self.requests[request.rid] = request
        self.queue.append(request.rid)

    def _admission_worker(self, rid: int) -> Optional[int]:
        pages_held = rid in self.table.requests()
        if pages_held:
            # Requeued mid-flight request: its KV pages already live
            # somewhere; re-admission must not move bytes, so it waits
            # for a slot exactly there.
            w = self.table.request_worker(rid)
            return w if self.slots_free(w) > 0 else None
        need = self.table.spec.pages_for(self.requests[rid].prompt_tokens)
        best = None
        best_key = None
        for w in self.workers():
            if self.slots_free(w) <= 0 or self.table.free_pages(w) < need:
                continue
            key = (self.slots_free(w), self.table.free_pages(w), -w)
            if best_key is None or key > best_key:
                best, best_key = w, key
        return best

    def admit(self, step: int) -> List[int]:
        """Fill free slots in FIFO order; returns the admitted rids.

        Head-of-line blocking is deliberate: if the oldest waiting
        request cannot be placed, nothing behind it jumps the queue
        (arrival-order fairness — the latency numbers mean something).
        """
        admitted: List[int] = []
        while self.queue:
            rid = self.queue[0]
            worker = self._admission_worker(rid)
            if worker is None:
                break
            self.queue.popleft()
            if rid not in self.table.requests():
                need = self.table.spec.pages_for(
                    self.requests[rid].prompt_tokens)
                self.table.allocate(rid, need, worker)
            self.active[rid] = worker
            self.progress.setdefault(rid, 0)
            admitted.append(rid)
        return admitted

    def decode(self, step: int) -> Tuple[int, List[int]]:
        """One decode step for every active request.

        Returns ``(tokens_decoded, completed_rids)``.  Page growth: a
        request's KV occupancy is ``prompt + generated``; crossing a
        page boundary appends a page on its worker.
        """
        done: List[int] = []
        n_decoded = len(self.active)
        for rid in sorted(self.active):
            req = self.requests[rid]
            before = req.prompt_tokens + self.progress[rid]
            self.progress[rid] += 1
            self.tokens_decoded += 1
            if (before + 1 > len(self.table.request_pages(rid))
                    * self.table.spec.page_tokens):
                self.table.append_page(rid)
            if self.progress[rid] >= req.gen_tokens:
                done.append(rid)
        for rid in done:
            self.table.free_request(rid)
            del self.active[rid]
            del self.progress[rid]
            self.completed[rid] = step
        return n_decoded, done

    # -------------------------------------------------------------- resize --
    def resize(self, workers_after: Sequence[int], step: int) -> ResizeResult:
        """Drain-and-remap onto a new worker set; never drops a request.

        The page table migrates in-flight KV (its plan is exactly what
        the engine's :class:`~repro.serving.kv_cache.KVBytesModel`
        priced); each moved ACTIVE request keeps decoding on the worker
        now holding its pages when a slot is free there, and otherwise
        rejoins the admission queue at the FRONT (request order
        preserved) with pages and progress intact.
        """
        before_active = dict(self.active)
        result = self.table.apply_resize(workers_after)
        back: List[int] = []
        for rid, _src, dst in result.moves:
            if rid not in before_active:
                continue                      # queued survivor: pages only
            if self.slots_free(dst) > 0:
                self.active[rid] = dst
                self.migrated += 1
            else:
                del self.active[rid]
                back.append(rid)
                self.requeued += 1
        for rid in sorted(back, reverse=True):
            self.queue.appendleft(rid)
        gone = [rid for rid, w in self.active.items()
                if w not in self.table.worker_ids()]
        if gone:                              # pragma: no cover - invariant
            raise RuntimeError(
                f"resize left active requests on evicted workers: {gone}")
        return result

    # ---------------------------------------------------------- invariants --
    def check_invariants(self) -> None:
        """Raise unless every slot/page/request invariant holds.

        The property-based suite calls this after every random
        operation: no request is ever lost or duplicated, slots never
        overcommit, completed requests hold no pages, and the page
        ledger balances (allocated == freed + resident).
        """
        queued = list(self.queue)
        if len(set(queued)) != len(queued):
            raise AssertionError(f"duplicate queue entries: {queued}")
        q, a, c = set(queued), set(self.active), set(self.completed)
        if q & a or q & c or a & c:
            raise AssertionError(
                f"request in two states: queue={q} active={a} done={c}")
        if q | a | c != set(self.requests):
            raise AssertionError("a submitted request vanished")
        if self.dropped:
            raise AssertionError(f"dropped={self.dropped} (must be 0)")
        for w in self.workers():
            if self.slots_free(w) < 0:
                raise AssertionError(f"worker {w} slots overcommitted")
        for rid, w in self.active.items():
            if self.table.request_worker(rid) != w:
                raise AssertionError(
                    f"active request {rid} decodes on {w} but its pages "
                    f"are on {self.table.request_worker(rid)}")
        paged = set(self.table.requests())
        if paged & c:
            raise AssertionError(f"completed requests hold pages: {paged & c}")
        if not a <= paged:
            raise AssertionError(f"active requests without pages: {a - paged}")
        ledger = self.table.pages_allocated - self.table.pages_freed
        if ledger != self.table.total_pages():
            raise AssertionError(
                f"page ledger off: allocated-freed={ledger} but "
                f"{self.table.total_pages()} resident")
