"""The elastic decode service: traffic in, priced reconfigurations out.

:func:`run_serve` replays a registered serve trace end to end:

* the **requests** come from the same rate trace the
  :class:`~repro.malleability.policies.TrafficPolicy` sized the pool
  from (``SERVE_TRAFFIC`` — single-sourced, so the autoscaler and the
  service always see the same load);
* the **resizes** are the trace's scenario events, dispatched through
  the exact machinery every other consumer uses
  (:func:`~repro.malleability.scenarios.dispatch_event` over either the
  device-free ``_SimCluster`` or the live
  :class:`~repro.elastic.ElasticRuntime`), with the engine's bytes
  model swapped for the live :class:`~repro.serving.kv_cache
  .KVBytesModel` — so each resize is priced from the **actual resident
  KV pages** at that moment;
* on every resize the loop asserts the three-way byte parity —
  engine-charged == predicted == measured page migration — and the
  prefix-range worker contract, then lets the
  :class:`~repro.serving.batching.ContinuousBatcher` drain-and-remap
  (zero dropped requests, by construction and by assertion);
* serving time advances ``step_time_s`` per step plus each resize's
  charged ``downtime_s``, so request latency feels reconfiguration
  stalls exactly as the timeline priced them.

Because every input is deterministic, a sim run and a live run of the
same trace produce **identical** :class:`ServeReport`\\ s — per-event
records, per-request latencies, throughput, downtime — which
:func:`serve_parity_key` pins (the serving analog of
:func:`~repro.malleability.scenarios.record_parity_key`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from repro.malleability.policies import SERVE_TRAFFIC
from repro.malleability.throughput import ThroughputModel
from repro.malleability.scenarios import (
    Scenario,
    ScenarioRecord,
    _dispatch,
    _SimCluster,
    get_scenario,
    record_parity_key,
    scenario_pool,
)

from .batching import ContinuousBatcher, Request
from .kv_cache import KVBytesModel, KVPageTable, PageSpec, page_bytes_for_arch

EXECUTORS = ("sim", "live")


@dataclass(frozen=True)
class ServeConfig:
    """Knobs of the decode service (defaults match the traffic policy).

    ``gen_spread`` staggers generation lengths (request ``rid`` decodes
    ``gen_tokens + rid % gen_spread`` tokens) so completions don't all
    land on the same step; ``page_bytes`` overrides the
    ``init_cache``-derived page size when nonzero (unit tests price
    round numbers, the real service prices the model's actual cache).

    ``throughput`` replaces the flat ``step_time_s`` with the modeled
    per-allocation decode step time
    (:class:`~repro.malleability.throughput.ThroughputModel`): each
    step is priced for the worker count actually serving it, so a
    scale-down cheap on migration bytes still pays its slower steps in
    every latency and throughput number.  ``None`` (the default) keeps
    the historical constant bit for bit.
    """

    arch: str = "xlstm_125m"        # model whose KV cache the pages slice
    page_tokens: int = 16
    page_bytes: int = 0             # 0 -> derive from arch via init_cache
    pages_per_worker: int = 24
    slots_per_worker: int = 5
    prompt_tokens: int = 24
    gen_tokens: int = 8
    gen_spread: int = 3
    step_time_s: float = 0.05
    max_drain_steps: int = 2000
    throughput: Optional[ThroughputModel] = None

    def resolved_step_time_s(self, workers: int = 0) -> float:
        """Seconds per decode step on ``workers`` nodes: modeled when a
        ``throughput`` model and a real worker count are given, the flat
        ``step_time_s`` otherwise.
        """
        if self.throughput is None or workers <= 0:
            return self.step_time_s
        return self.throughput.step_time(self.throughput.widths_for(workers))

    def page_spec(self) -> PageSpec:
        pb = self.page_bytes or page_bytes_for_arch(self.arch,
                                                    self.page_tokens)
        return PageSpec(page_tokens=self.page_tokens, page_bytes=pb)

    def request_for(self, rid: int, step: int) -> Request:
        gen = self.gen_tokens + (rid % self.gen_spread if self.gen_spread > 1
                                 else 0)
        return Request(rid=rid, arrival_step=step,
                       prompt_tokens=self.prompt_tokens, gen_tokens=gen)


def serve_config(name: str) -> ServeConfig:
    """The config a registered serve trace runs with.

    ``slots_per_worker`` / ``gen_tokens`` are taken from the trace's
    :class:`~repro.malleability.policies.TrafficPolicy` so the service
    honors the capacity model the autoscaler planned with (one request
    holds a slot for roughly ``hold_steps`` steps at one token/step).
    """
    pol = SERVE_TRAFFIC[name]
    return ServeConfig(slots_per_worker=pol.slots_per_worker,
                       gen_tokens=pol.hold_steps - 2, gen_spread=3)


@dataclass(frozen=True)
class ServePhase:
    """One steady allocation span between resizes."""

    start_step: int
    end_step: int                   # exclusive
    workers: int
    completed: int
    p50_latency_s: float
    throughput_tok_s: float


@dataclass(frozen=True)
class ServeReport:
    """Everything one serve replay produced (deterministic per trace)."""

    scenario: str
    executor: str
    records: Tuple[ScenarioRecord, ...]
    latencies: Tuple[float, ...]    # per completed request, in rid order
    phases: Tuple[ServePhase, ...]
    wall_s: float
    downtime_s: float
    queued_s: float
    bytes_moved: int
    bytes_cross_rack: int
    tokens_decoded: int
    submitted: int
    completed: int
    migrated: int                   # resize survivors that kept decoding
    requeued: int                   # resize survivors sent back to the queue
    dropped: int                    # MUST be 0 (asserted before reporting)

    @property
    def p50_latency_s(self) -> float:
        return _percentile(self.latencies, 0.50)

    @property
    def p99_latency_s(self) -> float:
        return _percentile(self.latencies, 0.99)

    @property
    def throughput_tok_s(self) -> float:
        return self.tokens_decoded / self.wall_s if self.wall_s > 0 else 0.0


def _percentile(values: Tuple[float, ...], q: float) -> float:
    if not values:
        return 0.0
    s = sorted(values)
    return s[min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))]


def serve_parity_key(report: ServeReport) -> tuple:
    """THE canonical serve-replay parity tuple for sim == live checks.

    Extends :func:`~repro.malleability.scenarios.record_parity_key` (one
    entry per reconfiguration) with the serving-side outcomes: request
    latencies, token counts, migration/requeue tallies, and the wall
    clock.  Two executors replaying the same trace must match on ALL of
    it — the numbers are produced by identical arithmetic on identical
    state, so the comparison is exact, not approximate.
    """
    return (
        report.scenario,
        tuple(record_parity_key(r) for r in report.records),
        report.latencies,
        report.wall_s,
        report.downtime_s,
        report.queued_s,
        report.bytes_moved,
        report.bytes_cross_rack,
        report.tokens_decoded,
        report.submitted,
        report.completed,
        report.migrated,
        report.requeued,
        report.dropped,
    )


class _ByteParityError(AssertionError):
    """A resize's charged, predicted, and measured bytes disagreed."""


def _serve_cluster_for(scenario: Scenario, engine, executor: str):
    if executor == "sim":
        return _SimCluster(scenario=scenario, engine=engine)
    if executor == "live":
        from repro.elastic.runtime import ElasticRuntime

        from repro.malleability.scenarios import RuntimeAdapter

        rt = ElasticRuntime(pool=scenario_pool(scenario),
                            initial_nodes=scenario.initial_nodes,
                            engine=engine)
        return RuntimeAdapter(rt)
    raise ValueError(f"unknown executor {executor!r}; pick from {EXECUTORS}")


def run_serve(
    name: str,
    *,
    executor: str = "sim",
    strategy=None,
    config: Optional[ServeConfig] = None,
) -> ServeReport:
    """Replay a registered serve trace through one executor.

    The engine is the scenario's default engine (same strategy
    resolution as every other consumer) with its bytes model swapped for
    the live :class:`~repro.serving.kv_cache.KVBytesModel`, so resize
    pricing tracks the actual in-flight KV pages.  Raises on any parity
    violation: engine-charged vs predicted vs measured bytes, the
    prefix-range worker contract, a dropped request, or a trace that
    fails to drain.
    """
    scenario = get_scenario(name)
    if name not in SERVE_TRAFFIC:
        raise KeyError(
            f"{name!r} has no traffic trace; serve scenarios: "
            f"{sorted(SERVE_TRAFFIC)}")
    rates = SERVE_TRAFFIC[name].rates
    cfg = config or serve_config(name)

    table = KVPageTable(
        cfg.page_spec(), range(scenario.initial_nodes), cfg.pages_per_worker,
        slot_limit=cfg.slots_per_worker)
    batcher = ContinuousBatcher(table, cfg.slots_per_worker)
    engine = scenario.default_engine(strategy)
    engine.bytes_model = KVBytesModel(table)
    cluster = _serve_cluster_for(scenario, engine, executor)

    events_at: Dict[int, List] = {}
    for ev in sorted(scenario.events, key=lambda e: e.step):
        events_at.setdefault(ev.step, []).append(ev)

    wall = 0.0
    next_rid = 0
    carry = 0.0                      # fractional-arrival accumulator
    arrival_wall: Dict[int, float] = {}
    latency: Dict[int, float] = {}
    records: List[ScenarioRecord] = []
    tokens_by_step: List[int] = []
    completions: List[Tuple[int, int]] = []      # (step, rid)
    downtime_by_step: Dict[int, float] = {}

    def one_step(step: int, rate: float) -> None:
        nonlocal wall, next_rid, carry
        for ev in events_at.get(step, ()):
            for rec in _dispatch(cluster, ev):
                rec = replace(rec, step=step)
                nodes_after = sorted(cluster.state.nodes_in_use())
                if nodes_after != list(range(len(nodes_after))):
                    raise RuntimeError(
                        f"serve trace {name!r} broke the prefix-range "
                        f"worker contract at step {step}: {nodes_after}")
                predicted = table.predicted_resize_stats(nodes_after)
                result = batcher.resize(nodes_after, step)
                if result.stats != predicted:
                    raise _ByteParityError(
                        f"step {step}: measured migration {result.stats} "
                        f"!= predicted {predicted}")
                charged = (rec.bytes_stayed, rec.bytes_moved)
                planned = (predicted["bytes_stayed"],
                           predicted["bytes_moved"])
                if charged != planned:
                    raise _ByteParityError(
                        f"step {step}: engine charged (stayed, moved)="
                        f"{charged} but the page table planned {planned}")
                wall += rec.downtime_s
                downtime_by_step[step] = (downtime_by_step.get(step, 0.0)
                                          + rec.downtime_s)
                records.append(rec)
        carry += rate
        while carry >= 1.0:
            carry -= 1.0
            batcher.submit(cfg.request_for(next_rid, step))
            arrival_wall[next_rid] = wall
            next_rid += 1
        batcher.admit(step)
        n_tokens, done = batcher.decode(step)
        wall += cfg.resolved_step_time_s(cluster.n_nodes)
        tokens_by_step.append(n_tokens)
        for rid in done:
            latency[rid] = wall - arrival_wall[rid]
            completions.append((step, rid))
        batcher.check_invariants()

    for step in range(scenario.steps):
        one_step(step, rates[step] if step < len(rates) else 0.0)
    step = scenario.steps
    while batcher.in_flight():
        if step >= scenario.steps + cfg.max_drain_steps:
            raise RuntimeError(
                f"serve trace {name!r} failed to drain: "
                f"{len(batcher.in_flight())} requests still in flight")
        one_step(step, 0.0)
        step += 1

    if batcher.dropped or len(batcher.completed) != next_rid:
        raise RuntimeError(
            f"serve trace {name!r} lost requests: submitted {next_rid}, "
            f"completed {len(batcher.completed)}, dropped {batcher.dropped}")
    if table.total_pages() or table.pages_allocated != table.pages_freed:
        raise RuntimeError(
            f"serve trace {name!r} leaked KV pages: {table.total_pages()} "
            f"resident, {table.pages_allocated} allocated, "
            f"{table.pages_freed} freed")

    phases = _phases(scenario, records, step, completions, latency,
                     tokens_by_step, downtime_by_step, cfg)
    return ServeReport(
        scenario=name,
        executor=executor,
        records=tuple(records),
        latencies=tuple(latency[r] for r in sorted(latency)),
        phases=phases,
        wall_s=wall,
        downtime_s=sum(r.downtime_s for r in records),
        queued_s=sum(r.queued_s for r in records),
        bytes_moved=sum(r.bytes_moved for r in records),
        bytes_cross_rack=sum(r.bytes_cross_rack for r in records),
        tokens_decoded=batcher.tokens_decoded,
        submitted=next_rid,
        completed=len(batcher.completed),
        migrated=batcher.migrated,
        requeued=batcher.requeued,
        dropped=batcher.dropped,
    )


def _phases(
    scenario: Scenario,
    records: List[ScenarioRecord],
    total_steps: int,
    completions: List[Tuple[int, int]],
    latency: Dict[int, float],
    tokens_by_step: List[int],
    downtime_by_step: Dict[int, float],
    cfg: ServeConfig,
) -> Tuple[ServePhase, ...]:
    """Slice the run into steady allocation spans between resizes.

    A resize happens at the top of its step, so that step opens a new
    phase (and carries the resize's downtime in the phase's wall time).
    Each phase's span is priced at ITS worker count
    (:meth:`ServeConfig.resolved_step_time_s`), matching the per-step
    accumulation in the run loop.
    """
    starts = [0]
    workers = [scenario.initial_nodes]
    for rec in records:
        if rec.step != starts[-1]:
            starts.append(rec.step)
            workers.append(rec.nodes_after)
        else:
            workers[-1] = rec.nodes_after
    bounds = starts + [total_steps]
    out = []
    for i, start in enumerate(starts):
        end = bounds[i + 1]
        lats = sorted(latency[rid] for s, rid in completions
                      if start <= s < end)
        toks = sum(tokens_by_step[start:end])
        span = (end - start) * cfg.resolved_step_time_s(workers[i]) + sum(
            dt for s, dt in downtime_by_step.items() if start <= s < end)
        out.append(ServePhase(
            start_step=start,
            end_step=end,
            workers=workers[i],
            completed=len(lats),
            p50_latency_s=_percentile(tuple(lats), 0.50),
            throughput_tok_s=toks / span if span > 0 else 0.0,
        ))
    return tuple(out)


def check_serve_agreement(names=None, *, strategy=None) -> int:
    """Replay every serve trace on BOTH executors; 0 iff all agree.

    The serving analog of :func:`examples.malleability_sim
    .check_sim_live_agreement`: prints each disagreement to stderr and
    returns the number of disagreeing traces, so callers can
    ``sys.exit`` on it.
    """
    import sys

    bad = 0
    for name in (names if names is not None else sorted(SERVE_TRAFFIC)):
        sim = run_serve(name, executor="sim", strategy=strategy)
        live = run_serve(name, executor="live", strategy=strategy)
        if serve_parity_key(sim) != serve_parity_key(live):
            bad += 1
            print(f"serve sim/live DISAGREE on {name!r}:", file=sys.stderr)
            for fld in ServeReport.__dataclass_fields__:
                a, b = getattr(sim, fld), getattr(live, fld)
                if a != b:
                    print(f"  {fld}: sim={a!r} live={b!r}", file=sys.stderr)
    return bad
