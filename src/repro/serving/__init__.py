"""repro.serving — the elastic decode serving plane.

A pool of decode workers managed by the
:class:`~repro.core.engine.ReconfigEngine`, grown and shrunk by
traffic-driven RMS policies, with in-flight KV caches migrated (never
dropped) and priced as REDISTRIBUTION bytes.  See ``docs/serving.md``.
"""
from .batching import ContinuousBatcher, Request
from .kv_cache import (
    KVBytesModel,
    KVPageTable,
    PageSpec,
    ResizeResult,
    page_bytes_for_arch,
)
from .service import (
    EXECUTORS,
    ServeConfig,
    ServePhase,
    ServeReport,
    check_serve_agreement,
    run_serve,
    serve_config,
    serve_parity_key,
)

__all__ = [
    "ContinuousBatcher",
    "Request",
    "KVBytesModel",
    "KVPageTable",
    "PageSpec",
    "ResizeResult",
    "page_bytes_for_arch",
    "EXECUTORS",
    "ServeConfig",
    "ServePhase",
    "ServeReport",
    "check_serve_agreement",
    "run_serve",
    "serve_config",
    "serve_parity_key",
]
