"""Paged KV-cache manager: page table + per-request page lists.

Decode workers hold their requests' KV caches in fixed-size **pages**
(the MaxText ``inference.page_manager`` / vLLM PagedAttention idea): a
request owns ``ceil(tokens / page_tokens)`` pages, all resident on the
worker decoding it.  :class:`KVPageTable` is the bookkeeping — which
page lives where, which request owns it — and, critically for this
repo, the **bytes model** for reconfiguration pricing: when the serving
pool resizes, the pages of migrated requests are REDISTRIBUTION bytes
exactly like resharded parameters are for training.

Pricing follows :mod:`repro.elastic.reshard` one-for-one:

* :meth:`KVPageTable.predicted_resize_stats` is the *predicted* side —
  a pure function of the current table and the target worker set,
  returning the same ``{"bytes_total", "bytes_stayed", "bytes_moved"}``
  dict as :func:`repro.elastic.reshard.predicted_transfer_stats`;
* :meth:`KVPageTable.apply_resize` performs the migration and
  *measures* the same stats from the page→worker diff; the two agree
  byte for byte (pinned by ``tests/test_serving.py``);
* :class:`KVBytesModel` adapts the table to the
  :class:`~repro.core.engine.ReconfigEngine` bytes-model protocol
  (``stats(ns, nt)``, mirroring
  :class:`~repro.elastic.reshard.PytreeBytesModel`), so an engine
  planning a decode-pool resize charges the in-flight KV footprint as
  stage-3 bytes — distance-class splitting (``bytes_cross_rack`` /
  ``bytes_cross_pod``) rides on top via the engine's placement
  machinery, unchanged.

Migration placement is deterministic (worker with the most free pages,
then lowest id; grows rebalance onto the fresh workers only), which is
what lets the simulator and the live runtime charge identical bytes
without exchanging any state.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class PageSpec:
    """Fixed page geometry: tokens per page and bytes per page."""

    page_tokens: int
    page_bytes: int

    def __post_init__(self) -> None:
        if self.page_tokens <= 0 or self.page_bytes <= 0:
            raise ValueError(
                f"page geometry must be positive, got {self}")

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV entries (at least one)."""
        return max(1, -(-int(tokens) // self.page_tokens))


@functools.lru_cache(maxsize=None)
def page_bytes_for_arch(arch: str, page_tokens: int, batch: int = 1) -> int:
    """Exact bytes of one ``page_tokens``-token KV page for a model config.

    ``init_cache``-compatible by construction: sums the abstract
    :func:`repro.models.transformer.init_cache_shapes` spec for a
    ``(batch, page_tokens)`` cache — the same shapes
    :meth:`repro.models.model.Model.init_cache` allocates — so a page
    priced here is a real slice of the model's decode cache, no weights
    allocated.
    """
    import numpy as np  # local: keep the serving plane light to import

    from repro.configs import arch_config
    from repro.models.transformer import init_cache_shapes

    shapes = init_cache_shapes(arch_config(arch), batch, page_tokens)
    return int(sum(
        int(np.prod(shape)) * np.dtype(dt).itemsize
        for shape, dt, _axes, _fill in shapes.values()
    ))


@dataclass(frozen=True)
class ResizeResult:
    """One applied page migration: who moved, and the measured stats.

    ``stats`` is MEASURED from the page→worker diff after the move (not
    read off the plan), so asserting it against
    :meth:`KVPageTable.predicted_resize_stats` is a real
    predicted-vs-measured parity check, like
    ``transfer_stats == predicted_transfer_stats`` in
    :mod:`repro.elastic.reshard`.
    """

    moves: Tuple[Tuple[int, int, int], ...]   # (request, src, dst) per move
    stats: Dict[str, int]                     # bytes_total/stayed/moved
    evicted: Tuple[int, ...]                  # workers removed
    added: Tuple[int, ...]                    # workers added

    @property
    def moved_requests(self) -> Tuple[int, ...]:
        return tuple(rid for rid, _s, _d in self.moves)


class KVPageTable:
    """Page table for a pool of decode workers.

    One request's pages all live on one worker (its decode slot's
    worker).  ``pages_per_worker`` is the admission capacity; migration
    may overcommit a survivor (shedding capacity under shrink must never
    fail — the zero-drop invariant outranks the soft page budget).
    ``slot_limit`` caps how many requests a grow may rebalance onto one
    fresh worker (the batching layer passes its decode-slot count, so a
    remapped request always finds a slot).
    """

    def __init__(
        self,
        spec: PageSpec,
        workers: Iterable[int],
        pages_per_worker: int,
        *,
        capacities: Optional[Dict[int, int]] = None,
        slot_limit: Optional[int] = None,
    ) -> None:
        if pages_per_worker <= 0:
            raise ValueError("pages_per_worker must be positive")
        self.spec = spec
        self.pages_per_worker = pages_per_worker
        self.slot_limit = slot_limit
        self._capacity: Dict[int, int] = {}
        for w in workers:
            self._capacity[int(w)] = pages_per_worker
        if capacities:
            for w, cap in capacities.items():
                if int(cap) <= 0:
                    raise ValueError(f"worker {w}: capacity must be positive")
                self._capacity[int(w)] = int(cap)
        if not self._capacity:
            raise ValueError("page table needs at least one worker")
        # page id -> worker / owning request; request -> its pages (ordered)
        self._page_worker: Dict[int, int] = {}
        self._page_owner: Dict[int, int] = {}
        self._request_pages: Dict[int, List[int]] = {}
        self._request_worker: Dict[int, int] = {}
        self._next_page = 0
        self.pages_allocated = 0
        self.pages_freed = 0

    # ------------------------------------------------------------- queries --
    def worker_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(self._capacity))

    @property
    def n_workers(self) -> int:
        return len(self._capacity)

    def capacity(self, worker: int) -> int:
        return self._capacity[worker]

    def used_pages(self, worker: int) -> int:
        if worker not in self._capacity:
            raise KeyError(f"unknown worker {worker}")
        return sum(1 for w in self._page_worker.values() if w == worker)

    def free_pages(self, worker: int) -> int:
        return self._capacity[worker] - self.used_pages(worker)

    def total_pages(self) -> int:
        return len(self._page_worker)

    def total_bytes(self) -> int:
        return self.total_pages() * self.spec.page_bytes

    def requests(self) -> Tuple[int, ...]:
        return tuple(sorted(self._request_pages))

    def request_worker(self, rid: int) -> int:
        return self._request_worker[rid]

    def request_pages(self, rid: int) -> Tuple[int, ...]:
        return tuple(self._request_pages[rid])

    def request_bytes(self, rid: int) -> int:
        return len(self._request_pages[rid]) * self.spec.page_bytes

    def requests_on(self, worker: int) -> Tuple[int, ...]:
        return tuple(sorted(
            r for r, w in self._request_worker.items() if w == worker))

    def pages_on(self, worker: int) -> int:
        """Pages resident on one worker (its migration load)."""
        return self.used_pages(worker)

    # ---------------------------------------------------------- allocation --
    def allocate(self, rid: int, n_pages: int, worker: int) -> Tuple[int, ...]:
        """Give a new request ``n_pages`` pages on ``worker``."""
        if rid in self._request_pages:
            raise ValueError(f"request {rid} already holds pages")
        if worker not in self._capacity:
            raise KeyError(f"unknown worker {worker}")
        if n_pages <= 0:
            raise ValueError("n_pages must be positive")
        ids = []
        for _ in range(n_pages):
            pid = self._next_page
            self._next_page += 1
            self._page_worker[pid] = worker
            self._page_owner[pid] = rid
            ids.append(pid)
        self._request_pages[rid] = ids
        self._request_worker[rid] = worker
        self.pages_allocated += n_pages
        return tuple(ids)

    def append_page(self, rid: int) -> int:
        """One more page for a decoding request (on its worker)."""
        worker = self._request_worker[rid]
        pid = self._next_page
        self._next_page += 1
        self._page_worker[pid] = worker
        self._page_owner[pid] = rid
        self._request_pages[rid].append(pid)
        self.pages_allocated += 1
        return pid

    def free_request(self, rid: int) -> int:
        """Release every page a finished request holds; returns the count."""
        pages = self._request_pages.pop(rid)
        del self._request_worker[rid]
        for pid in pages:
            del self._page_worker[pid]
            del self._page_owner[pid]
        self.pages_freed += len(pages)
        return len(pages)

    # ------------------------------------------------------------ resizing --
    def plan_resize(
        self, workers_after: Sequence[int],
    ) -> Dict[int, Tuple[int, int]]:
        """Deterministic migration plan for a new worker set.

        Pure (no mutation).  Returns ``{request: (src, dst)}``:

        * every request on an **evicted** worker moves to the remaining
          worker with the most free pages (lowest id on ties) — requests
          in id order, loads updated as they land, overcommit allowed
          (fresh workers join with ``pages_per_worker`` capacity and
          zero load, so they naturally absorb evictions first);
        * a **grow** additionally rebalances page load onto the fresh
          workers: while some remaining worker carries more pages than a
          fresh one plus the candidate request's pages, the newest
          request (highest id) moves over.  Moving strictly decreases
          the sum of squared loads, so the loop terminates; surviving
          placements are otherwise untouched.

        ``slot_limit`` (when set) caps TOTAL requests placed onto each
        fresh worker across both phases, so every remapped request finds
        a decode slot there.
        """
        after = {int(w) for w in workers_after}
        if not after:
            raise ValueError("cannot resize to an empty worker set")
        current = set(self._capacity)
        evicted = sorted(current - after)
        added = sorted(after - current)
        remaining = sorted(after)

        loads = {w: (self.used_pages(w) if w in current else 0)
                 for w in remaining}
        caps = {w: (self._capacity[w] if w in current
                    else self.pages_per_worker) for w in remaining}
        incoming = {w: 0 for w in added}
        moves: Dict[int, Tuple[int, int]] = {}

        def open_for(w: int) -> bool:
            return (w not in incoming or self.slot_limit is None
                    or incoming[w] < self.slot_limit)

        def place(rid: int, src: int, dst: int) -> None:
            moves[rid] = (src, dst)
            loads[dst] += len(self._request_pages[rid])
            if dst in incoming:
                incoming[dst] += 1

        # 1) evictions: drain every request off the removed workers.
        for w in evicted:
            for rid in self.requests_on(w):
                candidates = [s for s in remaining if open_for(s)]
                if not candidates:
                    raise RuntimeError(
                        "resize cannot place evicted requests: every "
                        "remaining worker is at its slot limit")
                dst = max(candidates, key=lambda s: (caps[s] - loads[s], -s))
                place(rid, w, dst)

        # 2) grow rebalance: spread page load onto the fresh workers.
        if added:
            survivors = sorted(current & after)
            movable = {
                w: [r for r in self.requests_on(w) if r not in moves]
                for w in survivors
            }
            while survivors:
                src = max(survivors, key=lambda s: (loads[s], -s))
                open_new = [w for w in added if open_for(w)]
                if not open_new or not movable[src]:
                    break
                dst = min(open_new, key=lambda w: (loads[w], w))
                rid = movable[src][-1]          # newest request first
                pages = len(self._request_pages[rid])
                if loads[src] - loads[dst] <= pages:
                    break                        # balanced: stop moving
                movable[src].pop()
                loads[src] -= pages
                place(rid, src, dst)
        return moves

    def _stats(self, moved_bytes: int) -> Dict[str, int]:
        total = self.total_bytes()
        return {
            "bytes_total": total,
            "bytes_stayed": total - moved_bytes,
            "bytes_moved": moved_bytes,
        }

    def predicted_resize_stats(
        self, workers_after: Sequence[int],
    ) -> Dict[str, int]:
        """Predicted transfer stats for a resize — pure, from the plan.

        The serving analog of :func:`repro.elastic.reshard
        .predicted_transfer_stats`: moved = pages of migrated requests,
        stayed = pages revalidated in place, total = the whole resident
        KV footprint.
        """
        moves = self.plan_resize(workers_after)
        moved = sum(self.request_bytes(rid) for rid in moves)
        return self._stats(moved)

    def apply_resize(self, workers_after: Sequence[int]) -> ResizeResult:
        """Perform the planned migration; MEASURE the stats from the diff."""
        moves = self.plan_resize(workers_after)
        after = {int(w) for w in workers_after}
        before_worker = dict(self._page_worker)
        for rid, (_src, dst) in moves.items():
            self._request_worker[rid] = dst
            for pid in self._request_pages[rid]:
                self._page_worker[pid] = dst
        evicted = tuple(sorted(set(self._capacity) - after))
        added = tuple(sorted(after - set(self._capacity)))
        for w in evicted:
            if self.used_pages(w):
                raise RuntimeError(
                    f"eviction left pages on worker {w}")  # pragma: no cover
            del self._capacity[w]
        for w in added:
            self._capacity[w] = self.pages_per_worker
        moved = sum(
            self.spec.page_bytes
            for pid, w in self._page_worker.items() if before_worker[pid] != w
        )
        return ResizeResult(
            moves=tuple((rid, src, dst)
                        for rid, (src, dst) in sorted(moves.items())),
            stats=self._stats(moved),
            evicted=evicted,
            added=added,
        )


@dataclass
class KVBytesModel:
    """The page table as a :class:`~repro.core.engine.ReconfigEngine`
    bytes model — KV migration priced as REDISTRIBUTION bytes.

    Mirrors :class:`~repro.elastic.reshard.PytreeBytesModel`'s protocol:
    ``stats(ns, nt)`` returns the per-link split the engine charges
    (stayed on the local link, moved across), and calling the model
    returns the same mapping.  The engine hands over **rank** counts;
    the serving pool runs 1-wide workers on the prefix node range
    ``0..n-1`` (grows acquire lowest-free, traffic-policy shrinks evict
    the top ids), so ``ns`` names the current workers and ``nt`` the
    target set ``range(nt)`` — enforced, not assumed.

    ``stats`` is pure: the engine prices the plan *before* the service
    applies the migration, and the measured
    :meth:`KVPageTable.apply_resize` stats must then equal the charged
    bytes exactly (the serve loop asserts it on every resize).
    """

    table: KVPageTable
    width: int = 1                  # ranks per worker (serve pools are 1-wide)

    def _check(self, ns: int) -> None:
        if ns % self.width:
            raise ValueError(
                f"rank count {ns} is not a multiple of worker width "
                f"{self.width}")
        workers = self.table.worker_ids()
        if workers != tuple(range(ns // self.width)):
            raise ValueError(
                f"page table holds workers {workers} but the engine is "
                f"pricing a resize from {ns} ranks (expected the prefix "
                f"range 0..{ns // self.width - 1})")

    def stats(self, ns: int, nt: int) -> Dict[str, int]:
        if ns == nt or ns <= 0 or nt <= 0:
            return {"bytes_total": 0, "bytes_stayed": 0, "bytes_moved": 0}
        self._check(ns)
        out = self.table.predicted_resize_stats(range(nt // self.width))
        return dict(out)

    def __call__(self, ns: int, nt: int) -> Dict[str, int]:
        return self.stats(ns, nt)
