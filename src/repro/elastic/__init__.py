"""Elastic runtime: the paper's control plane driving real JAX meshes.

NodeGroups are the releasable hardware unit (the paper's node-confined
MCWs); expansion runs a parallel spawn plan to bring groups up, shrink
terminates whole groups (TS) and returns their devices, and the data-
redistribution stage is a live resharding of params/optimizer state onto
the rebuilt mesh.
"""
from .node_group import DevicePool, NodeGroup
from .reshard import (
    PytreeBytesModel,
    predicted_transfer_stats,
    reshard_tree,
    transfer_stats,
)
from .rms import Event, EventKind, SimulatedRMS
from .runtime import ElasticRuntime, ReconfigRecord
from .trainer import ElasticTrainer, StepRecord

__all__ = [
    "DevicePool",
    "ElasticRuntime",
    "ElasticTrainer",
    "Event",
    "EventKind",
    "NodeGroup",
    "PytreeBytesModel",
    "ReconfigRecord",
    "SimulatedRMS",
    "StepRecord",
    "predicted_transfer_stats",
    "reshard_tree",
    "transfer_stats",
]
