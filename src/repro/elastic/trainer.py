"""ElasticTrainer: the full malleability loop as a library component.

Wraps a Model + ElasticRuntime + SimulatedRMS into one training loop:
every step it drains due RMS events, reconfigures (expand via the
parallel spawn plan, shrink/fail/straggler via TS), reshards the live
TrainState onto the rebuilt mesh (stage 3), re-jits, and continues.
Mesh-independent checkpoints — periodic, or CHECKPOINT-event-driven —
cover the full-stop path: a RESTART event rebuilds the world at the
target size and reads the params back from the latest snapshot.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticTokens, make_batch_on_mesh
from repro.malleability.scenarios import RuntimeAdapter, dispatch_event
from repro.models import Model
from repro.parallel.sharding import ShardingContext
from repro.train.steps import (
    TrainState,
    build_init_fn,
    build_train_step,
    train_state_shardings,
)

from .reshard import transfer_stats
from .rms import Event, EventKind, SimulatedRMS
from .runtime import ElasticRuntime


@dataclass
class StepRecord:
    step: int
    loss: float
    n_nodes: int


@dataclass
class ElasticTrainer:
    model: Model
    runtime: ElasticRuntime
    rms: SimulatedRMS
    lr: float = 1e-3
    batch: int = 8
    seq: int = 64
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    history: list[StepRecord] = field(default_factory=list)
    transfer_log: list[dict] = field(default_factory=list)

    def __post_init__(self):
        self._ctx = self._make_ctx()
        self._step_fn = None
        self._restore_pending = False
        self._state: Optional[TrainState] = None
        self._data = SyntheticTokens(self.model.cfg, self.batch, self.seq, self.seed)
        self._ckpt = (
            CheckpointManager(self.checkpoint_dir) if self.checkpoint_dir else None
        )

    @classmethod
    def from_scenario(cls, model: Model, scenario, pool=None, engine=None,
                      **kwargs) -> "ElasticTrainer":
        """Build the full loop from a declarative scenario: the runtime
        executes the trace through the same ReconfigEngine the simulator
        charges, so per-event downtimes (and charged bytes) agree across
        both paths.  Pass ``engine`` to override the scenario's default —
        e.g. one carrying a :class:`~repro.elastic.reshard.PytreeBytesModel`
        so charged bytes exactly equal the measured reshard.

        Heterogeneous scenarios run too: the pool is partitioned with the
        scenario's uneven ``core_pool`` width vector (host devices must
        cover ``sum(core_pool)``)."""
        from repro.malleability.scenarios import check_scenario_pool, scenario_pool

        need = (sum(scenario.core_pool) if scenario.core_pool
                else scenario.max_nodes() * scenario.cores_per_node)
        if pool is None:
            devs = jax.devices()
            if len(devs) >= need:
                pool = scenario_pool(scenario, devices=devs)
        else:
            check_scenario_pool(scenario, pool)
        if pool is None or pool.n_nodes < scenario.max_nodes():
            width = (f"widths {scenario.core_pool}" if scenario.core_pool
                     else f"{scenario.cores_per_node} devices/node")
            have = (pool.n_nodes if pool is not None
                    else f"{len(jax.devices())} devices")
            raise ValueError(
                f"scenario {scenario.name!r} peaks at {scenario.max_nodes()} "
                f"nodes ({width}) but the host/pool only has {have}; set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
                "before importing jax, or pass a larger pool"
            )
        runtime = ElasticRuntime(
            pool=pool,
            initial_nodes=scenario.initial_nodes,
            engine=engine or scenario.default_engine(),
        )
        rms = SimulatedRMS.from_scenario(scenario)
        return cls(model=model, runtime=runtime, rms=rms, **kwargs)

    # ------------------------------------------------------------------ mesh --
    def _make_ctx(self) -> ShardingContext:
        return ShardingContext(mesh=self.runtime.mesh(("data",)), mode="train")

    def _rejit(self):
        step_fn, shardings, _ = build_train_step(self.model, self._ctx, lr=self.lr)
        self._step_fn = jax.jit(
            step_fn,
            in_shardings=(shardings, None),
            out_shardings=(shardings, None),
            donate_argnums=(0,),
        )
        return shardings

    def _init_state(self):
        init_fn, _ = build_init_fn(self.model, self._ctx)
        self._state = init_fn(jax.random.key(self.seed))
        self._rejit()

    # --------------------------------------------------------------- resharding --
    def _reshard_state(self, step: int = -1, charged_bytes: int = 0):
        """Stage 3: move the live TrainState onto the rebuilt mesh.

        Logs the *measured* transfer stats of the parameter pytree next
        to the engine-*charged* bytes for the drained events, so the two
        accountings can be compared (they are equal when the engine uses
        a :class:`~repro.elastic.reshard.PytreeBytesModel` and one event
        was drained; multi-event drains reshard once over the net mesh
        change while the engine charges each hop).
        """
        _, shardings = train_state_shardings(self.model, self._ctx)
        old_params = self._state.params
        self._state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), self._state, shardings,
        )
        stats = dict(transfer_stats(old_params, self._state.params))
        stats["step"] = step
        stats["charged_bytes_moved"] = charged_bytes
        self.transfer_log.append(stats)
        self._rejit()

    def _restore_from_store(self, step: int, charged_bytes: int = 0):
        """SS-restart stage 3: params come back from the latest snapshot.

        Checkpoints are mesh-independent (host ``.npy`` leaves + a
        manifest), so a snapshot written under the old mesh restores
        under the rebuilt one's shardings.  Optimizer state and the step
        counter reshard live — mirroring what the saves persist.  With
        no store (or an empty one) the live state reshards instead: the
        charged cost story is identical, only the data source differs.
        """
        if self._ckpt is None or self._state is None:
            self._reshard_state(step=step, charged_bytes=charged_bytes)
            return
        _, shardings = train_state_shardings(self.model, self._ctx)
        spec_tree = jax.tree.map(lambda s: s.spec, shardings.params)
        tree, ck_step = self._ckpt.restore_latest(
            {"params": self._state.params}, mesh=self._ctx.mesh,
            spec_tree={"params": spec_tree},
        )
        if tree is None:
            self._reshard_state(step=step, charged_bytes=charged_bytes)
            return
        old_params = self._state.params
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), self._state, shardings,
        )
        self._state = state._replace(params=tree["params"])
        stats = dict(transfer_stats(old_params, self._state.params))
        stats["step"] = step
        stats["charged_bytes_moved"] = charged_bytes
        stats["restored_from_step"] = ck_step
        self.transfer_log.append(stats)
        self._rejit()

    # -------------------------------------------------------------------- events --
    def _handle(self, ev: Event):
        """One RMS event through the SAME dispatch the scenario executors
        use — the mapping lives once, in repro.malleability.scenarios."""
        if ev.kind is EventKind.NOOP:
            return False
        applied = list(dispatch_event(
            RuntimeAdapter(self.runtime), ev.kind.value,
            nodes=ev.nodes, target_nodes=ev.target_nodes,
            queue_delay_s=ev.queue_delay_s,
        ))
        if ev.kind is EventKind.CHECKPOINT:
            # Persist the real snapshot next to the charged record, so a
            # later RESTART (or failure recovery) has bytes to read back.
            if self._ckpt is not None and self._state is not None:
                self._ckpt.save({"params": self._state.params},
                                len(self.history))
            return False  # no allocation change: keep the mesh and jit
        if ev.kind is EventKind.RESTART and applied:
            self._restore_pending = True
        return bool(applied)

    # ---------------------------------------------------------------------- run --
    def run(self, steps: int) -> list[StepRecord]:
        if self._state is None:
            self._init_state()
        for i in range(steps):
            step_no = len(self.history)
            reconfigured = False
            records_before = len(self.runtime.history)
            for ev in self.rms.events_until(step_no):
                reconfigured |= self._handle(ev)
            if reconfigured:
                self._ctx = self._make_ctx()
                charged = sum(
                    r.bytes_moved
                    for r in self.runtime.history[records_before:]
                )
                if self._restore_pending:
                    self._restore_pending = False
                    self._restore_from_store(step_no, charged_bytes=charged)
                else:
                    self._reshard_state(step=step_no, charged_bytes=charged)
            batch = make_batch_on_mesh(
                self._data.sample(step_no), self.model.cfg, self._ctx
            )
            self._state, metrics = self._step_fn(self._state, batch)
            self.history.append(
                StepRecord(step=step_no, loss=float(metrics["loss"]),
                           n_nodes=self.runtime.n_nodes)
            )
            if self._ckpt and (step_no + 1) % self.checkpoint_every == 0:
                self._ckpt.save({"params": self._state.params}, step_no + 1)
        if self._ckpt:
            self._ckpt.wait()
        return self.history

    # ------------------------------------------------------------------ queries --
    @property
    def state(self) -> TrainState:
        return self._state

    def losses(self) -> list[float]:
        return [r.loss for r in self.history]
