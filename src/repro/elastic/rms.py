"""Simulated Resource Management System (DRM side of the paper).

Two halves:

* the **event source** (this module): :class:`SimulatedRMS` emits
  grow/shrink/failure/straggler events against which the elastic runtime
  reconfigures — scripted, scenario-fed, or *policy*-generated;
* the **policy engine** (:mod:`repro.malleability.policies`, re-exported
  here): an RMS-side :class:`~repro.malleability.policies.ClusterState`
  (one shared node pool + per-job allocations) with pluggable
  :class:`~repro.malleability.policies.RmsPolicy` implementations —
  :class:`~repro.malleability.policies.BackfillPolicy` (idle nodes flow
  to malleable jobs, reclaimed under queue pressure),
  :class:`~repro.malleability.policies.PreemptionPolicy` (priority jobs
  force-shrink lower-priority ones, composing with in-flight
  reconfigurations), and
  :class:`~repro.malleability.policies.ChurnPolicy` (seeded long-horizon
  grow/shrink cycling) — plus a multi-job arbiter
  (:func:`~repro.malleability.policies.arbitrate_jobs`) that charges
  several jobs' timelines against one pool.

Policies *generate* declarative
:class:`~repro.malleability.scenarios.Scenario` traces, so the existing
sim/live machinery consumes policy output unchanged:
``SimulatedRMS.from_policy(policy, cluster)`` is exactly
``from_scenario(policy.generate(cluster).scenario(job))``.
"""
from __future__ import annotations

import enum
import warnings
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterator

if TYPE_CHECKING:  # annotations only; the runtime names are shimmed below
    from repro.malleability.policies import ClusterState, RmsPolicy

# The policy subsystem used to be re-exported from here; the stable
# import path is now repro.api (satellite of the repro.api redesign).
# Each name resolves through a thin PEP 562 shim that emits ONE
# DeprecationWarning, then caches the real object into this module's
# globals so later lookups are free and silent.
_DEPRECATED_POLICY_EXPORTS = frozenset({
    "ArbitratedJob",
    "BackfillPolicy",
    "ChurnPolicy",
    "ClusterState",
    "JobSpec",
    "MultiJobOutcome",
    "PolicyTrace",
    "PreemptionPolicy",
    "PriorityArrival",
    "RigidArrival",
    "RmsPolicy",
    "arbitrate_jobs",
    "registered_policy_scenarios",
    "run_multijob_sim",
})


def __getattr__(name: str):
    if name in _DEPRECATED_POLICY_EXPORTS:
        warnings.warn(
            f"importing {name!r} from repro.elastic.rms is deprecated; "
            f"use repro.api.{name} (the stable surface) or "
            f"repro.malleability.policies.{name}",
            DeprecationWarning, stacklevel=2)
        from repro.malleability import policies

        value = getattr(policies, name)
        globals()[name] = value     # warn exactly once per name
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ArbitratedJob",
    "BackfillPolicy",
    "ChurnPolicy",
    "ClusterState",
    "Event",
    "EventKind",
    "JobSpec",
    "MultiJobOutcome",
    "PolicyTrace",
    "PreemptionPolicy",
    "PriorityArrival",
    "RigidArrival",
    "RmsPolicy",
    "SimulatedRMS",
    "arbitrate_jobs",
    "registered_policy_scenarios",
    "run_multijob_sim",
]


class EventKind(enum.Enum):
    GROW = "grow"            # RMS grants extra nodes
    SHRINK = "shrink"        # RMS reclaims nodes
    FAIL = "fail"            # a node died: forced TS shrink + recovery
    STRAGGLER = "straggler"  # a node is slow: voluntarily TS-shrink it out
    CHECKPOINT = "checkpoint"  # snapshot full state in place (no resize)
    RESTART = "restart"      # rigid full stop: checkpoint, respawn, restore
    NOOP = "noop"


@dataclass(frozen=True)
class Event:
    step: int
    kind: EventKind
    nodes: tuple[int, ...] = ()     # affected node ids (SHRINK/FAIL/STRAGGLER)
    target_nodes: int = 0           # new total node count (GROW/RESTART)
    queue_delay_s: float = 0.0      # RMS arbitration wait (QUEUE stage)


@dataclass
class SimulatedRMS:
    """Scripted, scenario-fed, or policy-generated event source."""

    script: list[Event] = field(default_factory=list)

    def events_until(self, step: int) -> Iterator[Event]:
        due = [e for e in self.script if e.step <= step]
        self.script = [e for e in self.script if e.step > step]
        yield from due

    @staticmethod
    def scripted(events: list[tuple[int, EventKind, tuple | int]]) -> "SimulatedRMS":
        out = []
        for step, kind, arg in events:
            if kind is EventKind.GROW:
                out.append(Event(step=step, kind=kind, target_nodes=int(arg)))
            else:
                nodes = (arg,) if isinstance(arg, int) else tuple(arg)
                out.append(Event(step=step, kind=kind, nodes=nodes))
        return SimulatedRMS(script=out)

    @staticmethod
    def from_scenario(scenario) -> "SimulatedRMS":
        """Feed a declarative :class:`repro.malleability.scenarios.Scenario`
        trace through the live event loop — the exact trace the simulator
        executes, so timeline-derived downtimes agree across both paths."""
        out = [
            Event(
                step=e.step,
                kind=EventKind(e.kind),
                nodes=tuple(e.nodes),
                target_nodes=e.target_nodes,
                queue_delay_s=e.queue_delay_s,
            )
            for e in sorted(scenario.events, key=lambda e: e.step)
        ]
        return SimulatedRMS(script=out)

    @staticmethod
    def from_policy(policy: RmsPolicy, cluster: ClusterState,
                    job: str | None = None) -> "SimulatedRMS":
        """Run an RMS policy and feed its generated trace to the runtime.

        Args:
            policy: any :class:`RmsPolicy` (backfill / preemption /
                churn / third-party).
            cluster: the RMS-side cluster view the policy schedules on.
            job: which job's trace to follow (defaults to the policy
                trace's primary — its first — job).
        Returns:
            A :class:`SimulatedRMS` scripted with the policy's decisions
            for that job.
        """
        trace = policy.generate(cluster)
        name = job if job is not None else trace.primary_job
        return SimulatedRMS.from_scenario(trace.scenario(name))
