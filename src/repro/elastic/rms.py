"""Simulated Resource Management System (DRM side of the paper).

Emits grow/shrink/failure/straggler events against which the elastic
runtime reconfigures.  Policies are deliberately simple — the paper's
scope is the *mechanism* (how to resize cheaply), not the policy (when).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Iterator


class EventKind(enum.Enum):
    GROW = "grow"            # RMS grants extra nodes
    SHRINK = "shrink"        # RMS reclaims nodes
    FAIL = "fail"            # a node died: forced TS shrink + recovery
    STRAGGLER = "straggler"  # a node is slow: voluntarily TS-shrink it out
    NOOP = "noop"


@dataclass(frozen=True)
class Event:
    step: int
    kind: EventKind
    nodes: tuple[int, ...] = ()     # affected node ids (SHRINK/FAIL/STRAGGLER)
    target_nodes: int = 0           # new total node count (GROW)


@dataclass
class SimulatedRMS:
    """Scripted or random event source."""

    script: list[Event] = field(default_factory=list)

    def events_until(self, step: int) -> Iterator[Event]:
        due = [e for e in self.script if e.step <= step]
        self.script = [e for e in self.script if e.step > step]
        yield from due

    @staticmethod
    def scripted(events: list[tuple[int, EventKind, tuple | int]]) -> "SimulatedRMS":
        out = []
        for step, kind, arg in events:
            if kind is EventKind.GROW:
                out.append(Event(step=step, kind=kind, target_nodes=int(arg)))
            else:
                nodes = (arg,) if isinstance(arg, int) else tuple(arg)
                out.append(Event(step=step, kind=kind, nodes=nodes))
        return SimulatedRMS(script=out)

    @staticmethod
    def from_scenario(scenario) -> "SimulatedRMS":
        """Feed a declarative :class:`repro.malleability.scenarios.Scenario`
        trace through the live event loop — the exact trace the simulator
        executes, so timeline-derived downtimes agree across both paths."""
        out = [
            Event(
                step=e.step,
                kind=EventKind(e.kind),
                nodes=tuple(e.nodes),
                target_nodes=e.target_nodes,
            )
            for e in sorted(scenario.events, key=lambda e: e.step)
        ]
        return SimulatedRMS(script=out)
