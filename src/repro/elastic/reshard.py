"""Data redistribution: reshard live state onto a rebuilt mesh.

This is stage 3 of the paper's malleability pipeline.  The paper defers
transfer-minimizing redistribution to future work; we implement it: the
device order of the new mesh keeps surviving devices in their previous
relative positions (the Eq. 9 reorder guarantees a deterministic order,
and :func:`repro.elastic.runtime.ElasticRuntime` feeds survivors first),
so shards that already sit on a surviving device do not move.

``transfer_stats`` quantifies the win: bytes that stay local vs bytes
that cross devices, for any (old sharding -> new sharding) pair.
``predicted_transfer_stats`` computes the same accounting *without*
materializing any array (from ``Sharding.devices_indices_map``), so the
cost simulator can charge the exact bytes the live reshard will move;
:class:`PytreeBytesModel` packages that as a
``ReconfigEngine.bytes_model``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard_tree(tree: Any, mesh: Mesh, spec_tree: Any) -> Any:
    """Reshard every leaf of ``tree`` onto ``mesh`` with the given specs.

    ``spec_tree`` is either a single PartitionSpec applied to all leaves or
    a pytree of specs matching ``tree``'s structure.  Uses ``device_put``,
    which moves only the shards that change placement.
    """
    if isinstance(spec_tree, P) or spec_tree is None:
        specs = jax.tree.map(lambda _: spec_tree or P(), tree)
    else:
        specs = spec_tree
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )


def _index_key(index: tuple, shape: tuple[int, ...]) -> tuple:
    """Normalize a shard's index slices to ((start, stop), ...) bounds."""
    return tuple(
        (s.start or 0, s.stop if s.stop is not None else dim)
        for s, dim in zip(index, shape)
    )


def _key_nbytes(key: tuple, itemsize: int) -> int:
    return int(np.prod([hi - lo for lo, hi in key]) * itemsize) if key else itemsize


def _shard_index_map(arr: Any) -> dict[tuple, set[int]]:
    """Map shard index-bounds -> device ids currently holding that shard."""
    out: dict[tuple, set[int]] = {}
    for shard in arr.addressable_shards:
        out.setdefault(_index_key(shard.index, arr.shape), set()).add(shard.device.id)
    return out


def _count_transfers(
    old_map: dict[tuple, set[int]],
    new_placements: list[tuple[tuple, int]],
    itemsize: int,
) -> tuple[int, int, int]:
    """(total, stayed, moved) bytes over new (index-key, device-id) pairs."""
    stayed = moved = total = 0
    for key, device_id in new_placements:
        nbytes = _key_nbytes(key, itemsize)
        total += nbytes
        if device_id in old_map.get(key, set()):
            stayed += nbytes
        else:
            moved += nbytes
    return total, stayed, moved


def transfer_stats(old_tree: Any, new_tree: Any) -> dict[str, int]:
    """Measure bytes that moved vs stayed local across a resharding.

    A shard "stays" when the new placement includes a device that already
    held identical index bounds before the reshard.

    Args:
        old_tree: pytree of live arrays before the reshard.
        new_tree: the same pytree after the reshard (matching structure).
    Returns:
        ``{"bytes_total", "bytes_stayed", "bytes_moved"}`` summed over
        all leaves (zeros for an empty tree).
    """
    stayed = moved = total = 0
    old_leaves = jax.tree.leaves(old_tree)
    new_leaves = jax.tree.leaves(new_tree)
    for old, new in zip(old_leaves, new_leaves):
        itemsize = np.dtype(old.dtype).itemsize
        placements = [
            (_index_key(shard.index, new.shape), shard.device.id)
            for shard in new.addressable_shards
        ]
        t, s, m = _count_transfers(_shard_index_map(old), placements, itemsize)
        total += t
        stayed += s
        moved += m
    return {"bytes_total": total, "bytes_stayed": stayed, "bytes_moved": moved}


def predicted_transfer_stats(
    tree: Any, old_shardings: Any, new_shardings: Any
) -> dict[str, int]:
    """Predict :func:`transfer_stats` without materializing any array.

    Uses ``Sharding.devices_indices_map`` on both sides, which is exactly
    the placement ``jax.device_put`` realizes — so for arrays actually
    placed with ``old_shardings``, the prediction equals the measured
    stats of a reshard onto ``new_shardings``, byte for byte.

    Args:
        tree: pytree of shape/dtype carriers (``jax.ShapeDtypeStruct`` or
            arrays; no data is read).
        old_shardings: pytree of ``Sharding`` matching ``tree`` (or a
            single sharding applied to all leaves).
        new_shardings: same, for the target placement.
    Returns:
        ``{"bytes_total", "bytes_stayed", "bytes_moved"}``.
    """
    leaves = jax.tree.leaves(tree)

    def _as_list(shardings, which):
        flat = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "devices_indices_map")
        )
        if len(flat) == 1 and len(leaves) > 1:
            return flat * len(leaves)
        if len(flat) != len(leaves):
            raise ValueError(
                f"{which} shardings have {len(flat)} leaves for a tree of "
                f"{len(leaves)} — bytes would be silently undercounted"
            )
        return flat

    stayed = moved = total = 0
    for leaf, old_s, new_s in zip(leaves, _as_list(old_shardings, "old"),
                                  _as_list(new_shardings, "new")):
        shape = tuple(leaf.shape)
        itemsize = np.dtype(leaf.dtype).itemsize
        old_map: dict[tuple, set[int]] = {}
        for dev, idx in old_s.devices_indices_map(shape).items():
            old_map.setdefault(_index_key(idx, shape), set()).add(dev.id)
        placements = [
            (_index_key(idx, shape), dev.id)
            for dev, idx in new_s.devices_indices_map(shape).items()
        ]
        t, s, m = _count_transfers(old_map, placements, itemsize)
        total += t
        stayed += s
        moved += m
    return {"bytes_total": total, "bytes_stayed": stayed, "bytes_moved": moved}


@dataclass
class PytreeBytesModel:
    """Exact stage-3 bytes model for a live model's parameter pytree.

    Callable as ``(ns_ranks, nt_ranks) -> bytes_moved``, the
    ``ReconfigEngine.bytes_model`` protocol: it resolves the model's
    parameter shardings on 1-D ``("data",)`` meshes of both rank counts
    (devices in pool order, matching
    :meth:`~repro.elastic.runtime.ElasticRuntime.mesh`) and predicts the
    reshard's measured bytes via :func:`predicted_transfer_stats`.

    Requires the host to expose at least ``max(ns, nt)`` devices; rank
    counts are device counts here (one rank per device).
    """

    model: Any                       # repro.models.Model
    devices: Optional[Sequence[Any]] = None   # defaults to jax.devices()
    mode: str = "train"
    _cache: dict = field(default_factory=dict, repr=False)

    def _shardings(self, k: int) -> dict:
        if k not in self._cache:
            from repro.parallel.sharding import (
                ShardingContext,
                param_sharding_abstract,
            )

            devs = list(self.devices if self.devices is not None else jax.devices())
            if k > len(devs):
                raise ValueError(
                    f"PytreeBytesModel needs {k} devices, host has {len(devs)}"
                )
            mesh = Mesh(np.asarray(devs[:k], dtype=object).reshape((k,)), ("data",))
            ctx = ShardingContext(mesh=mesh, mode=self.mode)
            shapes, specs = self._abstract()
            self._cache[k] = param_sharding_abstract(shapes, specs, ctx)
        return self._cache[k]

    def _abstract(self):
        if "abstract" not in self._cache:
            self._cache["abstract"] = self.model.abstract_params()
        return self._cache["abstract"]

    def __call__(self, ns: int, nt: int) -> int:
        return self.stats(ns, nt)["bytes_moved"]

    def total_bytes(self, ranks: int) -> int:
        """Full parameter-pytree bytes — the checkpoint snapshot size.

        Rank-count independent for a replicated-or-sharded pytree (the
        union of shards IS the pytree); the engine's
        :meth:`~repro.core.ReconfigEngine.checkpoint_bytes` calls this
        to size CHECKPOINT/RESTORE events.
        """
        shapes, _ = self._abstract()
        return int(sum(
            int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
            for s in jax.tree.leaves(shapes)
        ))

    def stats(self, ns: int, nt: int) -> dict:
        """Full per-link prediction ``{"bytes_total", "bytes_stayed",
        "bytes_moved"}`` for an ``ns -> nt`` resize — the engine consults
        this (in preference to ``__call__``) so stayed and moved bytes
        are charged against their own link bandwidths."""
        if ns == nt or ns <= 0 or nt <= 0:
            return {"bytes_total": 0, "bytes_stayed": 0, "bytes_moved": 0}
        shapes, _ = self._abstract()
        return predicted_transfer_stats(
            shapes, self._shardings(ns), self._shardings(nt)
        )
