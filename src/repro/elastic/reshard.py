"""Data redistribution: reshard live state onto a rebuilt mesh.

This is stage 3 of the paper's malleability pipeline.  The paper defers
transfer-minimizing redistribution to future work; we implement it: the
device order of the new mesh keeps surviving devices in their previous
relative positions (the Eq. 9 reorder guarantees a deterministic order,
and :func:`repro.elastic.runtime.ElasticRuntime` feeds survivors first),
so shards that already sit on a surviving device do not move.

``transfer_stats`` quantifies the win: bytes that stay local vs bytes
that cross devices, for any (old sharding -> new sharding) pair.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard_tree(tree: Any, mesh: Mesh, spec_tree: Any) -> Any:
    """Reshard every leaf of ``tree`` onto ``mesh`` with the given specs.

    ``spec_tree`` is either a single PartitionSpec applied to all leaves or
    a pytree of specs matching ``tree``'s structure.  Uses ``device_put``,
    which moves only the shards that change placement.
    """
    if isinstance(spec_tree, P) or spec_tree is None:
        specs = jax.tree.map(lambda _: spec_tree or P(), tree)
    else:
        specs = spec_tree
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )


def _shard_index_map(arr: Any) -> dict[tuple, set[int]]:
    """Map shard index-bounds -> device ids currently holding that shard."""
    out: dict[tuple, set[int]] = {}
    for shard in arr.addressable_shards:
        key = tuple(
            (s.start or 0, s.stop if s.stop is not None else dim)
            for s, dim in zip(shard.index, arr.shape)
        )
        out.setdefault(key, set()).add(shard.device.id)
    return out


def transfer_stats(old_tree: Any, new_tree: Any) -> dict[str, int]:
    """Bytes that moved vs stayed local across a resharding.

    A shard "stays" when the new placement includes a device that already
    held identical index bounds before the reshard.
    """
    stayed = moved = total = 0
    old_leaves = jax.tree.leaves(old_tree)
    new_leaves = jax.tree.leaves(new_tree)
    for old, new in zip(old_leaves, new_leaves):
        itemsize = np.dtype(old.dtype).itemsize
        old_map = _shard_index_map(old)
        for shard in new.addressable_shards:
            key = tuple(
                (s.start or 0, s.stop if s.stop is not None else dim)
                for s, dim in zip(shard.index, new.shape)
            )
            nbytes = int(np.prod([hi - lo for lo, hi in key]) * itemsize) if key else itemsize
            total += nbytes
            if shard.device.id in old_map.get(key, set()):
                stayed += nbytes
            else:
                moved += nbytes
    return {"bytes_total": total, "bytes_stayed": stayed, "bytes_moved": moved}
