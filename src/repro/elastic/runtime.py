"""ElasticRuntime: the paper's reconfiguration pipeline on live JAX state.

Maps the four malleability stages onto real device groups:

  1. feasibility        — the (simulated) RMS grants/reclaims nodes;
  2. process management — a parallel SpawnPlan brings NodeGroups up
                          (hypercube for homogeneous pools, diffusive for
                          heterogeneous), TS terminates whole groups;
  3. data redistribution— the caller reshards its pytrees onto the new
                          mesh (see :mod:`repro.elastic.reshard`);
  4. resume             — the caller re-jits its step for the new mesh.

Reconfiguration *cost* is charged by the calibrated simulator (this host
has one real device), so every record carries the estimated wall time a
real cluster would observe alongside the actual resharding stats.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh

from repro.core import (
    ClusterState,
    MalleabilityManager,
    Method,
    ShrinkKind,
    Strategy,
    apply_shrink,
    plan_shrink,
)
from repro.malleability import (
    MN5,
    CostModel,
    simulate_expansion,
    simulate_shrink,
)

from .node_group import DevicePool, NodeGroup


@dataclass(frozen=True)
class ReconfigRecord:
    kind: str                  # expand | shrink | fail | straggler
    mechanism: str             # strategy or TS/ZS/SS
    nodes_before: int
    nodes_after: int
    est_wall_s: float          # simulated reconfiguration cost
    downtime_s: float          # app-visible stall (Async overlaps spawn)
    steps: int = 0             # spawn rounds (expansions)
    groups: int = 0
    nodes_returned: tuple[int, ...] = ()
    nodes_pinned: tuple[int, ...] = ()


class ElasticRuntime:
    """Owns the NodeGroup registry and rebuilds meshes across resizes."""

    def __init__(
        self,
        pool: Optional[DevicePool] = None,
        method: Method = Method.MERGE,
        strategy: Strategy = Strategy.PARALLEL_HYPERCUBE,
        cost_model: CostModel = MN5,
        asynchronous: bool = False,
        initial_nodes: int = 1,
    ):
        self.pool = pool or DevicePool()
        self.cost_model = cost_model
        self.manager = MalleabilityManager(
            method=method, strategy=strategy, asynchronous=asynchronous
        )
        self.state: ClusterState = self.manager.state
        self.groups: dict[int, NodeGroup] = {}   # wid -> NodeGroup
        self.history: list[ReconfigRecord] = []
        # initial allocation: one world; if it spans several nodes it is the
        # paper's problematic multi-node initial MCW (handled by §4.6 logic).
        nodes, devs = [], []
        for _ in range(initial_nodes):
            node, d = self.pool.acquire_any()
            nodes.append(node)
            devs.append(d)
        w = self.state.add_world(nodes, [len(d) for d in devs], is_initial=True)
        self.groups[w.wid] = NodeGroup(gid=w.wid, node=nodes[0], devices=tuple(
            dev for group in devs for dev in group
        ))

    # ------------------------------------------------------------------ mesh --
    @property
    def n_nodes(self) -> int:
        return len(self.state.nodes_in_use())

    @property
    def devices(self) -> list:
        """All live devices in Eq. 9 order (node-contiguous, gid ascending)."""
        ordered = sorted(self.groups.values(), key=lambda g: (min(
            self.state.worlds[g.gid].nodes), g.gid))
        return [d for g in ordered for d in g.devices]

    def mesh(self, axes: tuple[str, ...] = ("data",), shape: Optional[tuple[int, ...]] = None) -> Mesh:
        devs = self.devices
        if shape is None:
            shape = (len(devs),)
        import numpy as np

        return Mesh(np.asarray(devs, dtype=object).reshape(shape), axes)

    # ---------------------------------------------------------------- expand --
    def expand(self, target_nodes: int) -> ReconfigRecord:
        """Grow the job to ``target_nodes`` NodeGroup-confined nodes."""
        before = self.n_nodes
        if target_nodes <= before:
            raise ValueError("expand() requires target_nodes > current nodes")
        cpn = self.pool.devices_per_node
        ns, nt = before * cpn, target_nodes * cpn
        if self.manager.strategy is Strategy.PARALLEL_DIFFUSIVE:
            plan = self.manager.plan_expand(ns, nt, [cpn] * target_nodes)
        else:
            plan = self.manager.plan_expand(ns, nt, cpn)
        spawn = plan.spawn
        assert spawn is not None
        sim = simulate_expansion(spawn, self.cost_model, self.manager.asynchronous)

        # Bring up one NodeGroup per spawned group (each node-confined).
        for g in spawn.groups:
            node, devs = self.pool.acquire_any()
            w = self.state.add_world([node], [len(devs)])
            self.groups[w.wid] = NodeGroup(gid=w.wid, node=node, devices=devs)
        self.state.expansions_done += 1

        rec = ReconfigRecord(
            kind="expand",
            mechanism=spawn.strategy.value,
            nodes_before=before,
            nodes_after=self.n_nodes,
            est_wall_s=sim.total,
            downtime_s=sim.downtime,
            steps=sim.steps,
            groups=sim.groups,
        )
        self.history.append(rec)
        return rec

    # ---------------------------------------------------------------- shrink --
    def shrink(self, n_nodes_to_release: int, kind: str = "shrink") -> ReconfigRecord:
        """TS-shrink: terminate the highest-node groups, return their devices."""
        before = self.n_nodes
        victims = sorted(self.state.nodes_in_use())[-n_nodes_to_release:]
        return self.shrink_nodes(victims, kind=kind)

    def shrink_nodes(self, victims: list[int], kind: str = "shrink") -> ReconfigRecord:
        before = self.n_nodes
        plan = plan_shrink(self.state, release_nodes=victims)
        doomed_sizes = [
            self.state.worlds[a.wid].size
            for a in plan.actions
            if a.wid is not None and a.wid in self.state.worlds
            and a.kind.value in ("terminate_world", "awaken_and_terminate")
        ]
        sim = simulate_shrink(
            plan.kind,
            self.cost_model,
            ns=sum(w.size for w in self.state.worlds.values()),
            nt=0,
            doomed_world_sizes=doomed_sizes or [1],
            nodes_returned=len(plan.nodes_returned),
            nodes_pinned=len(plan.nodes_pinned),
        )
        doomed_wids = [
            a.wid for a in plan.actions
            if a.wid is not None and a.kind.value in ("terminate_world", "awaken_and_terminate")
        ]
        doomed_nodes = {
            wid: self.state.worlds[wid].nodes
            for wid in doomed_wids
            if wid in self.state.worlds
        }
        apply_shrink(self.state, plan)
        for wid in doomed_wids:
            group = self.groups.pop(wid, None)
            if group is not None:
                for node in doomed_nodes.get(wid, (group.node,)):
                    self.pool.release(node)
        rec = ReconfigRecord(
            kind=kind,
            mechanism=plan.kind.value,
            nodes_before=before,
            nodes_after=self.n_nodes,
            est_wall_s=sim.total,
            downtime_s=sim.total,
            nodes_returned=plan.nodes_returned,
            nodes_pinned=plan.nodes_pinned,
        )
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------------ fault --
    def fail_node(self, node: int) -> ReconfigRecord:
        """Node failure == an RMS-forced TS shrink of that node's group.

        The paper's mechanism doubles as the recovery path: because every
        world is node-confined, losing a node loses exactly one group; the
        surviving groups keep a consistent state and the runtime simply
        reconfigures without it.
        """
        return self.shrink_nodes([node], kind="fail")

    def drop_straggler(self, node: int) -> ReconfigRecord:
        """Straggler mitigation: TS-shrink the slow group out of the job."""
        return self.shrink_nodes([node], kind="straggler")
