"""ElasticRuntime: the paper's reconfiguration pipeline on live JAX state.

A live :class:`~repro.core.engine.ExecutionBackend`: the
:class:`~repro.core.engine.ReconfigEngine` plans every resize through its
strategy registry and charges the event timeline; this backend applies
the same plan objects to real device groups:

  1. feasibility        — the (simulated) RMS grants/reclaims nodes;
  2. process management — a SpawnPlan brings NodeGroups up (hypercube for
                          homogeneous pools, diffusive for heterogeneous /
                          uneven-width pools), TS terminates whole groups;
  3. data redistribution— the caller reshards its pytrees onto the new
                          mesh (see :mod:`repro.elastic.reshard`);
  4. resume             — the caller re-jits its step for the new mesh.

Reconfiguration *cost* is read off the engine's timeline (this host has
one real device), so every record carries the estimated wall time a real
cluster would observe alongside the actual resharding stats — the same
timeline the simulator reports, by construction.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from jax.sharding import Mesh

from repro.core import (
    ClusterState,
    Method,
    ReconfigEngine,
    ReconfigPlan,
    Strategy,
    apply_shrink,
    strategy_key,
)
from repro.core.topology import split_bytes_by_class
from repro.malleability import MN5, CostModel

from .node_group import DevicePool, NodeGroup


@dataclass(frozen=True)
class ReconfigRecord:
    """One reconfiguration as observed by the live runtime.

    Every cost field is a read of the engine's charged timeline — the
    same timeline the simulator reports — so the two layers agree by
    construction.
    """

    kind: str                  # expand | shrink | fail | straggler
    #                          # | checkpoint | restart
    mechanism: str             # strategy or TS/ZS/SS (ckpt for checkpoints)
    nodes_before: int
    nodes_after: int
    est_wall_s: float          # timeline total (simulated reconfiguration cost)
    downtime_s: float          # timeline downtime (partial ASYNC overlap)
    steps: int = 0             # spawn rounds (expansions)
    groups: int = 0
    nodes_returned: tuple[int, ...] = ()
    nodes_pinned: tuple[int, ...] = ()
    bytes_moved: int = 0       # stage-3 cross-link bytes charged on the timeline
    queued_s: float = 0.0      # RMS arbitration wait charged (QUEUE span)
    bytes_stayed: int = 0      # stage-3 local-link bytes charged on the timeline
    bytes_cross_rack: int = 0  # rack-crossing portion of bytes_moved
    bytes_cross_pod: int = 0   # pod-crossing slice of bytes_cross_rack
    bytes_checkpointed: int = 0  # snapshot bytes streamed to the store
    bytes_restored: int = 0    # bytes read back from the store (RESTORE)
    restored_s: float = 0.0    # RESTORE span charged on the timeline

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class (sums to stayed + moved)."""
        return split_bytes_by_class(self.bytes_stayed, self.bytes_moved,
                                    self.bytes_cross_rack,
                                    self.bytes_cross_pod)


class ElasticRuntime:
    """Owns the NodeGroup registry and rebuilds meshes across resizes.

    Args:
        pool: device pool partitioned into nodes (defaults to all host
            devices, one per node).
        method / strategy / cost_model / asynchronous: engine knobs —
            only valid when no explicit ``engine`` is passed.
        initial_nodes: nodes acquired for the initial world.
        engine: a configured :class:`ReconfigEngine` (e.g. carrying a
            bytes model); mutually exclusive with the engine knobs.
    """

    def __init__(
        self,
        pool: Optional[DevicePool] = None,
        method: Method = Method.MERGE,
        strategy: Strategy = Strategy.PARALLEL_HYPERCUBE,
        cost_model: CostModel = MN5,
        asynchronous: bool = False,
        initial_nodes: int = 1,
        engine: Optional[ReconfigEngine] = None,
    ):
        self.pool = pool or DevicePool()
        if engine is not None:
            overridden = [
                name for name, value, default in (
                    ("method", method, Method.MERGE),
                    ("strategy", strategy, Strategy.PARALLEL_HYPERCUBE),
                    ("cost_model", cost_model, MN5),
                    ("asynchronous", asynchronous, False),
                )
                if value is not default and value != default
            ]
            if overridden:
                raise ValueError(
                    f"pass {overridden} on the engine, not the runtime: an "
                    "explicit `engine` already carries those knobs and the "
                    "runtime would silently ignore them"
                )
        if (engine is not None and engine.topology is not None
                and self.pool.topology is not None
                and engine.topology != self.pool.topology):
            raise ValueError(
                "engine and pool carry different topologies; placement "
                "and distance-class pricing would silently disagree"
            )
        if (engine is not None and engine.topology is not None
                and engine.topology.n_nodes < self.pool.n_nodes):
            raise ValueError(
                f"engine topology covers {engine.topology.n_nodes} nodes "
                f"but the pool partitions into {self.pool.n_nodes}; "
                "placement and distance-class pricing would fall off the "
                "rack tree mid-reconfiguration"
            )
        if (engine is not None and engine.topology is None
                and self.pool.topology is not None):
            # Adopt the pool's layout so an engine built without one
            # still prices distance classes over the real rack tree —
            # on a runtime-local copy, never by mutating the caller's
            # engine (which may outlive this pool).
            engine = dataclasses.replace(engine, topology=self.pool.topology)
        self.engine = engine or ReconfigEngine(
            method=method,
            strategy=strategy,
            asynchronous=asynchronous,
            cost_model=cost_model,
            topology=self.pool.topology,
        )
        self.cost_model = self.engine.cost_model
        self.state = ClusterState()
        self.groups: dict[int, NodeGroup] = {}   # wid -> NodeGroup
        self.history: list[ReconfigRecord] = []
        # initial allocation: one world; if it spans several nodes it is the
        # paper's problematic multi-node initial MCW (handled by §4.6 logic).
        nodes, devs = [], []
        for _ in range(initial_nodes):
            node, d = self.pool.acquire_any()
            nodes.append(node)
            devs.append(d)
        w = self.state.add_world(nodes, [len(d) for d in devs], is_initial=True)
        self.groups[w.wid] = NodeGroup(gid=w.wid, node=nodes[0], devices=tuple(
            dev for group in devs for dev in group
        ))

    # ------------------------------------------------------------------ mesh --
    @property
    def n_nodes(self) -> int:
        """Nodes currently in use by live worlds."""
        return len(self.state.nodes_in_use())

    @property
    def devices(self) -> list:
        """All live devices in Eq. 9 order (node-contiguous, gid ascending)."""
        ordered = sorted(self.groups.values(), key=lambda g: (min(
            self.state.worlds[g.gid].nodes), g.gid))
        return [d for g in ordered for d in g.devices]

    def mesh(self, axes: tuple[str, ...] = ("data",), shape: Optional[tuple[int, ...]] = None) -> Mesh:
        """Build a Mesh over the live devices (Eq. 9 order).

        Args:
            axes: mesh axis names (default the 1-D ``("data",)`` mesh).
            shape: optional device-grid shape; defaults to 1-D over all
                live devices.
        Returns:
            A ``jax.sharding.Mesh`` suitable for resharding state onto.
        """
        devs = self.devices
        if shape is None:
            shape = (len(devs),)
        import numpy as np

        return Mesh(np.asarray(devs, dtype=object).reshape(shape), axes)

    # -------------------------------------------------- backend protocol --
    def apply_expand(self, plan: ReconfigPlan) -> None:
        """Bring up NodeGroups for the spawned groups (node-confined).

        Parallel strategies spawn node-confined groups 1:1; a classic
        strategy's single multi-node group is split one NodeGroup per
        node (the substrate's releasable unit), mirroring the simulator
        backend — the charged timeline still prices the plan's own spawn
        structure.  A plan carrying explicit ``node_ids`` (placement is
        the strategy's decision) has its new nodes acquired in exactly
        that order; without them the historical greedy lowest-id order
        applies.
        """
        assert plan.spawn is not None
        in_use = self.state.nodes_in_use()
        queue = [n for n in plan.node_ids if n not in in_use]
        for g in plan.spawn.groups:
            remaining = g.size
            while remaining > 0:
                if queue:
                    node = queue.pop(0)
                    devs = self.pool.acquire(node)
                else:
                    node, devs = self.pool.acquire_any()
                take = min(len(devs), remaining)
                w = self.state.add_world([node], [take])
                self.groups[w.wid] = NodeGroup(gid=w.wid, node=node, devices=devs)
                remaining -= take
        self.state.expansions_done += 1

    def apply_shrink(self, plan: ReconfigPlan) -> None:
        """Terminate doomed worlds, return their devices to the pool."""
        assert plan.shrink is not None
        doomed_wids = plan.shrink.doomed_wids()
        doomed_nodes = {
            wid: self.state.worlds[wid].nodes
            for wid in doomed_wids
            if wid in self.state.worlds
        }
        apply_shrink(self.state, plan.shrink)
        for wid in doomed_wids:
            group = self.groups.pop(wid, None)
            if group is not None:
                for node in doomed_nodes.get(wid, (group.node,)):
                    self.pool.release(node)

    # ---------------------------------------------------------------- expand --
    def ranks_in_use(self) -> int:
        """Live ranks (== devices) across all worlds."""
        return sum(w.size for w in self.state.worlds.values())

    def expand(self, target_nodes: int, *,
               queue_delay_s: float = 0.0) -> ReconfigRecord:
        """Grow the job to ``target_nodes`` NodeGroup-confined nodes.

        Plans through the engine's strategy registry against the pool's
        actual per-node width vector (uniform or uneven), applies the
        plan to the device pool, and charges the event timeline
        (including the stage-3 bytes from the engine's bytes model, if
        configured).  New nodes are taken lowest-id-first, the same
        greedy order the simulator backend uses, so both executors see
        identical A vectors and charge identical timelines.

        Args:
            target_nodes: new total node count (must exceed the current).
            queue_delay_s: RMS arbitration wait (the grant was queued
                behind an in-flight reconfiguration); charged as a
                leading QUEUE timeline event.
        Returns:
            The appended :class:`ReconfigRecord`.
        Raises:
            ValueError: if ``target_nodes`` does not grow the job.
            RuntimeError: if the pool has too few free nodes.
        """
        before = self.n_nodes
        if target_nodes <= before:
            raise ValueError("expand() requires target_nodes > current nodes")
        need = target_nodes - before
        free = self.pool.free
        if need > len(free):
            raise RuntimeError(
                f"device pool exhausted: expand to {target_nodes} nodes "
                f"needs {need} free nodes, pool has {len(free)}"
            )
        used_sorted = sorted(self.state.nodes_in_use())
        # Placement is the strategy's decision: greedy lowest-id for the
        # classics (the historical order), rack-local-first for
        # topology-aware strategies on a topologized engine.
        new_nodes = self.engine.select_expansion_nodes(used_sorted, free, need)
        nodes_all = used_sorted + new_nodes
        ns = self.ranks_in_use()
        nt = ns + sum(self.pool.width(n) for n in new_nodes)
        cores = self._cores_arg(nodes_all)
        plan = self.engine.plan_expand(ns, nt, cores,
                                       queue_delay_s=queue_delay_s,
                                       node_ids=nodes_all)
        outcome = self.engine.execute(plan, backend=self)

        spawn = plan.spawn
        assert spawn is not None
        rec = ReconfigRecord(
            kind="expand",
            mechanism=strategy_key(spawn.strategy),
            nodes_before=before,
            nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s,
            downtime_s=outcome.downtime_s,
            steps=spawn.steps,
            groups=len(spawn.groups),
            bytes_moved=outcome.bytes_moved,
            queued_s=outcome.queued_s,
            bytes_stayed=outcome.bytes_stayed,
            bytes_cross_rack=outcome.bytes_cross_rack,
            bytes_cross_pod=outcome.bytes_cross_pod,
            bytes_checkpointed=outcome.bytes_checkpointed,
            bytes_restored=outcome.bytes_restored,
            restored_s=outcome.restored_s,
        )
        self.history.append(rec)
        return rec

    def _cores_arg(self, nodes: list[int]):
        """Planner allocation argument: the pool's A vector over
        ``nodes`` (node-id order), normalized by the shared
        :meth:`ReconfigEngine.allocation_arg` rule both executors use."""
        return self.engine.allocation_arg(
            [self.pool.width(n) for n in nodes])

    # ---------------------------------------------------------------- shrink --
    def shrink(self, n_nodes_to_release: int, kind: str = "shrink") -> ReconfigRecord:
        """TS-shrink ``n_nodes_to_release`` nodes chosen by the strategy.

        Victim choice is the engine's placement decision: highest-id
        nodes for the classics (the historical order), whole racks first
        for topology-aware strategies on a topologized engine.

        Args:
            n_nodes_to_release: how many nodes to return to the pool.
            kind: record label (``shrink`` / ``fail`` / ``straggler``).
        Returns:
            The appended :class:`ReconfigRecord`.
        """
        victims = self.engine.select_release_nodes(
            sorted(self.state.nodes_in_use()), n_nodes_to_release)
        return self.shrink_nodes(victims, kind=kind)

    def shrink_nodes(self, victims: list[int], kind: str = "shrink", *,
                     queue_delay_s: float = 0.0) -> ReconfigRecord:
        """TS-shrink specific node ids out of the job (see :meth:`shrink`).

        A ``kind="fail"`` shrink on an engine with ``restore_on_fail``
        additionally charges recovery of the lost shards from the last
        checkpoint (a trailing RESTORE event).
        """
        before = self.n_nodes
        plan = self.engine.plan_shrink(self.state, release_nodes=victims,
                                       queue_delay_s=queue_delay_s,
                                       failed=(kind == "fail"))
        outcome = self.engine.execute(plan, backend=self)
        assert plan.shrink is not None
        rec = ReconfigRecord(
            kind=kind,
            mechanism=plan.shrink.kind.value,
            nodes_before=before,
            nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s,
            downtime_s=outcome.downtime_s,
            nodes_returned=plan.shrink.nodes_returned,
            nodes_pinned=plan.shrink.nodes_pinned,
            bytes_moved=outcome.bytes_moved,
            queued_s=outcome.queued_s,
            bytes_stayed=outcome.bytes_stayed,
            bytes_cross_rack=outcome.bytes_cross_rack,
            bytes_cross_pod=outcome.bytes_cross_pod,
            bytes_checkpointed=outcome.bytes_checkpointed,
            bytes_restored=outcome.bytes_restored,
            restored_s=outcome.restored_s,
        )
        self.history.append(rec)
        return rec

    # ---------------------------------------------------------- fault tolerance --
    def checkpoint(self, *, queue_delay_s: float = 0.0) -> ReconfigRecord:
        """Charge one full-state checkpoint (no allocation change).

        The snapshot size comes from the engine's bytes model
        (:meth:`~repro.core.ReconfigEngine.checkpoint_bytes`); callers
        that actually persist state (the trainer's
        :class:`~repro.checkpoint.CheckpointManager`) do so alongside
        this record.
        """
        before = self.n_nodes
        plan = self.engine.plan_checkpoint(self.ranks_in_use(),
                                           queue_delay_s=queue_delay_s)
        outcome = self.engine.execute(plan, backend=self)
        rec = ReconfigRecord(
            kind="checkpoint",
            mechanism="ckpt",
            nodes_before=before,
            nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s,
            downtime_s=outcome.downtime_s,
            queued_s=outcome.queued_s,
            bytes_checkpointed=outcome.bytes_checkpointed,
        )
        self.history.append(rec)
        return rec

    def apply_restart(self, plan: ReconfigPlan) -> None:
        """Full stop + respawn: every world exits, the new one comes up.

        All nodes return to the pool first; the replacement world is
        then acquired in ``plan.node_ids`` order, one node-confined
        group per node (the same shape an initial allocation has, so
        subsequent TS shrinks work unchanged).
        """
        for wid in list(self.state.worlds):
            w = self.state.worlds.pop(wid)
            self.groups.pop(wid, None)
            for node in w.nodes:
                self.pool.release(node)
        for node in plan.node_ids:
            devs = self.pool.acquire(node)
            w = self.state.add_world([node], [len(devs)])
            self.groups[w.wid] = NodeGroup(gid=w.wid, node=node, devices=devs)

    def restart(self, target_nodes: int, *,
                queue_delay_s: float = 0.0) -> ReconfigRecord:
        """Full-stop checkpoint/restart to ``target_nodes`` nodes.

        The rigid baseline head-to-head against malleable resizing:
        checkpoint the whole state, stop every world, respawn at the
        target size (SS), restore from the store.  The new allocation
        takes the lowest-id ``target_nodes`` nodes of the whole pool
        (everything is momentarily free) — deterministic in both
        executors.
        """
        before = self.n_nodes
        if target_nodes <= 0:
            raise ValueError("restart() requires target_nodes >= 1")
        candidates = sorted(set(self.state.nodes_in_use()) | set(self.pool.free))
        if target_nodes > len(candidates):
            raise RuntimeError(
                f"device pool exhausted: restart to {target_nodes} nodes "
                f"exceeds the {len(candidates)} nodes available"
            )
        new_nodes = candidates[:target_nodes]
        ns = self.ranks_in_use()
        nt = sum(self.pool.width(n) for n in new_nodes)
        plan = self.engine.plan_restart(ns, nt, queue_delay_s=queue_delay_s,
                                        node_ids=new_nodes)
        outcome = self.engine.execute(plan, backend=self)
        rec = ReconfigRecord(
            kind="restart",
            mechanism="ss",
            nodes_before=before,
            nodes_after=self.n_nodes,
            est_wall_s=outcome.total_s,
            downtime_s=outcome.downtime_s,
            queued_s=outcome.queued_s,
            bytes_checkpointed=outcome.bytes_checkpointed,
            bytes_restored=outcome.bytes_restored,
            restored_s=outcome.restored_s,
        )
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------------ fault --
    def fail_node(self, node: int, *, queue_delay_s: float = 0.0) -> ReconfigRecord:
        """Node failure == an RMS-forced TS shrink of that node's group.

        The paper's mechanism doubles as the recovery path: because every
        world is node-confined, losing a node loses exactly one group; the
        surviving groups keep a consistent state and the runtime simply
        reconfigures without it.
        """
        return self.shrink_nodes([node], kind="fail", queue_delay_s=queue_delay_s)

    def drop_straggler(self, node: int, *,
                       queue_delay_s: float = 0.0) -> ReconfigRecord:
        """Straggler mitigation: TS-shrink the slow group out of the job."""
        return self.shrink_nodes([node], kind="straggler",
                                 queue_delay_s=queue_delay_s)
