"""NodeGroups: the releasable allocation unit of the elastic runtime.

A NodeGroup is the JAX-side analogue of the paper's node-confined MCW —
a set of devices that is acquired and released *as a unit*, which is
exactly the property TS shrinkage needs.  Nodes need not be the same
width: the pool accepts an explicit per-node width vector (the paper's
§5.3 NASP testbed alternates 20- and 32-core nodes), and because worlds
stay node-confined, a shrink still returns *complete* nodes to the RMS
whatever their width.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax

from repro.core.topology import Topology


@dataclass(frozen=True)
class NodeGroup:
    """One node-confined worker group (the paper's per-node MCW)."""

    gid: int                 # group id (stable across its lifetime)
    node: int                # node index in the cluster
    devices: tuple[Any, ...]  # jax devices owned by this group

    @property
    def size(self) -> int:
        return len(self.devices)


class DevicePool:
    """Partition of the host's devices into "nodes", uniform or uneven.

    The pool plays the RMS's role of owning idle nodes: `acquire` hands a
    node's devices to a new group, `release` (the TS path) returns them.

    Args:
        devices: devices to partition (defaults to all host devices).
        devices_per_node: uniform node width; node ``i`` owns devices
            ``[i*w, (i+1)*w)`` (leftover devices are ignored).
        node_widths: explicit per-node width vector (the heterogeneous
            A vector, e.g. ``(20, 32, 20, 32)``); node ``i`` owns the
            next ``node_widths[i]`` devices in pool order.  Mutually
            exclusive with a non-default ``devices_per_node``; raises
            if the vector needs more devices than the pool holds.
        topology: optional :class:`~repro.core.topology.Topology`
            (node -> rack -> pod tree) over this pool's node ids; must
            cover every node exactly.  Placement-aware engines read it
            via :meth:`rack_of`; ``None`` behaves as a single rack.
    """

    def __init__(
        self,
        devices: Sequence[Any] | None = None,
        devices_per_node: int = 1,
        node_widths: Optional[Sequence[int]] = None,
        topology: Optional[Topology] = None,
    ):
        devices = list(devices if devices is not None else jax.devices())
        if node_widths is not None:
            if devices_per_node != 1:
                raise ValueError(
                    "pass either devices_per_node or node_widths, not both"
                )
            widths = [int(w) for w in node_widths]
            if not widths or any(w <= 0 for w in widths):
                raise ValueError(
                    f"node_widths must be a non-empty sequence of positive "
                    f"ints, got {tuple(node_widths)}"
                )
            if sum(widths) > len(devices):
                raise ValueError(
                    f"node_widths {tuple(widths)} needs {sum(widths)} "
                    f"devices, pool only has {len(devices)}"
                )
        else:
            if devices_per_node <= 0:
                raise ValueError("devices_per_node must be positive")
            widths = [devices_per_node] * (len(devices) // devices_per_node)
        self.node_widths: tuple[int, ...] = tuple(widths)
        if topology is not None and topology.n_nodes != len(widths):
            raise ValueError(
                f"topology covers {topology.n_nodes} nodes but the pool "
                f"partitions into {len(widths)}; rack_sizes must match "
                "the node count exactly"
            )
        self.topology: Optional[Topology] = topology
        self.nodes: dict[int, tuple[Any, ...]] = {}
        offset = 0
        for i, w in enumerate(widths):
            self.nodes[i] = tuple(devices[offset:offset + w])
            offset += w
        self.free: set[int] = set(self.nodes)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def uniform(self) -> bool:
        """True when every node has the same width (the MN5 case)."""
        return len(set(self.node_widths)) <= 1

    @property
    def devices_per_node(self) -> int:
        """Uniform node width; raises on an uneven pool (use ``width``)."""
        widths = set(self.node_widths)
        if len(widths) > 1:
            raise ValueError(
                f"pool is uneven ({self.node_widths}); devices_per_node is "
                "undefined — use width(node) / node_widths instead"
            )
        return widths.pop() if widths else 1

    def width(self, node: int) -> int:
        """Devices owned by ``node`` (its entry in the A vector)."""
        return len(self.nodes[node])

    def rack_of(self, node: int) -> int:
        """Rack owning ``node`` (0 for the whole pool without a topology)."""
        if node not in self.nodes:
            raise KeyError(node)
        return 0 if self.topology is None else self.topology.rack_of(node)

    def total_devices(self) -> int:
        return sum(self.node_widths)

    def acquire(self, node: int) -> tuple[Any, ...]:
        if node not in self.free:
            raise RuntimeError(f"node {node} is not free")
        self.free.discard(node)
        return self.nodes[node]

    def acquire_any(self) -> tuple[int, tuple[Any, ...]]:
        if not self.free:
            raise RuntimeError("device pool exhausted")
        node = min(self.free)
        return node, self.acquire(node)

    def release(self, node: int) -> None:
        if node not in self.nodes:
            raise KeyError(node)
        self.free.add(node)
