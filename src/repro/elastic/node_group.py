"""NodeGroups: the releasable allocation unit of the elastic runtime.

A NodeGroup is the JAX-side analogue of the paper's node-confined MCW —
a set of devices that is acquired and released *as a unit*, which is
exactly the property TS shrinkage needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import jax


@dataclass(frozen=True)
class NodeGroup:
    """One node-confined worker group (the paper's per-node MCW)."""

    gid: int                 # group id (stable across its lifetime)
    node: int                # node index in the cluster
    devices: tuple[Any, ...]  # jax devices owned by this group

    @property
    def size(self) -> int:
        return len(self.devices)


class DevicePool:
    """Partition of the host's devices into fixed-size "nodes".

    The pool plays the RMS's role of owning idle nodes: `acquire` hands a
    node's devices to a new group, `release` (the TS path) returns them.
    """

    def __init__(self, devices: Sequence[Any] | None = None, devices_per_node: int = 1):
        devices = list(devices if devices is not None else jax.devices())
        if devices_per_node <= 0:
            raise ValueError("devices_per_node must be positive")
        self.devices_per_node = devices_per_node
        self.nodes: dict[int, tuple[Any, ...]] = {}
        for i in range(len(devices) // devices_per_node):
            self.nodes[i] = tuple(devices[i * devices_per_node:(i + 1) * devices_per_node])
        self.free: set[int] = set(self.nodes)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def acquire(self, node: int) -> tuple[Any, ...]:
        if node not in self.free:
            raise RuntimeError(f"node {node} is not free")
        self.free.discard(node)
        return self.nodes[node]

    def acquire_any(self) -> tuple[int, tuple[Any, ...]]:
        if not self.free:
            raise RuntimeError("device pool exhausted")
        node = min(self.free)
        return node, self.acquire(node)

    def release(self, node: int) -> None:
        if node not in self.nodes:
            raise KeyError(node)
        self.free.add(node)
