"""repro — reproduction of "Parallel Spawning Strategies for
Dynamic-Aware MPI Applications", grown into an elastic scheduling,
training, and serving stack.

The stable public surface lives in :mod:`repro.api` (see
``docs/api.md``); this package re-exports it lazily, so both spellings
work and ``import repro`` stays free of heavyweight imports:

    from repro.api import ReconfigEngine      # the documented path
    import repro; repro.ReconfigEngine        # same object

Subpackage imports (``repro.core``, ``repro.malleability``, ...) are
untouched — internal code keeps importing the implementation modules
directly.
"""
from __future__ import annotations

from importlib import import_module


def __getattr__(name: str):
    # import_module, NOT ``from repro import api``: a fromlist import
    # resolves "api" through this very __getattr__ and recurses.
    api = import_module("repro.api")
    if name == "api":
        return api
    if name in api.__all__:
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list[str]:
    api = import_module("repro.api")
    return sorted(set(globals()) | set(api.__all__) | {"api"})
