"""Chunked mLSTM Pallas kernel (TPU target, xLSTM arXiv:2405.04517).

Grid (B, H, n_chunks), chunk innermost; the matrix memory S (D, D), the
normalizer n (D,) and the stabilizer m (scalar) persist in VMEM scratch
across the sequential chunk dimension.  All gating math is fp32.

Layouts (pre-transposed by ops.py):
  q/k/v (B, H, nc, Q, D)   ig/fg (B, H, nc, Q)   ->  h (B, H, nc, Q, D)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref, h_ref,
                  s_ref, n_ref, m_ref, *, chunk: int, head_dim: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    Q, D = chunk, head_dim
    q = q_ref[0, 0, 0].astype(jnp.float32) / math.sqrt(D)   # (Q, D)
    k = k_ref[0, 0, 0].astype(jnp.float32)
    v = v_ref[0, 0, 0].astype(jnp.float32)
    ig = ig_ref[0, 0, 0].astype(jnp.float32)                # (Q,)
    logf = jax.nn.log_sigmoid(fg_ref[0, 0, 0].astype(jnp.float32))

    b = jnp.cumsum(logf)                                    # (Q,)
    total = b[-1]
    m_p = m_ref[0, 0]

    # intra log-weights: l_ij = b_i - b_j + ig_j  (j <= i)
    diff = b[:, None] - b[None, :] + ig[None, :]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        <= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    )
    diff = jnp.where(mask, diff, NEG)
    m_intra = jnp.max(diff, axis=1)                         # (Q,)

    # per-position stabilizer
    m_i = jnp.maximum(m_p + b, m_intra)                     # (Q,)
    inter_scale = jnp.exp(m_p + b - m_i)
    inter_scale = jnp.where(m_p <= NEG, 0.0, inter_scale)

    num = jax.lax.dot_general(
        q, s_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inter_scale[:, None]
    den = (q @ n_ref[...].reshape(D, 1))[:, 0] * inter_scale

    qk = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                       # (Q, Q)
    wts = jnp.exp(diff - m_i[:, None])
    wts = jnp.where(mask, wts, 0.0)
    num += jax.lax.dot_general(
        qk * wts, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    den += jnp.sum(qk * wts, axis=1)

    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[:, None]
    h_ref[0, 0, 0] = h.astype(h_ref.dtype)

    # state update (stabilized)
    w = total - b + ig                                      # (Q,)
    m_chunk = jnp.max(w)
    m_new = jnp.maximum(m_p + total, m_chunk)
    scale_old = jnp.where(m_p <= NEG, 0.0, jnp.exp(m_p + total - m_new))
    cw = jnp.exp(w - m_new)                                 # (Q,)
    s_ref[...] = s_ref[...] * scale_old + jax.lax.dot_general(
        k * cw[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_ref[...] = n_ref[...] * scale_old + jnp.sum(k * cw[:, None], axis=0)
    m_ref[0, 0] = m_new


def mlstm_scan_pallas(
    q: jax.Array,        # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,   # (B, S, H)
    f_gate: jax.Array,   # (B, S, H)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    assert S % chunk == 0
    nc = S // chunk
    Q = chunk

    def tr(a):
        return jnp.moveaxis(a, 2, 1).reshape(B, H, nc, Q, *a.shape[3:])

    qt, kt, vt = tr(q), tr(k), tr(v)
    igt = jnp.moveaxis(i_gate, 2, 1).reshape(B, H, nc, Q)
    fgt = jnp.moveaxis(f_gate, 2, 1).reshape(B, H, nc, Q)

    kernel = functools.partial(_mlstm_kernel, chunk=Q, head_dim=D)
    h = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h_, c: (b, h_, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h_, c: (b, h_, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, D), lambda b, h_, c: (b, h_, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h_, c: (b, h_, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h_, c: (b, h_, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, D), lambda b, h_, c: (b, h_, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, igt, fgt)
    return jnp.moveaxis(h.reshape(B, H, S, D), 1, 2)
