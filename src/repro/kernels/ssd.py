"""Mamba2 SSD chunked-scan Pallas kernel (TPU target).

Grid (B, H, n_chunks): the chunk dimension is innermost and TPU grids are
sequential, so the (N, P) recurrent state lives in VMEM scratch across
chunk steps — the HBM<->VMEM traffic per chunk is exactly one (Q, P) x
tile, one (Q, N) B/C tile pair and the (Q, P) output tile, which is what
makes the chunked formulation memory-optimal on TPU.

Layouts (pre-transposed by ops.py):
  x  (B, H, nc, Q, P)   dt (B, H, nc, Q)
  Bm (B, nc, Q, N)      Cm (B, nc, Q, N)     A (H,)
  -> y (B, H, nc, Q, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    Q = chunk
    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0].astype(jnp.float32)              # ()
    Bm = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)          # (Q, N)

    dA = dt * A                                   # (Q,) negative
    cum = jnp.cumsum(dA)                          # (Q,)
    total = cum[-1]

    # intra-chunk: w_ij = (C_i . B_j) exp(cum_i - cum_j) dt_j  (j <= i)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (Q, Q)
    diff = cum[:, None] - cum[None, :]
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        <= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    )
    w = jnp.where(mask, cb * jnp.exp(diff) * dt[None, :], 0.0)
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: C_i . S_prev, decayed into the chunk
    y += jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[:, None]

    # state update: S = exp(total) S + sum_j exp(total - cum_j) dt_j B_j x_j^T
    rem = jnp.exp(total - cum) * dt               # (Q,)
    state_ref[...] = state_ref[...] * jnp.exp(total) + jax.lax.dot_general(
        Bm * rem[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H)  (positive, post-softplus)
    A: jax.Array,        # (H,)       (negative)
    Bmat: jax.Array,     # (B, S, N)
    Cmat: jax.Array,     # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    Q = chunk

    xt = jnp.moveaxis(x, 2, 1).reshape(B, H, nc, Q, P)
    dtt = jnp.moveaxis(dt, 2, 1).reshape(B, H, nc, Q)
    Bq = Bmat.reshape(B, nc, Q, N)
    Cq = Cmat.reshape(B, nc, Q, N)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, Bq, Cq)
    return jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
