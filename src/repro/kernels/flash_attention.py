"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Canonical TPU pattern: the grid's innermost dimension walks KV blocks
*sequentially* (TPU grids are sequential), carrying the online-softmax
state (m, l, acc) in VMEM scratch; the output block is written once, at
the last KV step.  Q/K/V blocks are staged HBM->VMEM by BlockSpecs with
MXU-aligned tiles.

Features: causal masking, GQA (KV-head indexed as q_head // group via the
BlockSpec index_map — no KV repetition in HBM), sliding window, logit
soft-capping (gemma2).

Layouts: q (B, H, Sq, D); k/v (B, KV, Sk, D); out (B, H, Sq, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,        # VMEM blocks
    o_ref,                      # output block
    m_ref, l_ref, acc_ref,      # scratch: (BQ, 1), (BQ, 1), (BQ, D)
    *,
    n_kv_blocks: int,
    block_q: int,
    block_k: int,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (BK, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (BQ, BK)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = q_idx * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Guard fully-masked rows (m == -inf): exp(NEG_INF - NEG_INF) would be 1.
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, alpha)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,               # (B, H, Sq, D)
    k: jax.Array,               # (B, KV, Sk, D)
    v: jax.Array,               # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, KV, Sk, _ = k.shape
    assert H % KV == 0
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq = Sq // block_q
    nk = Sk // block_k
    scale = 1.0 / math.sqrt(D)

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _attn_kernel,
        n_kv_blocks=nk,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            # (m, l, acc) persist across the sequential kv grid dimension
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
