"""Pallas TPU kernels for the substrate's compute hot spots.

The paper's contribution is control-plane (process management), so these
kernels serve the model substrate: flash attention (GQA/window/softcap),
the Mamba2 SSD chunked scan, and the chunked mLSTM recurrence.  Each
kernel module ships ``<name>.py`` (pl.pallas_call + BlockSpec tiling),
an ``ops.py`` jit'd wrapper, and a ``ref.py`` pure-jnp oracle, validated
in interpret mode on CPU.
"""
from .ops import flash_attention, mlstm_scan, ssd_scan

__all__ = ["flash_attention", "mlstm_scan", "ssd_scan"]
