"""Pure-jnp oracles for every kernel (the ground truth in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0
):
    """q (B,H,Sq,D); k/v (B,KV,Sk,D); returns (B,H,Sq,D).  fp32 math."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def ssd_ref(x, dt, A, Bmat, Cmat):
    """Sequential SSD recurrence (the definitional oracle).

    x (B,S,H,P); dt (B,S,H); A (H,); Bmat/Cmat (B,S,N).
    Returns y (B,S,H,P), final state (B,H,N,P)."""
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    f32 = jnp.float32

    def step(state, t):
        xt, dtt, bt, ct = t
        decay = jnp.exp(dtt.astype(f32) * A.astype(f32))            # (B,H)
        outer = jnp.einsum("bn,bhp->bhnp", bt.astype(f32), xt.astype(f32))
        state = state * decay[:, :, None, None] + dtt.astype(f32)[:, :, None, None] * outer
        y = jnp.einsum("bn,bhnp->bhp", ct.astype(f32), state)
        return state, y

    init = jnp.zeros((B, H, N, P), f32)
    final, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0)),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def mlstm_ref(q, k, v, i_gate, f_gate):
    """Sequential stabilized mLSTM (definitional oracle).

    q/k/v (B,S,H,D); gates (B,S,H).  Returns h (B,S,H,D)."""
    B, S, H, D = q.shape
    f32 = jnp.float32

    def step(carry, t):
        S_p, n_p, m_p = carry
        qt, kt, vt, it, ft = t
        qt = qt.astype(f32) / math.sqrt(D)
        logf = jax.nn.log_sigmoid(ft.astype(f32))
        m_new = jnp.maximum(logf + m_p, it.astype(f32))
        scale_old = jnp.exp(logf + m_p - m_new)
        wt = jnp.exp(it.astype(f32) - m_new)
        S_new = S_p * scale_old[:, :, None, None] + wt[:, :, None, None] * jnp.einsum(
            "bhk,bhv->bhkv", kt.astype(f32), vt.astype(f32)
        )
        n_new = n_p * scale_old[:, :, None] + wt[:, :, None] * kt.astype(f32)
        num = jnp.einsum("bhk,bhkv->bhv", qt, S_new)
        den = jnp.einsum("bhk,bhk->bh", qt, n_new)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
        return (S_new, n_new, m_new), h

    init = (
        jnp.zeros((B, H, D, D), f32),
        jnp.zeros((B, H, D), f32),
        jnp.full((B, H), -jnp.inf, f32),
    )
    _, hs = jax.lax.scan(
        step, init,
        tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_gate, f_gate)),
    )
    return jnp.moveaxis(hs, 0, 1).astype(q.dtype)
