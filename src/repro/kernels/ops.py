"""Jit'd public wrappers for the Pallas kernels.

On TPU these call the pallas kernels directly; on CPU (this container)
``interpret=True`` executes the kernel bodies in Python for correctness
validation against :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_pallas
from .mlstm import mlstm_scan_pallas
from .ssd import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128, interpret=None):
    """Flash attention.  q (B,H,Sq,D); k/v (B,KV,Sk,D) -> (B,H,Sq,D)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interp,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk=128, interpret=None):
    """Mamba2 SSD.  x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,N)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return ssd_scan_pallas(x, dt, A, Bmat, Cmat, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(q, k, v, i_gate, f_gate, *, chunk=128, interpret=None):
    """Chunked mLSTM.  q/k/v (B,S,H,D), gates (B,S,H)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return mlstm_scan_pallas(q, k, v, i_gate, f_gate, chunk=chunk, interpret=interp)
