"""repro.api — THE stable, documented import surface.

Seven PRs of organic growth scattered entry points across subpackages
(``repro.core``, ``repro.malleability``, ``repro.elastic.rms`` shims,
``repro.serving``).  This module is the one import path user code —
``examples/``, ``benchmarks/``, and tests — programs against:

* everything in ``__all__`` is covered by the deprecation policy in
  ``docs/api.md``: removing or renaming a name requires a shim for one
  release, and ``scripts/check_api.py`` gates CI on the committed
  ``API_SNAPSHOT.txt``;
* device-free layers (the engine/strategy core, scenarios, policies,
  the scheduler optimizer, the serving plane) import eagerly;
  JAX-backed layers (the elastic runtime, models, training, launch
  helpers) resolve lazily on first attribute access, so
  ``import repro.api`` stays cheap on machines without an accelerator.

Naming note: :class:`ClusterState` here is the RMS-side ledger
(:mod:`repro.malleability.policies` — one shared pool, per-job
allocations).  The engine-internal world ledger of the same name stays
at :class:`repro.core.ClusterState` and is not part of this surface.
"""
from __future__ import annotations

from importlib import import_module

# ---- engine / strategy core (device-free) ----------------------------------
from repro.core import (
    DISTANCE_CLASSES,
    DMR_KEY,
    TOPO_KEY,
    CheckpointSpec,
    Method,
    ReconfigEngine,
    ReconfigOutcome,
    ReconfigPlan,
    ShrinkKind,
    SpawnPlan,
    Stage,
    Strategy,
    StrategySpec,
    Timeline,
    TimelineEvent,
    Topology,
    checkpoint_timeline,
    get_strategy,
    plan_diffusive,
    plan_dmr,
    plan_hypercube,
    plan_sequential,
    plan_topo,
    register_strategy,
    registered_strategies,
    restart_timeline,
    running_vector,
    shrink_timeline,
    strategy_key,
)

# ---- cost models, scenarios, executors (device-free) -----------------------
from repro.malleability import (
    FAULT_SCENARIO_NAMES,
    MN5,
    NASP,
    CostModel,
    ExpansionReport,
    Scenario,
    ScenarioEvent,
    ScenarioRecord,
    ShrinkReport,
    TransitionCache,
    fsdp_bytes_model,
    get_scenario,
    param_bytes_for_arch,
    record_parity_key,
    register_scenario,
    registered_fault_scenarios,
    registered_scenarios,
    replicated_bytes_model,
    replicated_link_model,
    resolve_engine,
    run_scenario_live,
    run_scenario_sim,
    run_scenario_vectorized,
    scenario_pool,
    simulate_expansion,
    simulate_redistribution,
    simulate_shrink,
)

# ---- RMS policies + the multi-job arbiter (device-free) --------------------
from repro.malleability import (
    SERVE_SCENARIO_NAMES,
    SERVE_TRAFFIC,
    ArbitratedJob,
    BackfillPolicy,
    CheckpointIntervalPolicy,
    ChurnPolicy,
    JobSpec,
    MonteCarloSweep,
    MultiJobOutcome,
    PolicyTrace,
    PreemptionPolicy,
    PriorityArrival,
    RigidArrival,
    RmsPolicy,
    TrafficPolicy,
    arbitrate_jobs,
    charge_in_flight_queueing,
    churn_trace,
    monte_carlo_sweep,
    registered_policy_scenarios,
    registered_serve_scenarios,
    run_multijob_sim,
)
from repro.malleability.policies import POLICY_SCENARIO_NAMES, ClusterState

# ---- the closed scheduling loop (device-free) ------------------------------
from repro.malleability import (
    KNOB_GRID,
    WORKLOAD_SCENARIO_NAMES,
    WORKLOAD_TRACES,
    OptimizerResult,
    ScheduleObjective,
    ScheduleOutcome,
    SchedulerKnobs,
    WorkloadTrace,
    evaluate_schedule,
    generate_workload,
    optimize_schedule,
    registered_workload_scenarios,
    rigid_baseline,
)

# ---- throughput model / time-to-result (device-free) -----------------------
from repro.malleability import (
    ThroughputModel,
    batch_shares,
    flops_per_token_for_arch,
    time_to_result,
)

# ---- elastic serving plane (device-free) -----------------------------------
from repro.serving import (
    EXECUTORS,
    ContinuousBatcher,
    KVBytesModel,
    KVPageTable,
    PageSpec,
    Request,
    ServeConfig,
    ServePhase,
    ServeReport,
    check_serve_agreement,
    run_serve,
    serve_config,
    serve_parity_key,
)

# ---- JAX-backed layers: resolved lazily on first access --------------------
# name -> providing module.  Kept out of the eager imports so
# `import repro.api` works (fast) anywhere the device-free simulator
# runs; touching one of these names imports jax.
_LAZY_EXPORTS: dict[str, str] = {
    # checkpoint store (imports jax for device_get / restore resharding)
    "CheckpointManager": "repro.checkpoint",
    # elastic runtime
    "DevicePool": "repro.elastic",
    "ElasticRuntime": "repro.elastic",
    "ElasticTrainer": "repro.elastic.trainer",
    "reshard_tree": "repro.elastic",
    "transfer_stats": "repro.elastic",
    # RMS event source (package import pulls the jax-backed runtime)
    "Event": "repro.elastic.rms",
    "EventKind": "repro.elastic.rms",
    "SimulatedRMS": "repro.elastic.rms",
    # model / data / config
    "Model": "repro.models",
    "arch_config": "repro.configs",
    "smoke_config": "repro.configs",
    "SyntheticTokens": "repro.data",
    "make_batch_on_mesh": "repro.data",
    # sharding + training
    "ShardingContext": "repro.parallel.sharding",
    "param_sharding": "repro.parallel.sharding",
    "use_sharding": "repro.parallel.sharding",
    "TrainState": "repro.train.steps",
    "build_init_fn": "repro.train.steps",
    "build_train_step": "repro.train.steps",
    "train_state_shardings": "repro.train.steps",
    # launchers
    "make_host_mesh": "repro.launch.mesh",
    "run_elastic": "repro.launch.serve",
}


def __getattr__(name: str):
    module = _LAZY_EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(import_module(module), name)
    globals()[name] = value     # cache: subsequent lookups are plain
    return value


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_LAZY_EXPORTS))


__all__ = [
    # engine / strategy core
    "DISTANCE_CLASSES",
    "DMR_KEY",
    "TOPO_KEY",
    "CheckpointSpec",
    "Method",
    "ReconfigEngine",
    "ReconfigOutcome",
    "ReconfigPlan",
    "ShrinkKind",
    "SpawnPlan",
    "Stage",
    "Strategy",
    "StrategySpec",
    "Timeline",
    "TimelineEvent",
    "Topology",
    "checkpoint_timeline",
    "get_strategy",
    "plan_diffusive",
    "plan_dmr",
    "plan_hypercube",
    "plan_sequential",
    "plan_topo",
    "register_strategy",
    "registered_strategies",
    "restart_timeline",
    "running_vector",
    "shrink_timeline",
    "strategy_key",
    # cost models, scenarios, executors
    "FAULT_SCENARIO_NAMES",
    "MN5",
    "NASP",
    "CostModel",
    "ExpansionReport",
    "Scenario",
    "ScenarioEvent",
    "ScenarioRecord",
    "ShrinkReport",
    "TransitionCache",
    "fsdp_bytes_model",
    "get_scenario",
    "param_bytes_for_arch",
    "record_parity_key",
    "register_scenario",
    "registered_fault_scenarios",
    "registered_scenarios",
    "replicated_bytes_model",
    "replicated_link_model",
    "resolve_engine",
    "run_scenario_live",
    "run_scenario_sim",
    "run_scenario_vectorized",
    "scenario_pool",
    "simulate_expansion",
    "simulate_redistribution",
    "simulate_shrink",
    # policies + arbiter
    "POLICY_SCENARIO_NAMES",
    "SERVE_SCENARIO_NAMES",
    "SERVE_TRAFFIC",
    "ArbitratedJob",
    "BackfillPolicy",
    "CheckpointIntervalPolicy",
    "ChurnPolicy",
    "ClusterState",
    "JobSpec",
    "MonteCarloSweep",
    "MultiJobOutcome",
    "PolicyTrace",
    "PreemptionPolicy",
    "PriorityArrival",
    "RigidArrival",
    "RmsPolicy",
    "TrafficPolicy",
    "arbitrate_jobs",
    "charge_in_flight_queueing",
    "churn_trace",
    "monte_carlo_sweep",
    "registered_policy_scenarios",
    "registered_serve_scenarios",
    "run_multijob_sim",
    # scheduler optimizer
    "KNOB_GRID",
    "WORKLOAD_SCENARIO_NAMES",
    "WORKLOAD_TRACES",
    "OptimizerResult",
    "ScheduleObjective",
    "ScheduleOutcome",
    "SchedulerKnobs",
    "WorkloadTrace",
    "evaluate_schedule",
    "generate_workload",
    "optimize_schedule",
    "registered_workload_scenarios",
    "rigid_baseline",
    # throughput model / time-to-result
    "ThroughputModel",
    "batch_shares",
    "flops_per_token_for_arch",
    "time_to_result",
    # serving plane
    "EXECUTORS",
    "ContinuousBatcher",
    "KVBytesModel",
    "KVPageTable",
    "PageSpec",
    "Request",
    "ServeConfig",
    "ServePhase",
    "ServeReport",
    "check_serve_agreement",
    "run_serve",
    "serve_config",
    "serve_parity_key",
    # JAX-backed (lazy)
    *sorted(_LAZY_EXPORTS),
]
