"""Logical-axis sharding (MaxText-style rules), mesh-agnostic model code.

Params and activations are annotated with *logical* axis names; a rule
table maps them to mesh axes.  Resolution is shape-aware:

  * a mesh axis is dropped when the dimension is smaller than the shard
    count (XLA rejects that); *uneven* sharding (dim >= shards but not
    divisible) is allowed — GSPMD pads internally (e.g. yi-34b's 56 heads
    over a 16-way model axis);
  * rule entries may be tuples — axes are applied greedily left to right.

Two rule tables exist because the same logical name means different
things on weights vs activations ("embed" is the FSDP dim of a weight but
the replicated feature dim of an activation).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Weights: TP over 'model' on the obvious dims, ZeRO-3/FSDP over 'data' on
# the embed dim.  'layers' is the scan axis and never sharded.
WEIGHT_RULES: dict[str, Any] = {
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",      # dropped automatically when kv < |model|
    "q_per_kv": None,
    "head_dim": None,
    "embed": "data",
    "embed_out": "data",
    "experts": "model",
    "layers": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    "xlstm_inner": "model",
    "xlstm_heads": "model",
    "gate": None,
}

# Activations, per execution shape.  'train': batch-parallel over
# (pod, data); 'decode': batch over (pod, data) + KV cache sequence over
# 'model' (context parallelism); 'long': batch too small to shard, the
# sequence/KV dims carry all parallelism.
ACT_RULES: dict[str, dict[str, Any]] = {
    "train": {
        "batch": ("pod", "data"),
        "exp_capacity": ("pod", "data"),
        "seq": None,
        "residual_seq": "model",   # Megatron-style sequence parallelism
        "kv_seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "q_per_kv": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "xlstm_inner": "model",
        "xlstm_heads": "model",
    },
    "decode": {
        "batch": ("pod", "data"),
        "exp_capacity": ("pod", "data"),
        "seq": None,
        "residual_seq": None,
        "kv_seq": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "q_per_kv": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "xlstm_inner": "model",
        "xlstm_heads": "model",
    },
    "long": {
        "batch": None,
        "exp_capacity": ("pod", "data"),
        "seq": ("pod", "data"),
        "residual_seq": ("pod", "data"),
        "kv_seq": ("pod", "data", "model"),
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "q_per_kv": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "xlstm_inner": "model",
        "xlstm_heads": "model",
    },
}


@dataclass
class ShardingContext:
    mesh: Mesh
    mode: str = "train"                       # key into ACT_RULES
    weight_overrides: dict[str, Any] = field(default_factory=dict)
    act_overrides: dict[str, Any] = field(default_factory=dict)

    def weight_rule(self, name: str):
        if name in self.weight_overrides:
            return self.weight_overrides[name]
        return WEIGHT_RULES.get(name)

    def act_rule(self, name: str):
        if name in self.act_overrides:
            return self.act_overrides[name]
        return ACT_RULES[self.mode].get(name)


_LOCAL = threading.local()


def current_context() -> Optional[ShardingContext]:
    return getattr(_LOCAL, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardingContext]):
    prev = current_context()
    _LOCAL.ctx = ctx
    try:
        yield ctx
    finally:
        _LOCAL.ctx = prev


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fit_axes(
    rule: Any, dim: int, mesh: Mesh, taken: set[str], divisible: bool
) -> tuple[str, ...]:
    """Greedy left-to-right selection of mesh axes for one dimension.

    ``divisible=True`` for weights: jit *argument* shardings reject uneven
    dims (e.g. yi-34b's 56 heads over 16).  Activations only need
    ``dim >= shards`` — with_sharding_constraint pads internally.
    """
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    sizes = _mesh_axis_sizes(mesh)
    out: list[str] = []
    shards = 1
    for ax in axes:
        if ax not in sizes or ax in taken:
            continue
        nxt = shards * sizes[ax]
        ok = (dim % nxt == 0) if divisible else (dim >= nxt)
        if ok:
            out.append(ax)
            shards = nxt
            taken.add(ax)
    return tuple(out)


def resolve_spec(
    logical_axes: tuple, shape: tuple[int, ...], ctx: ShardingContext, kind: str
) -> P:
    """Map logical axes -> PartitionSpec for a tensor of ``shape``."""
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    taken: set[str] = set()
    entries: list = []
    rule_fn = ctx.weight_rule if kind == "weight" else ctx.act_rule
    divisible = kind == "weight"
    for name, dim in zip(logical_axes, shape):
        rule = None if name is None else rule_fn(name)
        axes = _fit_axes(rule, dim, ctx.mesh, taken, divisible)
        entries.append(list(axes))
    if divisible:
        # Fallback pass: keep weights fully sharded even when the natural
        # dim doesn't divide (yi's 56 heads, GQA kv<TP, ...): place unused
        # mesh axes on the largest remaining divisible dim.  This is a
        # *storage* sharding (ZeRO-style); compute layout is re-propagated
        # by GSPMD from the activation constraints.
        sizes = _mesh_axis_sizes(ctx.mesh)
        for ax in ("model", "data", "pod"):
            if ax not in sizes or ax in taken:
                continue
            cands = [
                (shape[i], i)
                for i in range(len(shape))
                if logical_axes[i] != "layers"
                and shape[i] % (sizes[ax] * _prod(sizes[a] for a in entries[i])) == 0
            ]
            if not cands:
                continue
            _, best = max(cands)
            entries[best].append(ax)
            taken.add(ax)
    return P(*[tuple(e) if len(e) > 1 else (e[0] if e else None) for e in entries])


def _prod(it) -> int:
    out = 1
    for v in it:
        out *= v
    return out


def param_sharding(params: dict, specs: dict, ctx: ShardingContext) -> dict:
    """NamedSharding dict for a flat (params, logical-spec) pair."""
    return {
        k: NamedSharding(ctx.mesh, resolve_spec(tuple(specs[k]), p.shape, ctx, "weight"))
        for k, p in params.items()
    }


def param_sharding_abstract(shapes: dict, specs: dict, ctx: ShardingContext) -> dict:
    """Same as :func:`param_sharding` but from ShapeDtypeStructs."""
    return {
        k: NamedSharding(ctx.mesh, resolve_spec(tuple(specs[k]), s.shape, ctx, "weight"))
        for k, s in shapes.items()
    }


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a context."""
    ctx = current_context()
    if ctx is None:
        return x
    spec = resolve_spec(tuple(logical_axes), x.shape, ctx, "act")
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
