"""Distribution layer: logical-axis sharding rules over pjit meshes."""
from .sharding import (
    ShardingContext,
    constrain,
    current_context,
    param_sharding,
    param_sharding_abstract,
    resolve_spec,
    use_sharding,
)

__all__ = [
    "ShardingContext",
    "constrain",
    "current_context",
    "param_sharding",
    "param_sharding_abstract",
    "resolve_spec",
    "use_sharding",
]
