"""AdamW with global-norm clipping, as a pair of pure functions.

State layout mirrors the param pytree (one ``mu``/``nu`` per leaf), so it
reshards with the exact same PartitionSpecs as the params — which is what
the elastic runtime's redistribution stage relies on.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    def zeros(p):
        return jnp.zeros_like(p)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float | None = 1.0,
) -> tuple[Any, AdamWState]:
    """One AdamW step; returns (new_params, new_state)."""
    step = state.step + 1
    if clip_norm is not None:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    # bias correction
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf_update(p, m, v):
        update = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p
        return (p - lr * update).astype(p.dtype)

    new_params = jax.tree.map(leaf_update, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
