"""Pure-JAX optimizers (no optax dependency)."""
from .adamw import AdamWState, adamw_init, adamw_update, global_norm
from .schedule import cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
    "linear_warmup_cosine",
]
