"""Gradient compression for cross-pod reduction (distributed-opt trick).

int8 block-quantization with error feedback: gradients are quantized
before the (slow, cross-pod) all-reduce and the quantization residual is
carried into the next step, preserving convergence (1-bit Adam lineage).
4x reduction of DCN/ICI gradient bytes on the 'pod' axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # residual feedback pytree (same structure as grads)


def compression_init(grads_like: Any) -> CompressionState:
    return CompressionState(error=jax.tree.map(jnp.zeros_like, grads_like))


def quantize_int8(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8 quantization; returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return out[:n].reshape(shape).astype(dtype)


def compress_grads(
    grads: Any, state: CompressionState, block: int = 256
) -> tuple[Any, CompressionState]:
    """Quantize grads (+error feedback); returns (dequantized grads that
    would come out of the compressed all-reduce, new state).

    In a real deployment the int8 payload is what crosses the pod axis;
    here we model the numerics end-to-end so training tests can assert
    convergence is preserved.
    """
    def one(g, e):
        g_fb = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize_int8(g_fb, block)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        new_err = (g_fb - deq).astype(e.dtype)
        return deq.astype(g.dtype), new_err

    pairs = jax.tree.map(one, grads, state.error)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, CompressionState(error=err)


def compressed_bytes(grads: Any, block: int = 256) -> tuple[int, int]:
    """(raw_bytes, compressed_bytes) for reporting."""
    raw = comp = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        raw += n * g.dtype.itemsize
        nblocks = -(-n // block)
        comp += n * 1 + nblocks * 4  # int8 payload + fp32 scales
    return raw, comp
