"""Learning-rate schedules as plain callables (jit-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * (min_frac + (1 - min_frac) * cos)

    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int, min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)

    def lr(step):
        warm = base_lr * jnp.minimum(1.0, step / max(warmup, 1))
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return lr
