"""Synthetic token/embedding pipeline.

Deterministic per (seed, step) so that restarts and elastic resizes can
replay the exact stream — a restart after an SS shrink (or a failure)
resumes mid-epoch losslessly, which the integration tests assert.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.models.common import ModelConfig
from repro.parallel.sharding import ShardingContext, resolve_spec


@dataclass
class SyntheticTokens:
    """Zipf-ish synthetic LM stream with next-token labels."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def sample(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # Zipf-like marginal over the vocab (heavier head, realistic gather
        # locality for the embedding table).
        v = self.cfg.vocab
        ranks = rng.zipf(1.3, size=(self.batch, self.seq + 1)).astype(np.int64)
        tokens = np.minimum(ranks - 1, v - 1).astype(np.int32)
        out = {
            "labels": tokens[:, 1:],
        }
        if self.cfg.embed_inputs:
            erng = np.random.default_rng((self.seed << 21) ^ step)
            out["embeds"] = erng.standard_normal(
                (self.batch, self.seq, self.cfg.d_model), dtype=np.float32
            )
        else:
            out["tokens"] = tokens[:, :-1]
        if self.cfg.mrope_sections:
            pos = np.broadcast_to(
                np.arange(self.seq, dtype=np.int32), (self.batch, self.seq)
            )
            out["positions"] = np.stack([pos, pos, pos])
        return out

    def iter(self, start_step: int = 0, prefetch: int = 2) -> Iterator[dict]:
        """Background-thread prefetching iterator."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put(self.sample(s))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def batch_spec(cfg: ModelConfig, ctx: ShardingContext) -> dict:
    """NamedShardings for each batch field under the context's rules."""
    def spec_for(name: str, ndim: int):
        if name == "positions" and cfg.mrope_sections:
            axes = (None, "batch", "seq")
        elif name == "embeds":
            axes = ("batch", "seq", "embed")
        else:
            axes = ("batch", "seq")
        return axes[:ndim] if ndim else axes

    names = {"labels": 2}
    if cfg.embed_inputs:
        names["embeds"] = 3
    else:
        names["tokens"] = 2
    if cfg.mrope_sections:
        names["positions"] = 3
    return names, spec_for


def make_batch_on_mesh(host_batch: dict, cfg: ModelConfig, ctx: ShardingContext) -> dict:
    """device_put a host batch with the right activation shardings."""
    _, spec_for = batch_spec(cfg, ctx)
    out = {}
    for k, v in host_batch.items():
        axes = spec_for(k, v.ndim)
        spec = resolve_spec(tuple(axes), v.shape, ctx, "act")
        out[k] = jax.device_put(v, NamedSharding(ctx.mesh, spec))
    return out
