"""Deterministic synthetic data pipeline (sharded, prefetching)."""
from .pipeline import SyntheticTokens, batch_spec, make_batch_on_mesh

__all__ = ["SyntheticTokens", "batch_spec", "make_batch_on_mesh"]
