"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers and
compiles against these.  Modality frontends ([audio]/[vlm]) are stubs:
the spec supplies precomputed frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeCell
from repro.models.common import ModelConfig


def sharding_mode(shape: ShapeCell) -> str:
    return {"train": "train", "prefill": "train",
            "decode": "decode", "long_decode": "long"}[shape.kind]


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    out: dict[str, Any] = {
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.embed_inputs:
        out["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.mrope_sections:
        out["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return out


def decode_tok_specs(cfg: ModelConfig, batch: int) -> dict:
    out: dict[str, Any] = {"cache_pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.embed_inputs:
        out["embeds"] = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    if cfg.mrope_sections:
        out["positions"] = jax.ShapeDtypeStruct((3, batch, 1), jnp.int32)
    else:
        out["positions"] = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """All abstract inputs for the given cell (excluding model state)."""
    if shape.kind in ("train", "prefill"):
        return train_batch_specs(cfg, shape.global_batch, shape.seq_len)
    return decode_tok_specs(cfg, shape.global_batch)
