"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the host's devices (reduced config by default — the
full configs only fit the production mesh, which is exercised via the
dry-run).  Integrates the elastic runtime: pass ``--scenario <name>`` to
run the malleable loop against a registered declarative workload trace
(grow/shrink/fail/straggler events planned by the ReconfigEngine).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import arch_config, smoke_config
from repro.data import SyntheticTokens, make_batch_on_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.parallel.sharding import ShardingContext
from repro.train.steps import build_train_step
from repro.checkpoint import CheckpointManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full arch config (production scale)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--scenario", default=None,
                    help="run the elastic loop against a registered scenario "
                         "(see repro.malleability.registered_scenarios)")
    args = ap.parse_args()

    cfg = arch_config(args.arch) if args.full_config else smoke_config(args.arch)
    model = Model(cfg)

    if args.scenario:
        run_scenario(model, args)
        return
    mesh = make_host_mesh(args.model_parallel)
    ctx = ShardingContext(mesh=mesh, mode="train")

    step_fn, shardings, _ = build_train_step(model, ctx, lr=args.lr)
    from repro.train.steps import build_init_fn

    init_fn, _ = build_init_fn(model, ctx)
    state = init_fn(jax.random.key(0))
    step_jit = jax.jit(
        step_fn, in_shardings=(shardings, None), out_shardings=(shardings, None),
        donate_argnums=(0,),
    )

    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    data = SyntheticTokens(cfg, args.batch, args.seq)
    t0 = time.time()
    for i, host_batch in enumerate(data.iter()):
        if i >= args.steps:
            break
        batch = make_batch_on_mesh(host_batch, cfg, ctx)
        state, metrics = step_jit(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {i:>5} loss {loss:.4f} ({(time.time()-t0):.1f}s)", flush=True)
        if ckpt and (i + 1) % args.checkpoint_every == 0:
            ckpt.save({"params": state.params}, i + 1)
    if ckpt:
        ckpt.wait()


def run_scenario(model: Model, args) -> None:
    """Malleable training: the declarative trace drives the live runtime."""
    from repro.elastic import ElasticTrainer
    from repro.malleability import get_scenario

    scenario = get_scenario(args.scenario)
    trainer = ElasticTrainer.from_scenario(
        model, scenario, lr=args.lr, batch=args.batch, seq=args.seq,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    steps = max(args.steps, scenario.steps)
    t0 = time.time()
    hist = trainer.run(steps)
    for rec in trainer.runtime.history:
        print(f"reconfig {rec.kind:<10} {rec.mechanism:<22} "
              f"{rec.nodes_before}->{rec.nodes_after} nodes  "
              f"est {rec.est_wall_s*1e3:.2f} ms  downtime {rec.downtime_s*1e3:.2f} ms",
              flush=True)
    print(f"scenario {scenario.name!r}: {len(hist)} steps, "
          f"loss {hist[0].loss:.4f} -> {hist[-1].loss:.4f} "
          f"({time.time()-t0:.1f}s, {len(trainer.runtime.history)} reconfigs)",
          flush=True)


if __name__ == "__main__":
    main()
