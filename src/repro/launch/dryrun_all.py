"""Driver: run every (arch x shape x mesh) dry-run cell as a subprocess.

Each cell compiles in its own process (XLA device-count env must be set
before jax init; isolation also caps compile memory).  Results land in
``results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun_all [--jobs 4] [--mesh both]
      [--arch A ...] [--shape S ...] [--force]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor, as_completed

from repro.configs import ARCHS, SHAPES


def cell_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def run_one(arch: str, shape: str, mesh: str, out_dir: str, timeout: int) -> dict:
    out = cell_path(out_dir, arch, shape, mesh)
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", out,
    ]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    try:
        proc = subprocess.run(
            cmd, capture_output=True, text=True, timeout=timeout, env=env
        )
        if os.path.exists(out):
            with open(out) as f:
                return json.load(f)
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                "error": (proc.stderr or proc.stdout)[-2000:]}
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape, "mesh": mesh, "status": "timeout"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", nargs="*", default=list(ARCHS))
    ap.add_argument("--shape", nargs="*", default=[s.name for s in SHAPES])
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=7200)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = [
        (a, s, m)
        for a in args.arch
        for s in args.shape
        for m in meshes
        if args.force or not os.path.exists(cell_path(args.out_dir, a, s, m))
    ]
    print(f"{len(cells)} cells to run with {args.jobs} workers", flush=True)
    ok = bad = 0
    with ThreadPoolExecutor(max_workers=args.jobs) as pool:
        futs = {
            pool.submit(run_one, a, s, m, args.out_dir, args.timeout): (a, s, m)
            for a, s, m in cells
        }
        for fut in as_completed(futs):
            a, s, m = futs[fut]
            rec = fut.result()
            status = rec.get("status")
            if status in ("ok", "skipped"):
                ok += 1
            else:
                bad += 1
            extra = ""
            if status == "ok":
                extra = f"compile={rec.get('compile_s')}s"
            elif status == "error":
                extra = rec.get("error", "")[:160].replace("\n", " ")
            print(f"[{ok + bad}/{len(cells)}] {a} {s} {m}: {status} {extra}",
                  flush=True)
    print(f"done: {ok} ok/skipped, {bad} failed")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
