"""While-aware HLO analysis: FLOPs + collective bytes with loop trip counts.

``compiled.cost_analysis()`` on this XLA build counts each while body
ONCE, which silently undercounts scan-over-layers models by a factor of
L.  This module parses the post-SPMD HLO text into computations, walks
the call graph (entry -> while bodies -> nested whiles / fusions), infers
loop trip counts from the condition computation's comparison constant,
and accumulates:

  * dot FLOPs:  2 * prod(result_shape) * prod(lhs_contracting_dims)
  * collective result bytes per kind (all-reduce counted 2x: ring cost)
  * dot bytes (operands+result) as an HBM-traffic lower-bound complement

Elementwise FLOPs are ignored (negligible next to the matmuls for every
assigned arch).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]"
)

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _nbytes(text: str) -> int:
    total = 0
    for dt, shape in _shapes_in(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    header: str = ""
    lines: list[str] = field(default_factory=list)
    _symbols: dict | None = None

    def symbols(self) -> dict[str, str]:
        """Instruction name -> result type (incl. header parameters)."""
        if self._symbols is None:
            table: dict[str, str] = {}
            # parameters: "name.1: f32[6,48]" pairs in the header
            for m in re.finditer(
                r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\]\{\},]+))", self.header
            ):
                table[m.group(1)] = m.group(2)
            for s in self.lines:
                m = re.match(
                    r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],\{\}]+))\s",
                    s,
                )
                if m:
                    table[m.group(1)] = m.group(2)
            self._symbols = table
        return self._symbols


def split_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*{\s*(/\*.*\*/)?\s*$")
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = header.match(s)
            if m and ("->" in s or s.startswith("ENTRY")):
                cur = Computation(name=m.group(1), header=s)
        else:
            if s == "}" or s.startswith("}"):
                comps[cur.name] = cur
                cur = None
            else:
                cur.lines.append(s)
    if cur is not None:
        comps[cur.name] = cur
    return comps


_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations|called_computations)="
    r"\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)"
)
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _prodl(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclass
class Totals:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: dict = field(default_factory=lambda: {k: 0 for k in COLLECTIVES})
    coll_f32: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Totals", times: float = 1.0):
        self.flops += other.flops * times
        self.dot_bytes += other.dot_bytes * times
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * times
            self.coll_counts[k] += other.coll_counts[k] * times
            self.coll_f32[k] += other.coll_f32[k] * times


def _line_result_and_op(s: str):
    m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*((?:\([^)]*\))|(?:[\w\[\],\{\}]+))\s+([\w\-]+)", s)
    if not m:
        return None, None
    return m.group(1), m.group(2)


def _dot_flops(s: str, result_type: str, symbols: dict[str, str]) -> tuple[float, float]:
    """(flops, bytes) for one dot line.

    Optimized HLO prints operands as bare instruction names; shapes are
    resolved through the computation's symbol table.
    """
    res_elems = 0
    res_bytes = 0
    for dt, shape in _shapes_in(result_type):
        n = 1
        for d in shape:
            n *= d
        res_elems += n
        res_bytes += n * _DTYPE_BYTES[dt]
    # operand names inside dot(...)
    args = s[s.index("dot(") + 4:]
    depth = 1
    buf = []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    operands = "".join(buf)
    names = re.findall(r"%([\w\.\-]+)", operands)
    op_types = [symbols.get(n, "") for n in names]
    # typed-operand fallback (pre-optimization dumps)
    if not any(op_types) and _SHAPE_RE.search(operands):
        op_types = [operands]
    op_bytes = 0
    for t in op_types:
        op_bytes += _nbytes(t)
    lhs_shapes = _shapes_in(op_types[0]) if op_types else []
    lhs_shape = lhs_shapes[0][1] if lhs_shapes else []
    m = _DOT_CONTRACT_RE.search(s)
    k = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_shape):
                k *= lhs_shape[i]
    return 2.0 * res_elems * k, float(op_bytes + res_bytes)


def _trip_count(while_line: str, comps: dict[str, Computation]) -> int:
    """Trip count of one while op.

    Primary: XLA's ``backend_config known_trip_count`` on the op itself.
    Fallback: largest integer constant in the condition computation."""
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w\.\-]+)", while_line)
    best = 1
    if mc and mc.group(1) in comps:
        for s in comps[mc.group(1)].lines:
            for mm in re.finditer(r"constant\((\d+)\)", s):
                best = max(best, int(mm.group(1)))
    return best


def analyze(hlo: str) -> dict:
    comps = split_computations(hlo)

    # entry = computation with ENTRY marker, else the largest
    entry_name = None
    for raw_line in hlo.splitlines():
        if raw_line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", raw_line)
            if m:
                entry_name = m.group(1)
    if entry_name is None or entry_name not in comps:
        entry_name = max(comps, key=lambda c: len(comps[c].lines))

    cache: dict[str, Totals] = {}

    def cost(name: str, stack: tuple = ()) -> Totals:
        if name in cache:
            return cache[name]
        if name not in comps or name in stack:
            return Totals()
        comp = comps[name]
        t = Totals()
        for s in comp.lines:
            result_type, op = _line_result_and_op(s)
            if op is None:
                continue
            if op == "dot":
                fl, by = _dot_flops(s, result_type, comp.symbols())
                t.flops += fl
                t.dot_bytes += by
            elif op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", s)
                trips = _trip_count(s, comps)
                if mb:
                    t.add(cost(mb.group(1), stack + (name,)), times=trips)
            elif op in ("fusion", "call", "conditional", "custom-call",
                        "reduce", "sort", "map", "scatter", "select-and-scatter"):
                for group in _CALLED_RE.findall(s):
                    for sub in re.split(r",\s*%?", group):
                        if sub:
                            t.add(cost(sub, stack + (name,)))
            else:
                base = None
                for c in COLLECTIVES:
                    if op == c or op.startswith(c + "-start"):
                        base = c
                        break
                if base:
                    nb = _nbytes(result_type)
                    if base == "all-reduce":
                        nb *= 2
                    t.coll[base] += nb
                    t.coll_counts[base] += 1
                    # f32 payload portion, for the TPU-dtype correction:
                    # the CPU backend upcasts bf16 GEMM operands to f32
                    # *before* SPMD places the collective, doubling payload
                    # bytes vs what a TPU lowering moves (verified by
                    # probe; EXPERIMENTS.md §Dry-run caveats).
                    f32b = sum(
                        (lambda n: n * 4)(_prodl(shape))
                        for dt_, shape in _shapes_in(result_type)
                        if dt_ == "f32"
                    )
                    if base == "all-reduce":
                        f32b *= 2
                    t.coll_f32[base] += f32b
        cache[name] = t
        return t

    total = cost(entry_name)
    # TPU-dtype corrected bytes: f32 payloads that a TPU lowering would
    # move as bf16 (CPU GEMM upcast artifact) count at half.
    corrected = {
        k: total.coll[k] - 0.5 * total.coll_f32[k] for k in COLLECTIVES
    }
    return {
        "flops": total.flops,
        "dot_bytes": total.dot_bytes,
        "collectives": {
            "per_kind": {k: int(v) for k, v in total.coll.items()},
            "counts": {k: int(v) for k, v in total.coll_counts.items()},
            "total_bytes": int(sum(total.coll.values())),
            "per_kind_tpu_corrected": {k: int(v) for k, v in corrected.items()},
            "total_bytes_tpu_corrected": int(sum(corrected.values())),
        },
    }
