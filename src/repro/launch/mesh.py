"""Production mesh definitions (TPU v5e pods).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 names explicit/auto axis types; older jax has neither.
    from jax.sharding import AxisType

    def _axis_types(n: int):
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # pragma: no cover - depends on installed jax

    def _axis_types(n: int):
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_host_mesh(model_parallel: int = 1, axis_names=("data", "model")):
    """Mesh over whatever devices the host actually has (tests/examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel),
        axis_names,
        **_axis_types(2),
    )
