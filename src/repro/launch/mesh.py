"""Production mesh definitions (TPU v5e pods).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1, axis_names=("data", "model")):
    """Mesh over whatever devices the host actually has (tests/examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel),
        axis_names,
        axis_types=(AxisType.Auto,) * 2,
    )
