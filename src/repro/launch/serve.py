"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Batched greedy decoding over synthetic prompts on the host's devices
(reduced configs; the production decode shapes are exercised by the
dry-run).  Reports prefill/decode throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(embed_inputs=False)
    model = Model(cfg)
    params, _ = model.init(jax.random.key(0))
    B, P, G = args.batch, args.prompt_len, args.gen_len
    cache = model.init_cache(B, P + G)
    decode = jax.jit(model.decode_step)
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)

    def tok_batch(tokens, t):
        pos = jnp.full((B, 1), t, jnp.int32)
        out = {"tokens": tokens, "cache_pos": jnp.int32(t),
               "positions": jnp.stack([pos, pos, pos]) if cfg.mrope_sections else pos}
        return out

    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, tok_batch(prompts[:, t:t + 1], t))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [nxt]
    t0 = time.time()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, cache, tok_batch(nxt, t))
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0

    gen = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={B}")
    print(f"prefill: {P} steps in {t_prefill:.2f}s")
    print(f"decode:  {B * (G - 1) / max(t_decode, 1e-9):.1f} tok/s "
          f"({G - 1} steps in {t_decode:.2f}s)")
    print(f"sample output ids: {gen[0, :12].tolist()}")


if __name__ == "__main__":
    main()
