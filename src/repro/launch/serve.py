"""Serving driver: ``python -m repro.launch.serve``.

Default mode drives the **elastic decode service** (:mod:`repro.serving`):
replays one (or all) registered serve traffic traces — the decode pool
grown/shrunk by the traffic policy, in-flight KV caches migrated and
priced on every resize — on the simulator and the live runtime, prints
per-phase latency/throughput, and exits non-zero if the two executors
disagree on ANY number (the same contract as
``examples/malleability_sim.py``).

``--static`` keeps the original single-shot decode path: batched greedy
decoding over synthetic prompts on the host's devices, reporting
prefill/decode throughput.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence


def _run_static(args: argparse.Namespace) -> int:
    """The legacy single-shot decode driver (JAX imported lazily)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import smoke_config
    from repro.models import Model

    cfg = smoke_config(args.arch).replace(embed_inputs=False)
    model = Model(cfg)
    params, _ = model.init(jax.random.key(0))
    B, P, G = args.batch, args.prompt_len, args.gen_len
    cache = model.init_cache(B, P + G)
    decode = jax.jit(model.decode_step)
    prompts = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab)

    def tok_batch(tokens, t):
        pos = jnp.full((B, 1), t, jnp.int32)
        out = {"tokens": tokens, "cache_pos": jnp.int32(t),
               "positions": jnp.stack([pos, pos, pos]) if cfg.mrope_sections else pos}
        return out

    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, tok_batch(prompts[:, t:t + 1], t))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [nxt]
    t0 = time.time()
    for t in range(P, P + G - 1):
        logits, cache = decode(params, cache, tok_batch(nxt, t))
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0

    gen = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={B}")
    print(f"prefill: {P} steps in {t_prefill:.2f}s")
    print(f"decode:  {B * (G - 1) / max(t_decode, 1e-9):.1f} tok/s "
          f"({G - 1} steps in {t_decode:.2f}s)")
    print(f"sample output ids: {gen[0, :12].tolist()}")
    return 0


def print_serve_report(rep) -> None:
    """Per-phase table + totals for one serve replay."""
    print(f"[{rep.executor}] {rep.scenario}: {rep.submitted} requests, "
          f"{rep.completed} completed, {rep.dropped} dropped "
          f"({rep.migrated} migrated / {rep.requeued} requeued on resizes)")
    print(f"  {'steps':>12} {'workers':>7} {'done':>5} "
          f"{'p50 lat':>9} {'tok/s':>8}")
    for ph in rep.phases:
        print(f"  [{ph.start_step:4d},{ph.end_step:4d}) {ph.workers:7d} "
              f"{ph.completed:5d} {ph.p50_latency_s:8.3f}s "
              f"{ph.throughput_tok_s:8.1f}")
    print(f"  total: wall {rep.wall_s:.2f}s, downtime {rep.downtime_s:.4f}s, "
          f"queued {rep.queued_s:.2f}s, p50 {rep.p50_latency_s:.3f}s, "
          f"p99 {rep.p99_latency_s:.3f}s, {rep.throughput_tok_s:.1f} tok/s, "
          f"{rep.bytes_moved / 1e6:.1f} MB KV moved "
          f"({rep.bytes_cross_rack / 1e6:.1f} MB cross-rack)")


def run_elastic(names: Sequence[str], executor: str,
                strategy: Optional[str]) -> int:
    """Replay serve traces; returns the number of sim/live disagreements."""
    from repro.serving import run_serve, serve_parity_key

    bad = 0
    for name in names:
        if executor in ("sim", "live"):
            print_serve_report(run_serve(name, executor=executor,
                                         strategy=strategy))
            continue
        sim = run_serve(name, executor="sim", strategy=strategy)
        live = run_serve(name, executor="live", strategy=strategy)
        print_serve_report(live)
        if serve_parity_key(sim) == serve_parity_key(live):
            print(f"  sim == live: OK ({len(live.records)} resizes, "
                  f"{live.completed} requests, every number identical)")
        else:
            bad += 1
            print(f"  sim == live: DISAGREE on {name!r}", file=sys.stderr)
    return bad


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--static", action="store_true",
                    help="legacy single-shot decode (needs --arch)")
    ap.add_argument("--arch", default="",
                    help="model config (static mode only)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--scenario", default="all",
                    help="serve trace name, or 'all' (elastic mode)")
    ap.add_argument("--executor", choices=("sim", "live", "both"),
                    default="both", help="elastic-mode executor(s)")
    ap.add_argument("--strategy", default=None,
                    help="spawn strategy override (elastic mode)")
    args = ap.parse_args(argv)

    if args.static:
        if not args.arch:
            ap.error("--static requires --arch")
        return _run_static(args)

    from repro.malleability.policies import SERVE_SCENARIO_NAMES

    names = (SERVE_SCENARIO_NAMES if args.scenario == "all"
             else (args.scenario,))
    return run_elastic(names, args.executor, args.strategy)


if __name__ == "__main__":
    sys.exit(main())
