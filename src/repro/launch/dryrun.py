import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the appropriate step (train_step for train shapes, forward
     for prefill, serve_step for decode shapes) with full shardings,
  3. compiles, printing ``memory_analysis()`` (fits?) and
     ``cost_analysis()`` (FLOPs/bytes for the roofline),
  4. parses the post-SPMD HLO for collective operand bytes,
  5. writes a JSON record consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch stablelm_3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun     # driver mode
"""
import argparse
import json
import re
import sys
import time
import traceback


HW = {
    "peak_flops_bf16": 197e12,   # TPU v5e per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes from post-SPMD HLO.

    Convention (documented in EXPERIMENTS.md): bytes = result-shape bytes
    per op; all-reduce counted twice (ring = 2(N-1)/N ~ 2x buffer).  Ops
    inside loop bodies (scan-over-layers) are multiplied by the loop trip
    count parsed from the enclosing while op's induction bound when
    detectable; XLA names scan bodies ``body``/``region`` — we instead rely
    on layer-stacked collectives appearing inside the while body ONCE with
    per-iteration shapes, so we scale by the scan length recorded by the
    caller.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or " = " in s:
            m = re.search(r"=\s*((?:\([^)]*\))|(?:\S+))\s+([a-z0-9-]+)", s)
            if not m:
                continue
            result_type, opcode = m.group(1), m.group(2)
            # normalize fused/async variants like all-gather-start
            base = None
            for c in _COLLECTIVES:
                if opcode == c or opcode.startswith(c + "-start"):
                    base = c
                    break
            if base is None:
                continue
            nbytes = _shape_bytes(result_type)
            if base == "all-reduce":
                nbytes *= 2
            out[base] += nbytes
            counts[base] += 1
    out_total = sum(out.values())
    return {"per_kind": out, "counts": counts, "total_bytes": out_total}


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    import jax

    from repro.configs import arch_config, SHAPES, shape_skip_reason
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, sharding_mode
    from repro.models import Model
    from repro.parallel.sharding import ShardingContext, resolve_spec
    from repro.train.steps import (
        abstract_cache,
        batch_shardings,
        build_serve_step,
        build_train_step,
        cache_shardings,
        serving_param_shapes,
    )
    from repro.parallel.sharding import param_sharding_abstract
    from jax.sharding import NamedSharding, PartitionSpec as P

    shape = next(s for s in SHAPES if s.name == shape_name)
    skip = shape_skip_reason(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": skip}

    cfg = arch_config(arch)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    ctx = ShardingContext(mesh=mesh, mode=sharding_mode(shape))
    specs = input_specs(cfg, shape)

    t0 = time.time()
    if shape.kind == "train":
        step, state_shardings, abstract_state = build_train_step(model, ctx)
        b_shard = batch_shardings(cfg, ctx, shape.global_batch, shape.seq_len)
        fn = jax.jit(
            step,
            in_shardings=(state_shardings, b_shard),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,),
        )
        lowered = fn.lower(abstract_state, specs)
    elif shape.kind == "prefill":
        shapes, pspecs = serving_param_shapes(model)
        p_shard = param_sharding_abstract(shapes, pspecs, ctx)
        b_shard = batch_shardings(cfg, ctx, shape.global_batch, shape.seq_len)

        def prefill(params, batch):
            from repro.parallel.sharding import use_sharding
            with use_sharding(ctx):
                logits, caches = model.forward(params, batch, collect_kv=True)
                return logits[:, -1:], caches

        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        lowered = fn.lower(shapes, specs)
    else:  # decode / long_decode
        shapes, pspecs = serving_param_shapes(model)
        p_shard = param_sharding_abstract(shapes, pspecs, ctx)
        serve = build_serve_step(model, ctx)
        cache = abstract_cache(model, shape.global_batch, shape.seq_len)
        c_shard = cache_shardings(model, ctx, shape.global_batch, shape.seq_len)
        tok_shard = {}
        for name, sds in specs.items():
            if name == "cache_pos":
                tok_shard[name] = NamedSharding(mesh, P())
            elif name == "positions" and cfg.mrope_sections:
                tok_shard[name] = NamedSharding(
                    mesh, resolve_spec((None, "batch", "seq"), sds.shape, ctx, "act"))
            elif name == "embeds":
                tok_shard[name] = NamedSharding(
                    mesh, resolve_spec(("batch", "seq", "embed"), sds.shape, ctx, "act"))
            else:
                tok_shard[name] = NamedSharding(
                    mesh, resolve_spec(("batch", "seq"), sds.shape, ctx, "act"))
        fn = jax.jit(
            serve,
            in_shardings=(p_shard, c_shard, tok_shard),
            donate_argnums=(1,),
        )
        lowered = fn.lower(shapes, cache, specs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax < 0.5 wraps it per-program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()

    # While-aware analysis: cost_analysis() counts scan bodies once on this
    # XLA build; `analyze` multiplies by loop trip counts (hlo_analysis.py).
    from repro.launch.hlo_analysis import analyze

    deep = analyze(hlo)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
            # deep = while-aware dot count; raw = XLA cost_analysis (counts
            # loop bodies once but sees fused non-dot matmuls).  Decode has
            # no layer loop, so raw is the better bound there; train is
            # loop-dominated, so deep is.  Record the max as the estimate.
            "flops": max(deep["flops"], float(cost.get("flops", 0.0))),
            "flops_deep": deep["flops"],
            "dot_bytes": deep["dot_bytes"],
            "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
            "xla_bytes_accessed_raw": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives": deep["collectives"],
        "hw": HW,
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    try:
        rec = run_cell(args.arch, args.shape, args.mesh)
    except Exception as e:  # a failed cell is a bug in the system: report it
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    js = json.dumps(rec, indent=2)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
    sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
