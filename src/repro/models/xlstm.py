"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunked
parallel form) and sLSTM (scalar memory, sequential scan).

The chunked mLSTM below is the pure-jnp oracle for the ``mlstm_scan``
Pallas kernel.  Shapes: B batch, S seq, H heads, K=V head dims, Q chunk.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .common import ModelConfig, ParamBuilder


# ---------------------------------------------------------------------------
# Chunked, stabilized mLSTM (exp input gate, sigmoid forget gate)
# ---------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int):
    """Chunk-parallel stabilized mLSTM.

    q,k,v:  (B, S, H, D)
    i_gate: (B, S, H) raw input-gate preactivation  (exp gating)
    f_gate: (B, S, H) raw forget-gate preactivation (log-sigmoid decay)
    Returns: h (B, S, H, D), final (S_state (B,H,D,D), n (B,H,D), m (B,H)).
    """
    B, S, H, D = q.shape
    Q = chunk
    assert S % Q == 0
    nc = S // Q
    f32 = jnp.float32

    qq = q.reshape(B, nc, Q, H, D).astype(f32) / math.sqrt(D)
    kk = k.reshape(B, nc, Q, H, D).astype(f32)
    vv = v.reshape(B, nc, Q, H, D).astype(f32)
    ig = i_gate.reshape(B, nc, Q, H).astype(f32)
    logf = jax.nn.log_sigmoid(f_gate.reshape(B, nc, Q, H).astype(f32))

    b = jnp.cumsum(logf, axis=2)                        # (B,nc,Q,H) incl. own f
    total = b[:, :, -1, :]                              # (B,nc,H)

    # intra-chunk log weights: l_{ij} = b_i - b_j + i_j  (j <= i)
    diff = b[:, :, :, None, :] - b[:, :, None, :, :] + ig[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    diff = jnp.where(mask, diff, -jnp.inf)
    m_intra = jnp.max(diff, axis=3)                     # (B,nc,Q,H)

    # state contribution log weights to chunk end: w_j = total - b_j + i_j
    w = total[:, :, None, :] - b + ig                   # (B,nc,Q,H)
    m_chunk = jnp.max(w, axis=2)                        # (B,nc,H)

    def step(carry, inp):
        S_p, n_p, m_p = carry                           # (B,H,D,D),(B,H,D),(B,H)
        kc, vc, wc, mc, tot = inp
        m_new = jnp.maximum(m_p + tot, mc)              # (B,H)
        scale_old = jnp.exp(m_p + tot - m_new)
        wts = jnp.exp(wc - m_new[:, None, :])           # (B,Q,H)
        S_new = S_p * scale_old[:, :, None, None] + jnp.einsum(
            "bqh,bqhk,bqhv->bhkv", wts, kc, vc
        )
        n_new = n_p * scale_old[:, :, None] + jnp.einsum("bqh,bqhk->bhk", wts, kc)
        return (S_new, n_new, m_new), (S_p, n_p, m_p)

    init = (
        jnp.zeros((B, H, D, D), f32),
        jnp.zeros((B, H, D), f32),
        jnp.full((B, H), -jnp.inf, f32),
    )
    xs = (
        jnp.moveaxis(kk, 1, 0),
        jnp.moveaxis(vv, 1, 0),
        jnp.moveaxis(w, 1, 0),
        jnp.moveaxis(m_chunk, 1, 0),
        jnp.moveaxis(total, 1, 0),
    )
    (S_f, n_f, m_f), (S_prev, n_prev, m_prev) = jax.lax.scan(step, init, xs)
    S_prev = jnp.moveaxis(S_prev, 0, 1)                 # (B,nc,H,D,D)
    n_prev = jnp.moveaxis(n_prev, 0, 1)                 # (B,nc,H,D)
    m_prev = jnp.moveaxis(m_prev, 0, 1)                 # (B,nc,H)

    # per-position stabilizer: inter weight is m_prev + b_i
    m_i = jnp.maximum(m_prev[:, :, None, :] + b, m_intra)   # (B,nc,Q,H)
    inter_scale = jnp.exp(m_prev[:, :, None, :] + b - m_i)  # (B,nc,Q,H)
    num_inter = jnp.einsum("bcqhk,bchkv->bcqhv", qq, S_prev) * inter_scale[..., None]
    den_inter = jnp.einsum("bcqhk,bchk->bcqh", qq, n_prev) * inter_scale

    intra_w = jnp.exp(diff - m_i[:, :, :, None, :])         # (B,nc,Q,Q,H)
    qk = jnp.einsum("bcihk,bcjhk->bcijh", qq, kk)
    num_intra = jnp.einsum("bcijh,bcijh,bcjhv->bcihv", qk, intra_w, vv)
    den_intra = jnp.einsum("bcijh,bcijh->bcih", qk, intra_w)

    num = num_inter + num_intra
    den = den_inter + den_intra
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
    return h.reshape(B, S, H, D).astype(q.dtype), (S_f, n_f, m_f)


def mlstm_decode_step(state, q, k, v, i_gate, f_gate):
    """One decode step.  state: (S (B,H,D,D), n (B,H,D), m (B,H));
    q,k,v (B,H,D); gates (B,H)."""
    f32 = jnp.float32
    S_p, n_p, m_p = state
    qf = q.astype(f32) / math.sqrt(q.shape[-1])
    logf = jax.nn.log_sigmoid(f_gate.astype(f32))
    ig = i_gate.astype(f32)
    m_new = jnp.maximum(logf + m_p, ig)
    scale_old = jnp.exp(logf + m_p - m_new)
    wt = jnp.exp(ig - m_new)
    S_new = S_p * scale_old[:, :, None, None] + wt[:, :, None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k.astype(f32), v.astype(f32)
    )
    n_new = n_p * scale_old[:, :, None] + wt[:, :, None] * k.astype(f32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, S_new)
    den = jnp.einsum("bhk,bhk->bh", qf, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (S_new, n_new, m_new)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm_block(b: ParamBuilder, name: str, cfg: ModelConfig):
    d = cfg.d_model
    dp = int(cfg.xlstm_proj_factor * d)
    b.add(f"{name}/up", (d, 2 * dp), ("embed", "xlstm_inner"))
    b.add(f"{name}/wq", (dp, dp), ("xlstm_inner", "xlstm_heads"))
    b.add(f"{name}/wk", (dp, dp), ("xlstm_inner", "xlstm_heads"))
    b.add(f"{name}/wv", (dp, dp), ("xlstm_inner", "xlstm_heads"))
    b.add(f"{name}/w_if", (dp, 2 * cfg.n_heads), ("xlstm_inner", "xlstm_heads"))
    b.add(f"{name}/out_scale", (dp,), ("xlstm_inner",), init="ones")
    b.add(f"{name}/down", (dp, d), ("xlstm_inner", "embed"))


def mlstm_block(params, name: str, cfg: ModelConfig, x, state=None):
    """x (B,S,d) -> (y (B,S,d), new_state)."""
    B, S, d = x.shape
    dt_ = x.dtype
    dp = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    D = dp // H

    up = jnp.einsum("bsd,dk->bsk", x, params[f"{name}/up"].astype(dt_))
    xm, z = jnp.split(up, 2, axis=-1)
    xm = constrain(xm, ("batch", "seq", "xlstm_inner"))
    q = jnp.einsum("bsk,kj->bsj", xm, params[f"{name}/wq"].astype(dt_)).reshape(B, S, H, D)
    k = jnp.einsum("bsk,kj->bsj", xm, params[f"{name}/wk"].astype(dt_)).reshape(B, S, H, D)
    v = jnp.einsum("bsk,kj->bsj", xm, params[f"{name}/wv"].astype(dt_)).reshape(B, S, H, D)
    gates = jnp.einsum("bsk,kj->bsj", xm, params[f"{name}/w_if"].astype(dt_))
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)       # (B,S,H) each

    if state is None:
        h, _ = mlstm_chunked(q, k, v, i_gate, f_gate, cfg.xlstm_chunk)
        new_state = None
    else:
        h1, new_state = mlstm_decode_step(
            state, q[:, 0], k[:, 0], v[:, 0], i_gate[:, 0], f_gate[:, 0]
        )
        h = h1[:, None]
    h = h.reshape(B, S, dp)
    h = h * jax.nn.silu(z)
    h = h * params[f"{name}/out_scale"].astype(dt_)
    h = constrain(h, ("batch", "seq", "xlstm_inner"))
    y = jnp.einsum("bsk,kd->bsd", h, params[f"{name}/down"].astype(dt_))
    return constrain(y, ("batch", "seq", "embed")), new_state


def mlstm_state_shapes(cfg: ModelConfig, batch: int) -> dict:
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    D = dp // H
    return {"S": (batch, H, D, D), "n": (batch, H, D), "m": (batch, H)}


# ---------------------------------------------------------------------------
# sLSTM block (sequential scalar recurrence with block-diagonal R)
# ---------------------------------------------------------------------------


def init_slstm_block(b: ParamBuilder, name: str, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    b.add(f"{name}/w_in", (d, 4 * d), ("embed", "xlstm_inner"))
    b.add(f"{name}/r", (4, H, dh, dh), (None, "xlstm_heads", None, None),
          scale=1.0 / math.sqrt(dh))
    b.add(f"{name}/bias", (4 * d,), ("xlstm_inner",), init="zeros")
    ff = max(int(4 * d / 3), 1)
    b.add(f"{name}/ff_gate", (d, ff), ("embed", "mlp"))
    b.add(f"{name}/ff_up", (d, ff), ("embed", "mlp"))
    b.add(f"{name}/ff_down", (ff, d), ("mlp", "embed"))


def slstm_block(params, name: str, cfg: ModelConfig, x, state=None):
    """sLSTM with exp gating + stabilizer state (B,S,d); lax.scan over S."""
    B, S, d = x.shape
    dt_ = x.dtype
    H = cfg.n_heads
    dh = d // H
    f32 = jnp.float32

    pre = jnp.einsum("bsd,dk->bsk", x, params[f"{name}/w_in"].astype(dt_))
    pre = pre + params[f"{name}/bias"].astype(dt_)
    pre = pre.reshape(B, S, 4, H, dh).astype(f32)
    R = params[f"{name}/r"].astype(f32)                  # (4,H,dh,dh)

    if state is None:
        c0 = jnp.zeros((B, H, dh), f32)
        n0 = jnp.zeros((B, H, dh), f32)
        h0 = jnp.zeros((B, H, dh), f32)
        m0 = jnp.zeros((B, H, dh), f32)
    else:
        c0, n0, h0, m0 = state

    def step(carry, pre_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhj,ghjk->bghk", h, R)         # (B,4,H,dh)
        zt = jnp.tanh(pre_t[:, 0] + rec[:, 0])
        it = pre_t[:, 1] + rec[:, 1]
        ft = pre_t[:, 2] + rec[:, 2]
        ot = jax.nn.sigmoid(pre_t[:, 3] + rec[:, 3])
        m_new = jnp.maximum(ft + m, it)                  # stabilizer
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    (cf, nf, hf, mf), hs = jax.lax.scan(
        step, (c0, n0, h0, m0), jnp.moveaxis(pre, 1, 0)
    )
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(dt_)

    # post-recurrence gated FFN (proj factor 4/3, per the paper's sLSTM block)
    gate = jnp.einsum("bsd,df->bsf", y, params[f"{name}/ff_gate"].astype(dt_))
    upv = jnp.einsum("bsd,df->bsf", y, params[f"{name}/ff_up"].astype(dt_))
    hmid = jax.nn.gelu(gate) * upv
    hmid = constrain(hmid, ("batch", "seq", "mlp"))
    out = jnp.einsum("bsf,fd->bsd", hmid, params[f"{name}/ff_down"].astype(dt_))
    new_state = (cf, nf, hf, mf) if state is not None else None
    return constrain(out, ("batch", "seq", "embed")), new_state


def slstm_state_shapes(cfg: ModelConfig, batch: int) -> tuple:
    H = cfg.n_heads
    dh = cfg.d_model // H
    s = (batch, H, dh)
    return (s, s, s, s)
