"""Model substrate: config + functional param system with logical axes.

No flax/haiku — params are plain pytrees built by ``init`` functions that
also emit a parallel pytree of *logical axis names* per parameter.  The
sharding layer (:mod:`repro.parallel.sharding`) maps logical axes to mesh
axes, MaxText-style, so the same model code runs on any mesh.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """One decoder-only architecture (all ten assigned archs fit here)."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- gemma2-style alternating local/global attention ---------------------
    sliding_window: int = 0        # 0 -> full attention everywhere
    alt_local_global: bool = False  # even layers local, odd layers global
    attn_softcap: float = 0.0      # tanh soft-capping on attention logits
    final_softcap: float = 0.0     # tanh soft-capping on final logits

    # --- SSM / hybrid (zamba2) ------------------------------------------------
    ssm_state: int = 0             # Mamba2 state dim (N)
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn block every k SSM layers

    # --- xLSTM ------------------------------------------------------------------
    xlstm_slstm_every: int = 2     # every k-th block is sLSTM (rest mLSTM)
    xlstm_proj_factor: float = 2.0
    xlstm_chunk: int = 128

    # --- VLM (qwen2-vl) ------------------------------------------------------------
    mrope_sections: tuple[int, ...] = ()   # (t, h, w) split of head_dim/2

    # --- modality frontend stub --------------------------------------------------
    embed_inputs: bool = False     # True: inputs are precomputed embeddings

    # --- numerics / impl ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    remat: bool = True
    attn_impl: str = "chunked"     # chunked (online-softmax) | reference | pallas
    attn_chunk: int = 512          # query-chunk for the chunked path
    seq_parallel: bool = True      # shard the residual stream's seq dim over TP
    tie_embeddings: bool = False
    logit_dtype: str = "bfloat16"  # dtype of loss logits (vocab-sharded)
    loss_chunk: int = 0            # 0 -> unchunked; else seq-chunked loss

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter count (docs/roofline MODEL_FLOPS term).
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if self.family == "moe":
                ffn = 3 * d * self.d_ff * (self.n_experts + self.n_shared_experts) + d * self.n_experts
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            ssm = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * d
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            mlp = 3 * d * self.d_ff
            n_attn = self.n_layers // max(self.attn_every, 1)
            per_layer = ssm  # per SSM layer
            return embed + self.n_layers * per_layer + (attn + mlp)  # shared attn counted once
        elif self.family == "ssm":
            dp = int(self.xlstm_proj_factor * d)
            per_layer = 2 * d * dp + 3 * dp * dp // max(self.n_heads, 1) + dp * d
        return embed + self.n_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        # shared experts are already in `total` and always active; only the
        # routed experts collapse from n_experts to top_k.
        ffn_routed_all = 3 * d * self.d_ff * self.n_experts * self.n_layers
        ffn_routed_active = 3 * d * self.d_ff * self.top_k * self.n_layers
        return total - ffn_routed_all + ffn_routed_active


# ---------------------------------------------------------------------------
# Param construction with logical axes
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Collects (param, logical_axes) pairs under nested name scopes."""

    def __init__(self, key: jax.Array, param_dtype=jnp.float32):
        self._key = key
        self.params: dict[str, Any] = {}
        self.specs: dict[str, Any] = {}
        self.param_dtype = param_dtype

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name: str, shape, axes: tuple, init: str = "normal", scale: float | None = None):
        assert len(shape) == len(axes), (name, shape, axes)
        dtype = self.param_dtype
        if init == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif init == "ones":
            arr = jnp.ones(shape, dtype)
        elif init == "normal":
            fan_in = shape[0] if len(shape) >= 1 else 1
            s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            arr = jax.random.normal(self.next_key(), shape, dtype) * s
        elif init == "embed":
            arr = jax.random.normal(self.next_key(), shape, dtype) * (scale or 1.0)
        else:
            raise ValueError(init)
        self.params[name] = arr
        self.specs[name] = axes
        return arr

    def scope(self, name: str) -> "ScopedBuilder":
        return ScopedBuilder(self, name)

    def build(self):
        return self.params, self.specs


class ScopedBuilder:
    def __init__(self, parent, name):
        self.parent = parent
        self.name = name

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False

    def add(self, name, shape, axes, init="normal", scale=None):
        return self.parent.add(f"{self.name}/{name}", shape, axes, init, scale)


def stack_params(per_layer: list[tuple[dict, dict]]) -> tuple[dict, dict]:
    """Stack L per-layer param dicts along a leading 'layers' axis (scan)."""
    if not per_layer:
        return {}, {}
    keys = per_layer[0][0].keys()
    params = {
        k: jnp.stack([pl[0][k] for pl in per_layer], axis=0) for k in keys
    }
    specs = {k: ("layers",) + tuple(per_layer[0][1][k]) for k in keys}
    return params, specs
