"""Pure-JAX model zoo for the ten assigned architectures."""
from .common import ModelConfig, ParamBuilder, stack_params
from .model import Model

__all__ = ["Model", "ModelConfig", "ParamBuilder", "stack_params"]
