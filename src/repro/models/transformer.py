"""Decoder assembly: scan-over-layers for every family.

Training/prefill use ``jax.lax.scan`` over stacked per-layer params (HLO
size independent of depth — essential for 60+ layer archs), with optional
per-layer remat.  Decode uses an unrolled loop over layers (tiny per-layer
graphs, per-layer cache slices are simpler and XLA fuses them well).

Families:
  dense   — [attn, mlp] x L     (gemma2: alternating sliding window + softcap)
  moe     — [attn, moe] x L     (optional shared expert)
  hybrid  — zamba2: Mamba2 backbone + ONE shared attn+mlp block applied
            every ``attn_every`` layers (weights shared across positions)
  ssm     — xLSTM: mLSTM blocks with an sLSTM every ``xlstm_slstm_every``
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .common import ModelConfig, ParamBuilder, stack_params
from .layers import (
    attention,
    init_attention,
    init_mlp,
    init_moe,
    init_rmsnorm,
    mlp,
    moe,
    rmsnorm,
)
from .ssm import init_mamba2, mamba2_block, mamba2_state_shapes
from .xlstm import (
    init_mlstm_block,
    init_slstm_block,
    mlstm_block,
    mlstm_state_shapes,
    slstm_block,
    slstm_state_shapes,
)

# ---------------------------------------------------------------------------
# Per-layer inits
# ---------------------------------------------------------------------------


def _init_dense_layer(key, cfg: ModelConfig):
    b = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
    init_rmsnorm(b, "ln_attn", cfg.d_model)
    init_attention(b, "attn", cfg)
    init_rmsnorm(b, "ln_mlp", cfg.d_model)
    if cfg.family == "moe":
        init_moe(b, "moe", cfg)
    else:
        init_mlp(b, "mlp", cfg.d_model, cfg.d_ff)
    return b.build()


def _init_mamba_layer(key, cfg: ModelConfig):
    b = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
    init_rmsnorm(b, "ln", cfg.d_model)
    init_mamba2(b, "mamba", cfg)
    return b.build()


def _init_xlstm_unit(key, cfg: ModelConfig):
    """One scan unit: (xlstm_slstm_every - 1) mLSTM blocks + 1 sLSTM block."""
    b = ParamBuilder(key, jnp.dtype(cfg.param_dtype))
    for i in range(cfg.xlstm_slstm_every - 1):
        init_rmsnorm(b, f"ln_m{i}", cfg.d_model)
        init_mlstm_block(b, f"mlstm{i}", cfg)
    init_rmsnorm(b, "ln_s", cfg.d_model)
    init_slstm_block(b, "slstm", cfg)
    return b.build()


def init_blocks(key, cfg: ModelConfig) -> tuple[dict, dict]:
    """Stacked block params + the shared (non-stacked) extras."""
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        keys = jax.random.split(key, cfg.n_layers)
        stacked, st_specs = stack_params(
            [_init_dense_layer(k, cfg) for k in keys]
        )
        params.update({f"blocks/{k}": v for k, v in stacked.items()})
        specs.update({f"blocks/{k}": v for k, v in st_specs.items()})
    elif cfg.family == "hybrid":
        keys = jax.random.split(key, cfg.n_layers + 1)
        stacked, st_specs = stack_params(
            [_init_mamba_layer(k, cfg) for k in keys[:-1]]
        )
        params.update({f"blocks/{k}": v for k, v in stacked.items()})
        specs.update({f"blocks/{k}": v for k, v in st_specs.items()})
        shared, sh_specs = _init_dense_layer(keys[-1], cfg.replace(family="dense"))
        params.update({f"shared_attn/{k}": v for k, v in shared.items()})
        specs.update({f"shared_attn/{k}": v for k, v in sh_specs.items()})
    elif cfg.family == "ssm":
        every = max(cfg.xlstm_slstm_every, 1)
        n_units = cfg.n_layers // every
        keys = jax.random.split(key, max(n_units, 1))
        stacked, st_specs = stack_params(
            [_init_xlstm_unit(k, cfg) for k in keys[:n_units]]
        )
        params.update({f"blocks/{k}": v for k, v in stacked.items()})
        specs.update({f"blocks/{k}": v for k, v in st_specs.items()})
    else:
        raise ValueError(cfg.family)
    return params, specs


# ---------------------------------------------------------------------------
# Forward (train / prefill): scan over layers
# ---------------------------------------------------------------------------


def _layer_windows(cfg: ModelConfig) -> Optional[jnp.ndarray]:
    """Per-layer sliding window sizes (0 = full attention)."""
    if not cfg.sliding_window:
        return None
    if cfg.alt_local_global:
        return jnp.asarray(
            [cfg.sliding_window if i % 2 == 0 else 0 for i in range(cfg.n_layers)],
            jnp.int32,
        )
    return jnp.asarray([cfg.sliding_window] * cfg.n_layers, jnp.int32)


def _split_stacked(params: dict, prefix: str, dtype=None) -> dict:
    """Extract a sub-dict; optionally cast floating params to the compute
    dtype ONCE here, so FSDP all-gathers inside the scan move bf16, not
    the fp32 master copies (2x collective volume otherwise)."""
    plen = len(prefix)
    out = {k[plen:]: v for k, v in params.items() if k.startswith(prefix)}
    if dtype is not None:
        out = {
            k: (v.astype(dtype) if jnp.issubdtype(v.dtype, jnp.floating) else v)
            for k, v in out.items()
        }
    return out


def _residual(cfg, x):
    """Sequence-parallel residual stream: shard seq over the TP axis
    between blocks (Megatron SP) so saved scan carries are 1/TP sized."""
    if cfg.seq_parallel:
        return constrain(x, ("batch", "residual_seq", "embed"))
    return x


def _dense_block(layer_params, cfg, x, positions, window, collect_kv):
    x = _residual(cfg, x)
    h = rmsnorm(layer_params, "ln_attn", x, cfg.norm_eps)
    attn_out, kv = attention(
        layer_params, "attn", cfg, h, positions, window=window,
        collect_kv=collect_kv,
    )
    x = _residual(cfg, x + attn_out)
    h = rmsnorm(layer_params, "ln_mlp", x, cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe(layer_params, "moe", cfg, h)
    else:
        x = x + mlp(layer_params, "mlp", h)
    return _residual(cfg, x), kv


def forward_blocks(params, cfg: ModelConfig, x, positions, collect_kv=False):
    """x: (B,S,d) post-embedding.  Returns (y, caches-or-None)."""
    B, S, d = x.shape

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        stacked = _split_stacked(params, "blocks/", cfg.compute_dtype)
        windows = _layer_windows(cfg)

        def body(carry, xs):
            lp = xs["params"]
            window = xs.get("window")
            y, kv = _dense_block(lp, cfg, carry, positions, window, collect_kv)
            return y, (kv if collect_kv else 0)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = {"params": stacked}
        if windows is not None:
            xs["window"] = windows
        y, kvs = jax.lax.scan(body, x, xs)
        return y, (kvs if collect_kv else None)

    if cfg.family == "hybrid":
        stacked = _split_stacked(params, "blocks/", cfg.compute_dtype)
        shared = _split_stacked(params, "shared_attn/", cfg.compute_dtype)
        every = max(cfg.attn_every, 1)

        def body(carry, xs):
            lp, idx = xs
            carry = _residual(cfg, carry)
            h = rmsnorm(lp, "ln", carry, cfg.norm_eps)
            out, _ = mamba2_block(lp, "mamba", cfg, h)
            y = _residual(cfg, carry + out)

            def with_attn(y):
                r, _ = _dense_block(shared, cfg, y, positions, None, False)
                return r

            apply_attn = (idx % every) == (every - 1)
            y = jax.lax.cond(apply_attn, with_attn, lambda y: y, y)
            return y, 0

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        y, _ = jax.lax.scan(
            body, x, (stacked, jnp.arange(cfg.n_layers, dtype=jnp.int32))
        )
        return y, None

    if cfg.family == "ssm":
        stacked = _split_stacked(params, "blocks/", cfg.compute_dtype)
        every = max(cfg.xlstm_slstm_every, 1)

        def body(carry, lp):
            y = _residual(cfg, carry)
            for i in range(every - 1):
                h = rmsnorm(lp, f"ln_m{i}", y, cfg.norm_eps)
                out, _ = mlstm_block(lp, f"mlstm{i}", cfg, h)
                y = _residual(cfg, y + out)
            h = rmsnorm(lp, "ln_s", y, cfg.norm_eps)
            out, _ = slstm_block(lp, "slstm", cfg, h)
            y = _residual(cfg, y + out)
            return y, 0

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        y, _ = jax.lax.scan(body, x, stacked)
        return y, None

    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Decode: unrolled layer loop over per-layer cache slices
# ---------------------------------------------------------------------------


def decode_blocks(params, cfg: ModelConfig, x, positions, cache: dict, cache_pos):
    """One decode step.  x: (B,1,d).  cache: stacked per-layer dict.
    Returns (y, new_cache)."""
    B = x.shape[0]
    new_cache = {k: v for k, v in cache.items()}

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        stacked = _split_stacked(params, "blocks/")
        windows = _layer_windows(cfg)
        split_cache = "k_loc" in cache   # gemma2: window-sized ring caches
        loc_slot = glob_slot = 0
        for i in range(cfg.n_layers):
            lp = {k: v[i] for k, v in stacked.items()}
            is_local = bool(cfg.alt_local_global and i % 2 == 0)
            if split_cache and is_local:
                layer_cache = {"k": cache["k_loc"][loc_slot],
                               "v": cache["v_loc"][loc_slot], "ring": True}
            else:
                layer_cache = {"k": cache["k"][glob_slot], "v": cache["v"][glob_slot]}
            window = None if windows is None else windows[i]
            h = rmsnorm(lp, "ln_attn", x, cfg.norm_eps)
            attn_out, upd = attention(
                lp, "attn", cfg, h, positions, window=window,
                cache=layer_cache, cache_pos=cache_pos,
            )
            if split_cache and is_local:
                new_cache["k_loc"] = new_cache["k_loc"].at[loc_slot].set(upd["k"])
                new_cache["v_loc"] = new_cache["v_loc"].at[loc_slot].set(upd["v"])
                loc_slot += 1
            else:
                new_cache["k"] = new_cache["k"].at[glob_slot].set(upd["k"])
                new_cache["v"] = new_cache["v"].at[glob_slot].set(upd["v"])
                glob_slot += 1
            x = x + attn_out
            h = rmsnorm(lp, "ln_mlp", x, cfg.norm_eps)
            if cfg.family == "moe":
                x = x + moe(lp, "moe", cfg, h)
            else:
                x = x + mlp(lp, "mlp", h)
        return x, new_cache

    if cfg.family == "hybrid":
        stacked = _split_stacked(params, "blocks/")
        shared = _split_stacked(params, "shared_attn/")
        every = max(cfg.attn_every, 1)
        attn_slot = 0
        for i in range(cfg.n_layers):
            lp = {k: v[i] for k, v in stacked.items()}
            h = rmsnorm(lp, "ln", x, cfg.norm_eps)
            st = {"ssm": cache["ssm"][i], "conv": cache["conv"][i]}
            out, new_st = mamba2_block(lp, "mamba", cfg, h, state=st)
            new_cache["ssm"] = new_cache["ssm"].at[i].set(new_st["ssm"])
            new_cache["conv"] = new_cache["conv"].at[i].set(new_st["conv"])
            x = x + out
            if (i % every) == (every - 1):
                layer_cache = {
                    "k": cache["attn_k"][attn_slot],
                    "v": cache["attn_v"][attn_slot],
                }
                h = rmsnorm(shared, "ln_attn", x, cfg.norm_eps)
                attn_out, upd = attention(
                    shared, "attn", cfg, h, positions,
                    cache=layer_cache, cache_pos=cache_pos,
                )
                new_cache["attn_k"] = new_cache["attn_k"].at[attn_slot].set(upd["k"])
                new_cache["attn_v"] = new_cache["attn_v"].at[attn_slot].set(upd["v"])
                x = x + attn_out
                h = rmsnorm(shared, "ln_mlp", x, cfg.norm_eps)
                x = x + mlp(shared, "mlp", h)
                attn_slot += 1
        return x, new_cache

    if cfg.family == "ssm":
        stacked = _split_stacked(params, "blocks/")
        every = max(cfg.xlstm_slstm_every, 1)
        n_units = cfg.n_layers // every
        for u in range(n_units):
            lp = {k: v[u] for k, v in stacked.items()}
            for i in range(every - 1):
                h = rmsnorm(lp, f"ln_m{i}", x, cfg.norm_eps)
                st = (
                    cache["mlstm_S"][u, i],
                    cache["mlstm_n"][u, i],
                    cache["mlstm_m"][u, i],
                )
                out, new_st = mlstm_block(lp, f"mlstm{i}", cfg, h, state=st)
                new_cache["mlstm_S"] = new_cache["mlstm_S"].at[u, i].set(new_st[0])
                new_cache["mlstm_n"] = new_cache["mlstm_n"].at[u, i].set(new_st[1])
                new_cache["mlstm_m"] = new_cache["mlstm_m"].at[u, i].set(new_st[2])
                x = x + out
            h = rmsnorm(lp, "ln_s", x, cfg.norm_eps)
            names = ("slstm_c", "slstm_n", "slstm_h", "slstm_m")
            st = tuple(cache[nm][u] for nm in names)
            out, new_st = slstm_block(lp, "slstm", cfg, h, state=st)
            for j, nm in enumerate(names):
                new_cache[nm] = new_cache[nm].at[u].set(new_st[j])
            x = x + out
        return x, new_cache

    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Abstract cache spec: name -> (shape, dtype, logical_axes, fill)."""
    dt = cfg.dtype
    out: dict[str, tuple] = {}
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if cfg.alt_local_global and 0 < cfg.sliding_window < max_len:
            # gemma2: local layers only ever see the last `window` tokens;
            # give them window-sized ring caches (2x decode-cache saving,
            # ~128x for long_500k local layers — EXPERIMENTS.md §Perf).
            n_loc = sum(1 for i in range(cfg.n_layers) if i % 2 == 0)
            n_glob = cfg.n_layers - n_loc
            out["k_loc"] = ((n_loc, batch, cfg.sliding_window, cfg.n_kv_heads, cfg.hd),
                            dt, kv_axes, 0.0)
            out["v_loc"] = ((n_loc, batch, cfg.sliding_window, cfg.n_kv_heads, cfg.hd),
                            dt, kv_axes, 0.0)
            shape = (n_glob, batch, max_len, cfg.n_kv_heads, cfg.hd)
            out["k"] = (shape, dt, kv_axes, 0.0)
            out["v"] = (shape, dt, kv_axes, 0.0)
            return out
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        out["k"] = (shape, dt, kv_axes, 0.0)
        out["v"] = (shape, dt, kv_axes, 0.0)
    elif cfg.family == "hybrid":
        ssm = mamba2_state_shapes(cfg, batch)
        L = cfg.n_layers
        out["ssm"] = ((L,) + ssm["ssm"], "float32",
                      ("layers", "batch", "ssm_heads", "ssm_state", None), 0.0)
        out["conv"] = ((L,) + ssm["conv"], dt,
                       ("layers", "batch", None, "ssm_inner"), 0.0)
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        shape = (n_attn, batch, max_len, cfg.n_kv_heads, cfg.hd)
        out["attn_k"] = (shape, dt, kv_axes, 0.0)
        out["attn_v"] = (shape, dt, kv_axes, 0.0)
    elif cfg.family == "ssm":
        every = max(cfg.xlstm_slstm_every, 1)
        n_units = cfg.n_layers // every
        m = mlstm_state_shapes(cfg, batch)
        out["mlstm_S"] = ((n_units, every - 1) + m["S"], "float32",
                          ("layers", None, "batch", "xlstm_heads", None, None), 0.0)
        out["mlstm_n"] = ((n_units, every - 1) + m["n"], "float32",
                          ("layers", None, "batch", "xlstm_heads", None), 0.0)
        # stabilizer must start at -inf to match the chunked-train scan init
        out["mlstm_m"] = ((n_units, every - 1) + m["m"], "float32",
                          ("layers", None, "batch", "xlstm_heads"), -jnp.inf)
        s = slstm_state_shapes(cfg, batch)[0]
        for nm in ("slstm_c", "slstm_n", "slstm_h", "slstm_m"):
            out[nm] = ((n_units,) + s, "float32",
                       ("layers", "batch", "xlstm_heads", None), 0.0)
    return out
