"""Model facade: init / loss / prefill / decode for every assigned arch.

The facade is purely functional; the training and serving step builders
(:mod:`repro.train.steps`) close over it and add sharding + optimizer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .common import ModelConfig, ParamBuilder
from .layers import init_rmsnorm, rmsnorm
from .transformer import (
    decode_blocks,
    forward_blocks,
    init_blocks,
    init_cache_shapes,
)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- init --
    def init(self, key: jax.Array) -> tuple[dict, dict]:
        cfg = self.cfg
        ke, kb, kh = jax.random.split(key, 3)
        b = ParamBuilder(ke, jnp.dtype(cfg.param_dtype))
        if not cfg.embed_inputs:
            b.add("embed/table", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                  init="embed", scale=0.02)
        init_rmsnorm(b, "final_norm", cfg.d_model)
        if not cfg.tie_embeddings:
            b.add("head/w", (cfg.d_model, cfg.vocab), ("embed", "vocab"),
                  init="normal")
        params, specs = b.build()
        bp, bs = init_blocks(kb, cfg)
        params.update(bp)
        specs.update(bs)
        return params, specs

    def abstract_params(self, key: Optional[jax.Array] = None) -> tuple[dict, dict]:
        """Shape/dtype-only params (no allocation) + logical specs."""
        captured: dict = {}

        def fn(k):
            p, s = self.init(k)
            captured.update(s)  # specs are static python; capture at trace time
            return p

        shapes = jax.eval_shape(fn, jax.random.key(0))
        return shapes, dict(captured)

    # -------------------------------------------------------------- forward --
    def embed(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        if cfg.embed_inputs:
            x = batch["embeds"].astype(cfg.compute_dtype)
        else:
            table = params["embed/table"]
            x = jnp.take(table, batch["tokens"], axis=0).astype(cfg.compute_dtype)
        return constrain(x, ("batch", "seq", "embed"))

    def logits(self, params: dict, y: jax.Array) -> jax.Array:
        cfg = self.cfg
        y = rmsnorm(params, "final_norm", y, cfg.norm_eps)
        w = (params["embed/table"].T if cfg.tie_embeddings else params["head/w"])
        logits = jnp.einsum(
            "bsd,dv->bsv", y, w.astype(cfg.compute_dtype)
        ).astype(jnp.dtype(cfg.logit_dtype))
        if cfg.final_softcap > 0:
            logits = cfg.final_softcap * jnp.tanh(
                logits.astype(jnp.float32) / cfg.final_softcap
            ).astype(logits.dtype)
        return constrain(logits, ("batch", "seq", "vocab"))

    def forward(self, params: dict, batch: dict, collect_kv: bool = False):
        x = self.embed(params, batch)
        positions = batch.get("positions")
        if positions is None:
            B, S = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        y, caches = forward_blocks(params, self.cfg, x, positions, collect_kv)
        return self.logits(params, y), caches

    # ------------------------------------------------------------------ loss --
    def loss(self, params: dict, batch: dict) -> jax.Array:
        """Mean next-token cross entropy; labels < 0 are masked."""
        cfg = self.cfg
        logits, _ = self.forward(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)

        def xent(lg, lb, mk):
            lg = lg.astype(jnp.float32)
            lse = jax.nn.logsumexp(lg, axis=-1)
            picked = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - picked) * mk), jnp.sum(mk)

        if cfg.loss_chunk and logits.shape[1] % cfg.loss_chunk == 0:
            # Sequence-chunked loss: bounds the fp32 (B, S, V) materialization.
            nch = logits.shape[1] // cfg.loss_chunk
            B = logits.shape[0]
            lg = logits.reshape(B, nch, cfg.loss_chunk, -1)
            lb = labels.reshape(B, nch, cfg.loss_chunk)
            mk = mask.reshape(B, nch, cfg.loss_chunk)

            def body(carry, xs):
                s, c = carry
                ls, cnt = xent(xs[0], xs[1], xs[2])
                return (s + ls, c + cnt), 0

            (tot, cnt), _ = jax.lax.scan(
                body,
                (jnp.float32(0), jnp.float32(0)),
                (jnp.moveaxis(lg, 1, 0), jnp.moveaxis(lb, 1, 0), jnp.moveaxis(mk, 1, 0)),
            )
            return tot / jnp.maximum(cnt, 1.0)
        tot, cnt = xent(logits, labels, mask)
        return tot / jnp.maximum(cnt, 1.0)

    # ---------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int) -> dict:
        shapes = init_cache_shapes(self.cfg, batch, max_len)
        return {
            name: jnp.full(shape, fill, jnp.dtype(dt))
            for name, (shape, dt, _axes, fill) in shapes.items()
        }

    def cache_logical_axes(self, batch: int, max_len: int) -> dict:
        shapes = init_cache_shapes(self.cfg, batch, max_len)
        return {name: axes for name, (_s, _d, axes, _f) in shapes.items()}

    def decode_step(self, params: dict, cache: dict, batch: dict):
        """One token for every sequence.  batch: tokens/embeds (B,1),
        positions (B,1) or (3,B,1), cache_pos () int32."""
        x = self.embed(params, batch)
        positions = batch["positions"]
        y, new_cache = decode_blocks(
            params, self.cfg, x, positions, cache, batch["cache_pos"]
        )
        return self.logits(params, y), new_cache
