"""Mamba2 (SSD) mixing layer — the zamba2 backbone block.

Implements the chunked "state-space dual" algorithm (Mamba-2,
arXiv:2405.21060): within a chunk the recurrence is evaluated as a masked
decay-weighted attention (quadratic in the chunk length, MXU-friendly);
across chunks a small scan carries the (H, N, P) state.  The same
function is the pure-jnp oracle for the ``ssd_scan`` Pallas kernel.

Shapes: B batch, S seq, H ssm heads, P head dim, N state dim, Q chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .common import ModelConfig, ParamBuilder


# ---------------------------------------------------------------------------
# Chunked SSD scan (shared reference for the Pallas kernel)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int):
    """Chunked SSD: y_t = C_t . S_t,  S_t = exp(A dt_t) S_{t-1} + dt_t B_t x_t^T.

    Args:
      x:    (B, S, H, P) input heads
      dt:   (B, S, H)    positive step sizes (already softplus'ed)
      A:    (H,)         negative per-head decay rates
      Bmat: (B, S, N)    input projection (shared across heads, like MQA)
      Cmat: (B, S, N)    output projection
      chunk: Q, chunk length (S % Q == 0)
    Returns: y (B, S, H, P), final_state (B, H, N, P)
    """
    B, S, H, P = x.shape
    N = Bmat.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    f32 = jnp.float32
    xq = x.reshape(B, nc, Q, H, P)
    dtq = dt.reshape(B, nc, Q, H).astype(f32)
    Bq = Bmat.reshape(B, nc, Q, N)
    Cq = Cmat.reshape(B, nc, Q, N)

    dA = dtq * A.astype(f32)                       # (B,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative log decay

    # ---- intra-chunk (quadratic, causal) ----------------------------------
    # decay(i,j) = exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cq.astype(f32), Bq.astype(f32))
    w = cb[..., None] * decay * dtq[:, :, None, :, :]             # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xq.astype(f32))

    # ---- chunk summaries ---------------------------------------------------
    total = cum[:, :, -1:, :]                                     # (B,nc,1,H)
    rem = jnp.exp(total - cum)                                    # decay to chunk end
    # state contributed by chunk c: sum_j rem_j dt_j B_j x_j^T -> (B,nc,H,N,P)
    contrib = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchnp", rem * dtq, Bq.astype(f32), xq.astype(f32)
    )

    # ---- inter-chunk scan ----------------------------------------------------
    chunk_decay = jnp.exp(total[:, :, 0, :])                      # (B,nc,H)

    def step(state, inp):
        dec, con = inp                                            # (B,H), (B,H,N,P)
        new = state * dec[:, :, None, None] + con
        return new, state                                         # emit state BEFORE chunk

    init = jnp.zeros((B, H, N, P), f32)
    final, prev_states = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(contrib, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (B,nc,H,N,P)

    # ---- inter-chunk contribution to outputs ---------------------------------
    # y_inter_i = exp(cum_i) * C_i . S_prev
    y_inter = jnp.einsum(
        "bcin,bchnp->bcihp", Cq.astype(f32), prev_states
    ) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, Bmat, Cmat):
    """Single-token SSD update.  state: (B,H,N,P); x: (B,H,P); dt: (B,H);
    Bmat/Cmat: (B,N).  Returns (y (B,H,P), new_state)."""
    f32 = jnp.float32
    dtf = dt.astype(f32)
    decay = jnp.exp(dtf * A.astype(f32))                          # (B,H)
    outer = jnp.einsum("bn,bhp->bhnp", Bmat.astype(f32), x.astype(f32))
    new_state = state * decay[:, :, None, None] + dtf[:, :, None, None] * outer
    y = jnp.einsum("bn,bhnp->bhp", Cmat.astype(f32), new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------


def init_mamba2(b: ParamBuilder, name: str, cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    b.add(f"{name}/in_proj", (d, 2 * d_in + 2 * N + H), ("embed", "ssm_inner"))
    b.add(f"{name}/conv_w", (cfg.ssm_conv_width, d_in + 2 * N), ("conv", "ssm_inner"))
    b.add(f"{name}/conv_b", (d_in + 2 * N,), ("ssm_inner",), init="zeros")
    b.add(f"{name}/A_log", (H,), ("ssm_heads",), init="zeros")
    b.add(f"{name}/D", (H,), ("ssm_heads",), init="ones")
    b.add(f"{name}/dt_bias", (H,), ("ssm_heads",), init="zeros")
    b.add(f"{name}/norm_scale", (d_in,), ("ssm_inner",), init="ones")
    b.add(f"{name}/out_proj", (d_in, d), ("ssm_inner", "embed"))


def _causal_conv(x, w, b, state=None):
    """Causal depthwise conv; x (B,S,C), w (K,C).  With ``state`` (B,K-1,C)
    runs one decode step (S==1) and returns the updated state."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : K - 1])
        xp = jnp.concatenate([pad, x], axis=1)
        out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
        return jax.nn.silu(out + b), None
    xp = jnp.concatenate([state, x], axis=1)                      # (B,K,C)
    out = sum(xp[:, i : i + 1] * w[i] for i in range(K))
    return jax.nn.silu(out + b), xp[:, 1:]


def mamba2_block(params, name: str, cfg: ModelConfig, x, state=None):
    """x: (B,S,d).  state: None (training) or dict {ssm, conv} for decode.

    Returns (y (B,S,d), new_state).
    """
    B, S, d = x.shape
    dt_ = x.dtype
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state

    proj = jnp.einsum("bsd,dk->bsk", x, params[f"{name}/in_proj"].astype(dt_))
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    conv_in = xbc                                                 # (B,S,d_in+2N)
    conv_w = params[f"{name}/conv_w"].astype(dt_)
    conv_b = params[f"{name}/conv_b"].astype(dt_)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, conv_w, conv_b, conv_state)
    xs, Bmat, Cmat = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    xs = constrain(xs, ("batch", "seq", "ssm_inner"))
    xh = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params[f"{name}/dt_bias"].astype(jnp.float32)
    )                                                             # (B,S,H)
    A = -jnp.exp(params[f"{name}/A_log"].astype(jnp.float32))     # (H,)

    if state is None:
        y, _final = ssd_chunked(xh, dt, A, Bmat, Cmat, cfg.ssm_chunk)
        new_ssm = None
    else:
        y1, new_ssm = ssd_decode_step(
            state["ssm"], xh[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0]
        )
        y = y1[:, None]
    y = y + xh * params[f"{name}/D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, d_in)

    # gated RMSNorm (Mamba-2's norm-before-out)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps)
    y = (yf * params[f"{name}/norm_scale"].astype(jnp.float32)).astype(dt_)
    y = constrain(y, ("batch", "seq", "ssm_inner"))

    out = jnp.einsum("bsk,kd->bsd", y, params[f"{name}/out_proj"].astype(dt_))
    out = constrain(out, ("batch", "seq", "embed"))
    new_state = None
    if state is not None:
        new_state = {"ssm": new_ssm, "conv": new_conv}
    return out, new_state


def mamba2_state_shapes(cfg: ModelConfig, batch: int) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return {
        "ssm": (batch, H, cfg.ssm_state, cfg.ssm_head_dim),
        "conv": (batch, cfg.ssm_conv_width - 1, d_in + 2 * cfg.ssm_state),
    }
