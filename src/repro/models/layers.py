"""Transformer layer primitives: norm, RoPE/M-RoPE, GQA attention
(full / sliding-window / soft-capped), GLU MLP, and capacity-routed MoE.

Activation sharding is annotated with logical axes via
``repro.parallel.sharding.constrain`` so the same code lowers correctly
on any mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from .common import ModelConfig, ParamBuilder

# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------


def init_rmsnorm(b: ParamBuilder, name: str, d: int):
    b.add(f"{name}/scale", (d,), ("embed",), init="ones")


def rmsnorm(params, name: str, x, eps: float = 1e-6):
    scale = params[f"{name}/scale"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def rope(x, positions, theta: float = 10_000.0, sections: tuple[int, ...] = ()):
    """Rotary embedding.

    x: (B, S, H, D); positions: (B, S) int32, or (3, B, S) for M-RoPE with
    ``sections`` (t, h, w) summing to D//2 (Qwen2-VL §2.1).
    """
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)            # (half,)
    if sections:
        assert sum(sections) == half, (sections, half)
        assert positions.ndim == 3
        # Each frequency channel uses the position id of its section.
        sec_id = jnp.repeat(
            jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
        )                                              # (half,) in {0,1,2}
        pos = positions.astype(jnp.float32)            # (3, B, S)
        angle = pos[sec_id, :, :].transpose(1, 2, 0) * freqs  # (B, S, half)
    else:
        angle = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window + logit softcap)
# ---------------------------------------------------------------------------


def init_attention(b: ParamBuilder, name: str, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.hd
    b.add(f"{name}/wq", (d, cfg.n_heads, hd), ("embed", "heads", "head_dim"))
    b.add(f"{name}/wk", (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"))
    b.add(f"{name}/wv", (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"))
    b.add(f"{name}/wo", (cfg.n_heads, hd, d), ("heads", "head_dim", "embed"))


def _chunked_attention(cfg: ModelConfig, qg, k, v, mask):
    """Blockwise online-softmax attention (the flash-attention algorithm
    in pure jnp — also the oracle of the Pallas kernel).

    Scans over query chunks; per chunk the (Q_c, S_k) scores exist only
    transiently, so peak memory is O(S * chunk) instead of O(S^2).

    qg: (B, S, KV, G, hd); k/v: (B, S_k, KV, hd); mask: (1|B, 1, S, S_k).
    Returns (B, S, KV, G, hd).
    """
    B, S, KV, G, hd = qg.shape
    S_k = k.shape[1]
    C = cfg.attn_chunk
    nch = S // C
    scale = 1.0 / jnp.sqrt(hd).astype(qg.dtype)
    mask_b = jnp.broadcast_to(mask, (B, 1, S, S_k))[:, 0]      # (B,S,S_k)
    qs = jnp.moveaxis(qg.reshape(B, nch, C, KV, G, hd), 1, 0)  # (nch,B,C,KV,G,hd)
    ms = jnp.moveaxis(mask_b.reshape(B, nch, C, S_k), 1, 0)    # (nch,B,C,S_k)

    def chunk(carry, xs):
        qc, mc = xs
        s = jnp.einsum("bskgh,btkh->bkgst", qc * scale, k).astype(jnp.float32)
        if cfg.attn_softcap > 0:
            s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
        s = jnp.where(mc[:, None, None, :, :], s, jnp.float32(-1e30))
        p = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        o = jnp.einsum("bkgst,btkh->bskgh", p, v)
        return carry, o

    _, outs = jax.lax.scan(chunk, 0, (qs, ms))                 # (nch,B,C,KV,G,hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, KV, G, hd)


def attention(
    params,
    name: str,
    cfg: ModelConfig,
    x,                       # (B, S, d)
    positions,               # (B, S) or (3, B, S) for M-RoPE
    *,
    window=None,             # None | int | traced scalar; <=0 means full
    cache: Optional[dict] = None,   # {"k": (B, S_max, KV, hd), "v": ...} decode
    cache_pos: Optional[jax.Array] = None,  # () int32 write offset
    collect_kv: bool = False,       # prefill: also return this step's (k, v)
):
    """Reference GQA attention; returns (out, aux).

    ``aux`` is the updated cache dict in decode mode, the fresh ``(k, v)``
    pair when ``collect_kv`` (prefill), else None.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype

    qf, kf, vf = column_parallel_in(
        x,
        [params[f"{name}/wq"].astype(dt).reshape(d, H * hd),
         params[f"{name}/wk"].astype(dt).reshape(d, KV * hd),
         params[f"{name}/wv"].astype(dt).reshape(d, KV * hd)],
        fallback_axes=("batch", "seq", None),
    )
    q = qf.reshape(B, S, H, hd)
    k = kf.reshape(B, S, KV, hd)
    v = vf.reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    fresh_kv = (k, v) if collect_kv else None

    aux = None
    if cache is not None:
        S_k = cache["k"].shape[1]
        ring = bool(cache.get("ring", False))
        # Ring caches (window-sized, for local/sliding layers): write at
        # pos % S_k; slot j currently holds absolute position
        # p_j = pos - ((pos - j) mod S_k)  (the last S_k tokens).
        write_pos = (cache_pos % S_k) if ring else cache_pos
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0)
        )
        aux = {"k": k_cache, "v": v_cache}
        k, v = k_cache, v_cache
        k = constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
        v = constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
        q_pos = positions if positions.ndim == 2 else positions[0]
        if ring:
            j = jnp.arange(S_k)
            k_abs = cache_pos - ((cache_pos - j) % S_k)            # (S_k,)
            mask = (k_abs[None, None, :] >= 0) & (
                k_abs[None, None, :] <= q_pos[:, :, None]
            )
        else:
            k_pos = jnp.arange(S_k)
            mask = k_pos[None, None, :] <= q_pos[:, :, None]      # (B,S,S_k)
            if window is not None:
                win_eff = jnp.where(jnp.asarray(window) > 0, window, S_k + 1)
                mask &= k_pos[None, None, :] > q_pos[:, :, None] - win_eff
        mask = mask[:, None, :, :]                                 # (B,1,S,S_k)
    else:
        S_k = S
        q_pos = jnp.arange(S)
        k_pos = jnp.arange(S_k)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            win_eff = jnp.where(jnp.asarray(window) > 0, window, S_k + 1)
            mask &= k_pos[None, :] > q_pos[:, None] - win_eff
        mask = mask[None, None, :, :]

    # Group query heads over KV heads: (B, S, KV, G, hd)
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    if cfg.attn_impl == "chunked" and cache is None and S > cfg.attn_chunk:
        out = _chunked_attention(cfg, qg, k.astype(dt), v.astype(dt), mask)
    else:
        scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(dt)) / jnp.sqrt(hd).astype(dt)
        scores = scores.astype(jnp.float32)
        if cfg.attn_softcap > 0:
            scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
        mask_b = jnp.broadcast_to(mask, (B, 1, S, S_k))[:, :, None, :, :]
        scores = jnp.where(
            mask_b.reshape(B, 1, 1, S, S_k), scores, jnp.float32(-1e30)
        )
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(dt))
        out = out.reshape(B, S, KV, G, hd)
    out = out.reshape(B, S, H, hd)
    out = constrain(out, ("batch", "seq", "heads", "head_dim"))
    # Row-parallel attention output (contraction over sharded heads):
    # explicit reduce-scatter into the SP layout (Megatron-SP's g-bar).
    out = row_parallel_out(
        out.reshape(B, S, H * hd),
        params[f"{name}/wo"].astype(dt).reshape(H * hd, d),
        "heads",
    )
    return out, (aux if cache is not None else fresh_kv)


def _row_parallel_ctx(d_contract: int, seq: int):
    """If the context allows an explicit Megatron-style reduce-scatter
    (train mode, 'model' axis divides both the contracted dim and seq),
    return (ctx, model_size); else None.

    GSPMD on this pipeline lowers row-parallel outputs as all-reduce +
    slice (measured: 0 reduce-scatters on command-r).  shard_map +
    psum_scatter makes the halved-volume collective explicit.
    """
    from repro.parallel.sharding import current_context

    ctx = current_context()
    if ctx is None or ctx.mode != "train":
        return None
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    m = sizes.get("model", 1)
    if m <= 1 or d_contract % m or seq % m:
        return None
    return ctx, m


def row_parallel_out(x, w, name_axes: str, seq_axis: int = 1):
    """y = x @ w with the contraction dim sharded over 'model'; output is
    reduce-scattered over the sequence dim (SP layout).

    x: (B, S, K); w: (K, d).  Returns (B, S/TP-shard, d) logical (B,S,d)
    sharded on seq.  Falls back to einsum + constraint when shard_map
    preconditions fail (non-divisible dims, decode modes).
    """
    from functools import partial

    from jax.sharding import PartitionSpec as P

    B, S, K = x.shape
    rp = _row_parallel_ctx(K, S)
    if rp is None:
        out = jnp.einsum("bsk,kd->bsd", x, w)
        return constrain(out, ("batch", "residual_seq", "embed"))
    ctx, m = rp
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    token_axes = tuple(a for a in ("pod", "data") if a in sizes)

    @partial(
        jax.shard_map,
        mesh=ctx.mesh,
        in_specs=(P(token_axes, None, "model"), P("model", None)),
        out_specs=P(token_axes, "model", None),
        check_vma=False,
    )
    def body(x_loc, w_loc):
        partial_sum = jnp.einsum("bsk,kd->bsd", x_loc, w_loc)
        # reduce + scatter over seq in one collective (vs AR + slice)
        return jax.lax.psum_scatter(
            partial_sum, "model", scatter_dimension=1, tiled=True
        )

    return body(x, w)


def column_parallel_in(x, weights: list, fallback_axes=("batch", "seq", "mlp")):
    """Column-parallel projections under SP: ONE explicit all-gather of the
    seq-sharded input feeds every projection in the block (GSPMD emits a
    gather per einsum); the gather's autodiff transpose is psum_scatter —
    a true reduce-scatter in the backward pass.

    x: (B, S, d) seq-sharded; weights: list of (d, F_i) with F_i sharded
    over 'model'.  Returns list of (B, S, F_i) outputs (F sharded).
    Fallback: plain einsums + constraints.
    """
    from functools import partial

    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    rp = _row_parallel_ctx(d, S)
    ok = rp is not None and all(w.shape[1] % rp[1] == 0 for w in weights)
    if not ok:
        return [
            constrain(jnp.einsum("bsd,df->bsf", x, w), fallback_axes)
            for w in weights
        ]
    ctx, m = rp
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    token_axes = tuple(a for a in ("pod", "data") if a in sizes)
    n_w = len(weights)

    @partial(
        jax.shard_map,
        mesh=ctx.mesh,
        in_specs=(P(token_axes, "model", None),)
        + tuple(P(None, "model") for _ in range(n_w)),
        out_specs=tuple(P(token_axes, None, "model") for _ in range(n_w)),
        check_vma=False,
    )
    def body(x_loc, *ws):
        xg = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        return tuple(jnp.einsum("bsd,df->bsf", xg, w) for w in ws)

    return list(body(x, *weights))


# ---------------------------------------------------------------------------
# GLU MLP
# ---------------------------------------------------------------------------


def init_mlp(b: ParamBuilder, name: str, d: int, d_ff: int):
    b.add(f"{name}/wi_gate", (d, d_ff), ("embed", "mlp"))
    b.add(f"{name}/wi_up", (d, d_ff), ("embed", "mlp"))
    b.add(f"{name}/wo", (d_ff, d), ("mlp", "embed"))


def mlp(params, name: str, x):
    dt = x.dtype
    gate, up = column_parallel_in(
        x, [params[f"{name}/wi_gate"].astype(dt), params[f"{name}/wi_up"].astype(dt)]
    )
    h = jax.nn.silu(gate) * up
    h = constrain(h, ("batch", "seq", "mlp"))
    # row-parallel output -> explicit reduce-scatter into the SP layout
    return row_parallel_out(h, params[f"{name}/wo"].astype(dt), "mlp")


# ---------------------------------------------------------------------------
# MoE (top-k routing with capacity buffers, GShard-style)
# ---------------------------------------------------------------------------


def init_moe(b: ParamBuilder, name: str, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    b.add(f"{name}/router", (d, E), ("embed", "experts"))
    b.add(f"{name}/wi_gate", (E, d, ff), ("experts", "embed", "mlp"))
    b.add(f"{name}/wi_up", (E, d, ff), ("experts", "embed", "mlp"))
    b.add(f"{name}/wo", (E, ff, d), ("experts", "mlp", "embed"))
    if cfg.n_shared_experts:
        init_mlp(b, f"{name}/shared", d, cfg.d_ff * cfg.n_shared_experts)


def moe(params, name: str, cfg: ModelConfig, x):
    """Top-k expert routing with per-expert capacity buffers.

    Two execution paths:

    * **EP path** (under a sharding context whose mesh has a 'model' axis
      dividing n_experts): explicit ``shard_map`` — tokens stay sharded
      over (pod, data), every device builds a *local* capacity buffer
      (scatter stays on-device), computes only its own experts, and one
      all-gather over 'model' combines expert outputs.  GSPMD cannot infer
      this from a global scatter (it replicates instead: measured 18 TB of
      collectives and 189 GB peak on phi3.5 — EXPERIMENTS.md §Perf it. 2).
    * **fallback** (no context / tiny meshes): global scatter semantics,
      used by the smoke tests and decode-equivalence oracle.
    """
    from repro.parallel.sharding import current_context

    ctx = current_context()
    if ctx is not None:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        model_sz = sizes.get("model", 1)
        token_axes = tuple(a for a in ("pod", "data") if a in sizes)
        tok_shards = 1
        for a in token_axes:
            tok_shards *= sizes[a]
        T_all = x.shape[0] * x.shape[1]
        if (
            model_sz > 1
            and cfg.n_experts % model_sz == 0
            and T_all % tok_shards == 0
            and x.shape[0] % tok_shards == 0
        ):
            return _moe_shard_map(params, name, cfg, x, ctx, token_axes)
    return _moe_dense(params, name, cfg, x)


def _moe_shard_map(params, name: str, cfg: ModelConfig, x, ctx, token_axes):
    from functools import partial

    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    mesh = ctx.mesh

    router_w = params[f"{name}/router"].astype(dt)
    wi_gate = params[f"{name}/wi_gate"].astype(dt)
    wi_up = params[f"{name}/wi_up"].astype(dt)
    wo = params[f"{name}/wo"].astype(dt)

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(
            P(token_axes, None, None),        # x: tokens over (pod, data)
            P(None, None),                    # router replicated
            P("model", None, None),           # expert weights over 'model'
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=P(token_axes, None, None),
        check_vma=False,
    )
    def body(x_loc, router, wg, wu, wod):
        Bl, Sl, _ = x_loc.shape
        Tl = Bl * Sl
        xt = x_loc.reshape(Tl, d)
        logits = (xt @ router).astype(jnp.float32)
        weights, experts = jax.lax.top_k(logits, k)
        weights = jax.nn.softmax(weights, axis=-1).astype(dt)

        cap = max(int(Tl * k * cfg.capacity_factor / E), 1)
        flat_e = experts.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], axis=1
        )[:, 0]
        keep = slot < cap
        slot = jnp.where(keep, slot, cap)

        tok_idx = jnp.repeat(jnp.arange(Tl), k)
        buf = jnp.zeros((E, cap + 1, d), dt).at[flat_e, slot].add(xt[tok_idx])

        # compute ONLY the experts this model-rank owns
        E_loc = wg.shape[0]
        ridx = jax.lax.axis_index("model")
        my = jax.lax.dynamic_slice_in_dim(buf, ridx * E_loc, E_loc, axis=0)
        gate = jnp.einsum("ecd,edf->ecf", my, wg)
        up = jnp.einsum("ecd,edf->ecf", my, wu)
        out_loc = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, wod)
        # combine across the model axis: (E, cap+1, d) everywhere
        out_all = jax.lax.all_gather(out_loc, "model", axis=0, tiled=True)

        gathered = out_all[flat_e, slot]
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = jnp.zeros((Tl, d), dt).at[tok_idx].add(
            gathered * weights.reshape(-1)[:, None]
        )
        return y.reshape(Bl, Sl, d)

    out = body(x, router_w, wi_gate, wi_up, wo)
    if cfg.n_shared_experts:
        out = out + mlp(params, f"{name}/shared", x)
    return out


def _moe_dense(params, name: str, cfg: ModelConfig, x):
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, params[f"{name}/router"].astype(dt))
    logits = logits.astype(jnp.float32)
    weights, experts = jax.lax.top_k(logits, k)            # (T, k)
    weights = jax.nn.softmax(weights, axis=-1).astype(dt)

    capacity = max(int(T * k * cfg.capacity_factor / E), 1)
    flat_expert = experts.reshape(-1)                      # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)       # (T*k, E)
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = slot < capacity
    slot = jnp.where(keep, slot, capacity)                 # overflow -> scratch row

    # Scatter tokens to (E, C+1, d); row `capacity` absorbs dropped tokens.
    # The capacity dim is sharded over 'data' (exp_capacity rule): without
    # it every device computes its expert's FULL capacity — a |data|-times
    # per-device overcompute (measured 13x on phi3.5; EXPERIMENTS.md §Perf).
    buf = jnp.zeros((E, capacity + 1, d), dt)
    token_idx = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[flat_expert, slot].add(xt[token_idx])
    buf = constrain(buf, ("experts", "exp_capacity", "embed"))

    gate = jnp.einsum("ecd,edf->ecf", buf, params[f"{name}/wi_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", buf, params[f"{name}/wi_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = constrain(h, ("experts", "exp_capacity", "mlp"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params[f"{name}/wo"].astype(dt))
    out_buf = constrain(out_buf, ("experts", "exp_capacity", "embed"))

    # Gather back, weighted by router probability; dropped tokens get 0.
    gathered = out_buf[flat_expert, slot]                  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    wflat = weights.reshape(-1)[:, None]
    out = jnp.zeros((T, d), dt).at[token_idx].add(gathered * wflat)

    if cfg.n_shared_experts:
        out = out + mlp(params, f"{name}/shared", x).reshape(T, d)
    return out.reshape(B, S, d)
