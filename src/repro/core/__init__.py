"""Core: the paper's contribution — parallel spawning strategies for
dynamic-aware (malleable) distributed jobs.

Faithful implementations of:
  * Hypercube strategy            (§4.1, Eqs. 1-3)   -> :mod:`.hypercube`
  * Iterative Diffusive strategy  (§4.2, Eqs. 4-8)   -> :mod:`.diffusive`
  * Group synchronization         (§4.3)             -> :mod:`.sync`
  * Binary connection             (§4.4)             -> :mod:`.connect`
  * Rank reordering               (§4.5, Eq. 9)      -> :mod:`.reorder`
  * TS/ZS/SS shrink planning      (§4.6-4.7)         -> :mod:`.shrink`
  * MaM-style manager facade      (§3)               -> :mod:`.manager`
  * Cluster topology + distance classes              -> :mod:`.topology`
  * Topology-aware spawning strategy ("topo")        -> :mod:`.topo`
  * DMR-style async two-phase strategy ("dmr-async") -> :mod:`.dmr`
"""
from .connect import (
    ConnectRound,
    binary_connection_schedule,
    extend_graph_with_connection,
    required_ports,
    simulate_merges,
)
from .diffusive import plan_diffusive
from .engine import (
    CheckpointSpec,
    ExecutionBackend,
    ReconfigEngine,
    ReconfigOutcome,
    ReconfigPlan,
    RedistributionSpec,
    Stage,
    StrategySpec,
    Timeline,
    TimelineEvent,
    as_core_vector,
    checkpoint_timeline,
    expansion_timeline,
    get_strategy,
    register_strategy,
    registered_strategies,
    restart_timeline,
    running_vector,
    shrink_timeline,
    strategy_key,
)
from .hypercube import nodes_at_step, plan_hypercube, procs_at_step, steps_required
from .manager import MalleabilityManager
from .sequential import plan_sequential
from .reorder import global_order, node_of_rank, reorder_key
from .shrink import ClusterState, apply_shrink, plan_initial_world_shrink, plan_shrink
from .sync import (
    EventGraph,
    Event,
    assert_ports_before_release,
    build_sync_graph,
    port_openers,
    spawn_children,
)
from .topology import DISTANCE_CLASSES, Topology
from .vectorized import (
    Charge,
    ChargeStats,
    EventArrays,
    charge_stats,
    checkpoint_charge,
    hypercube_expand_charges,
    queue_charge,
    redistribution_charge,
    restart_charges,
    restore_charge,
    ts_shrink_charges,
)
# Importing .topo / .dmr registers the "topo" and "dmr-async" strategies
# in the engine registry (ordinary third-party-style registrations).
from .topo import TOPO_KEY, place_rack_local, plan_topo, vacate_racks
from .dmr import DMR_KEY, plan_dmr
from .types import (
    SOURCE_GID,
    GroupSpec,
    Method,
    RankInfo,
    ShrinkAction,
    ShrinkActionKind,
    ShrinkKind,
    ShrinkPlan,
    SpawnPlan,
    StepTrace,
    Strategy,
    World,
)

__all__ = [
    "DISTANCE_CLASSES",
    "DMR_KEY",
    "SOURCE_GID",
    "TOPO_KEY",
    "Charge",
    "ChargeStats",
    "CheckpointSpec",
    "ClusterState",
    "Topology",
    "ConnectRound",
    "Event",
    "EventArrays",
    "EventGraph",
    "ExecutionBackend",
    "GroupSpec",
    "MalleabilityManager",
    "Method",
    "RankInfo",
    "ReconfigEngine",
    "ReconfigOutcome",
    "ReconfigPlan",
    "RedistributionSpec",
    "Stage",
    "StrategySpec",
    "Timeline",
    "TimelineEvent",
    "ShrinkAction",
    "ShrinkActionKind",
    "ShrinkKind",
    "ShrinkPlan",
    "SpawnPlan",
    "StepTrace",
    "Strategy",
    "World",
    "apply_shrink",
    "as_core_vector",
    "assert_ports_before_release",
    "binary_connection_schedule",
    "build_sync_graph",
    "charge_stats",
    "checkpoint_charge",
    "checkpoint_timeline",
    "expansion_timeline",
    "extend_graph_with_connection",
    "get_strategy",
    "global_order",
    "hypercube_expand_charges",
    "node_of_rank",
    "nodes_at_step",
    "place_rack_local",
    "plan_diffusive",
    "plan_dmr",
    "plan_hypercube",
    "plan_initial_world_shrink",
    "plan_sequential",
    "plan_shrink",
    "plan_topo",
    "port_openers",
    "procs_at_step",
    "queue_charge",
    "redistribution_charge",
    "register_strategy",
    "registered_strategies",
    "reorder_key",
    "required_ports",
    "restart_charges",
    "restart_timeline",
    "restore_charge",
    "running_vector",
    "shrink_timeline",
    "simulate_merges",
    "spawn_children",
    "steps_required",
    "strategy_key",
    "ts_shrink_charges",
    "vacate_racks",
]
