"""Core: the paper's contribution — parallel spawning strategies for
dynamic-aware (malleable) distributed jobs.

Faithful implementations of:
  * Hypercube strategy            (§4.1, Eqs. 1-3)   -> :mod:`.hypercube`
  * Iterative Diffusive strategy  (§4.2, Eqs. 4-8)   -> :mod:`.diffusive`
  * Group synchronization         (§4.3)             -> :mod:`.sync`
  * Binary connection             (§4.4)             -> :mod:`.connect`
  * Rank reordering               (§4.5, Eq. 9)      -> :mod:`.reorder`
  * TS/ZS/SS shrink planning      (§4.6-4.7)         -> :mod:`.shrink`
  * MaM-style manager facade      (§3)               -> :mod:`.manager`
"""
from .connect import (
    ConnectRound,
    binary_connection_schedule,
    extend_graph_with_connection,
    required_ports,
    simulate_merges,
)
from .diffusive import plan_diffusive
from .hypercube import nodes_at_step, plan_hypercube, procs_at_step, steps_required
from .manager import (
    MalleabilityManager,
    ReconfigPlan,
    RedistributionSpec,
    plan_sequential,
)
from .reorder import global_order, node_of_rank, reorder_key
from .shrink import ClusterState, apply_shrink, plan_initial_world_shrink, plan_shrink
from .sync import (
    EventGraph,
    Event,
    assert_ports_before_release,
    build_sync_graph,
    port_openers,
    spawn_children,
)
from .types import (
    SOURCE_GID,
    GroupSpec,
    Method,
    RankInfo,
    ShrinkAction,
    ShrinkActionKind,
    ShrinkKind,
    ShrinkPlan,
    SpawnPlan,
    StepTrace,
    Strategy,
    World,
)

__all__ = [
    "SOURCE_GID",
    "ClusterState",
    "ConnectRound",
    "Event",
    "EventGraph",
    "GroupSpec",
    "MalleabilityManager",
    "Method",
    "RankInfo",
    "ReconfigPlan",
    "RedistributionSpec",
    "ShrinkAction",
    "ShrinkActionKind",
    "ShrinkKind",
    "ShrinkPlan",
    "SpawnPlan",
    "StepTrace",
    "Strategy",
    "World",
    "apply_shrink",
    "assert_ports_before_release",
    "binary_connection_schedule",
    "build_sync_graph",
    "extend_graph_with_connection",
    "global_order",
    "node_of_rank",
    "nodes_at_step",
    "plan_diffusive",
    "plan_hypercube",
    "plan_initial_world_shrink",
    "plan_sequential",
    "plan_shrink",
    "port_openers",
    "procs_at_step",
    "reorder_key",
    "required_ports",
    "simulate_merges",
    "spawn_children",
    "steps_required",
]
