"""ReconfigEngine: the single owner of the reconfiguration pipeline.

The paper describes ONE cooperative pipeline (spawn rounds → tree sync →
binary connect → reorder → final intercomm, plus TS/ZS/SS shrinks); this
module is its single implementation point:

* a **strategy registry** — the built-in spawning strategies (SEQUENTIAL,
  SEQUENTIAL_PER_NODE, SINGLE, PARALLEL_HYPERCUBE, PARALLEL_DIFFUSIVE,
  plus the topology-aware ``topo`` and two-phase ``dmr-async`` specs)
  register themselves here, and third-party strategies can too, so the
  simulator, the elastic runtime, the trainer, and the benchmarks all
  dispatch through one table instead of hand-stitching strategy×method
  matrices;
* an **event timeline** — every plan is executed as an explicit list of
  typed stage events with start/end times charged by a ``CostModel``.
  ASYNC overlap is a *property of the timeline*: each event carries an
  ``overlap_fraction`` (how much of it can hide under application
  compute) and the timeline a contention factor, so downtime is never
  arithmetic re-derived per consumer.  Stage-3 data movement is a
  first-class term: events carry ``bytes_moved`` and the engine charges
  them through a pluggable *bytes model* (see ``ReconfigEngine``);
* an **execution protocol** — backends (the cost simulator, the live
  NodeGroup runtime) receive the same :class:`ReconfigPlan` objects and
  apply them to their substrate while the engine charges the timeline.

Stages map onto the paper: SPAWN (§4.1/§4.2), SYNC (§4.3), CONNECT
(§4.4), REORDER (§4.5 Eq. 9), FINAL (the sources↔children intercomm),
REDISTRIBUTION (stage 3), TERMINATE/ZOMBIFY/RESPAWN/TEARDOWN (§4.6-4.7
TS/ZS/SS shrink mechanisms), and CHECKPOINT/RESTORE (the full-stop
checkpoint/restart baseline malleability is measured against, plus
failure recovery from the last checkpoint).

Scope: timelines price what a reconfiguration *costs*.  What the
resulting allocation *earns* per application step — the other half of
the time-to-result trade — is priced by the companion
:mod:`repro.malleability.throughput` step-time model, which the scenario
executors accrue between charged events.  Keeping the two scopes
separate means a shared :class:`TransitionCache` never depends on the
throughput model in force.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Iterable, Optional, Protocol, Sequence, Union

from .connect import binary_connection_schedule, extend_graph_with_connection
from .diffusive import plan_diffusive
from .hypercube import plan_hypercube
from .reorder import global_order
from .sequential import plan_sequential
from .shrink import ClusterState
from .shrink import plan_shrink as _plan_shrink_actions
from .sync import EventGraph, build_sync_graph
from .topology import Topology, split_bytes_by_class
from .types import SOURCE_GID, Method, ShrinkKind, ShrinkPlan, SpawnPlan, Strategy

if TYPE_CHECKING:  # runtime import would be circular (malleability → core)
    from repro.malleability.cost_model import CostModel


# =============================================================== timeline ==
class Stage(enum.Enum):
    """Typed reconfiguration stages (paper §4 + §4.6-4.7 shrinks)."""

    QUEUE = "queue"              # RMS arbitration: waiting behind an
    #                              in-flight reconfiguration (ours or a
    #                              co-scheduled job's) before stage 2 starts
    SPAWN = "spawn"
    SYNC = "sync"
    CONNECT = "connect"
    REORDER = "reorder"
    FINAL = "final"
    REDISTRIBUTION = "redistribution"
    TERMINATE = "terminate"      # TS: doomed node-confined worlds exit
    ZOMBIFY = "zombify"          # ZS: ranks sleep, nodes stay pinned
    RESPAWN = "respawn"          # SS: the replacement world comes up
    TEARDOWN = "teardown"        # SS: old world finalize + dealloc
    # Fault-tolerance stages (appended last: the vectorized layer's int8
    # stage codes follow declaration order, so earlier codes are stable).
    CHECKPOINT = "checkpoint"    # snapshot streamed to the checkpoint store
    RESTORE = "restore"          # snapshot read back from the store


@dataclass(frozen=True)
class TimelineEvent:
    """One charged stage interval on the reconfiguration timeline.

    ``overlap_fraction`` is the share of this event's work that can
    proceed under application compute when the job runs ASYNC (MaM's
    binary model is the special case 1.0 for spawn, 0.0 elsewhere).
    ``bytes_moved`` / ``bytes_stayed`` are the stage-3 data volumes this
    event accounts for per link — moved bytes cross devices, stayed
    bytes are re-validated on the device that already holds them —
    (non-zero only on REDISTRIBUTION events today).  ``bytes_cross_rack``
    is the portion of ``bytes_moved`` whose source and destination nodes
    sit in different racks of the engine's :class:`~repro.core.topology
    .Topology` (0 without a topology: everything is one rack), and
    ``bytes_cross_pod`` the slice of that portion additionally crossing
    pods (0 unless the topology defines pods), so
    :attr:`bytes_by_class` recovers the full distance-class split.
    ``bytes_checkpointed`` is the snapshot volume streamed to the
    checkpoint store (non-zero only on CHECKPOINT events); RESTORE
    events carry the bytes read back in ``bytes_moved``/``bytes_stayed``
    (store traffic, excluded from the timeline's stage-3 byte sums).
    """

    stage: Stage
    start: float
    end: float
    label: str = ""
    overlap_fraction: float = 0.0
    bytes_moved: int = 0
    bytes_stayed: int = 0
    bytes_cross_rack: int = 0
    bytes_cross_pod: int = 0
    bytes_checkpointed: int = 0

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class (sums to stayed + moved)."""
        return split_bytes_by_class(self.bytes_stayed, self.bytes_moved,
                                    self.bytes_cross_rack,
                                    self.bytes_cross_pod)

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def overlappable(self) -> bool:
        """True when any part of this event can hide under compute."""
        return self.overlap_fraction > 0.0

    def hidden_under_compute(self, contention: float = 1.0) -> float:
        """Seconds of this event that ASYNC execution hides from the app.

        The hidden portion (``duration * overlap_fraction``) shares the
        network and launcher daemons with compute, so hiding a fraction
        ``f`` still costs ``f * (contention - 1)`` of the duration in
        lost application progress: the effective hidden time is
        ``duration * f * (2 - contention)``, clamped to ``[0, d*f]``.
        ``contention=1`` is perfect hiding; ``contention>=2`` means the
        overlap buys nothing.
        """
        f = min(max(self.overlap_fraction, 0.0), 1.0)
        eff = f * max(0.0, 2.0 - max(contention, 1.0))
        return self.duration * min(eff, f)


@dataclass(frozen=True)
class Timeline:
    """An executed plan: ordered stage events + derived cost queries.

    Both ``ExpansionReport.downtime`` and ``ReconfigRecord.downtime_s``
    read off this object, so the two layers cannot disagree.
    ``contention`` is the CostModel's overlap-contention factor, captured
    at build time so downtime queries need no cost model.
    """

    events: tuple[TimelineEvent, ...] = ()
    contention: float = 1.0

    @property
    def total(self) -> float:
        """Wall time of the whole reconfiguration."""
        return max((e.end for e in self.events), default=0.0)

    @property
    def bytes_moved(self) -> int:
        """Total stage-3 cross-link bytes charged across all events.

        RESTORE events are excluded from all four stage-3 sums: their
        bytes come off the checkpoint store, not a peer rank, and are
        reported separately as :attr:`bytes_restored`.
        """
        return sum(e.bytes_moved for e in self.events
                   if e.stage is not Stage.RESTORE)

    @property
    def bytes_stayed(self) -> int:
        """Total stage-3 local-link bytes charged across all events."""
        return sum(e.bytes_stayed for e in self.events
                   if e.stage is not Stage.RESTORE)

    @property
    def bytes_cross_rack(self) -> int:
        """Total stage-3 rack-crossing bytes charged across all events."""
        return sum(e.bytes_cross_rack for e in self.events
                   if e.stage is not Stage.RESTORE)

    @property
    def bytes_cross_pod(self) -> int:
        """Total stage-3 pod-crossing bytes charged across all events."""
        return sum(e.bytes_cross_pod for e in self.events
                   if e.stage is not Stage.RESTORE)

    @property
    def bytes_checkpointed(self) -> int:
        """Total snapshot bytes streamed to the checkpoint store."""
        return sum(e.bytes_checkpointed for e in self.events)

    @property
    def bytes_restored(self) -> int:
        """Total bytes read back from the store (RESTORE events)."""
        return sum(e.bytes_stayed + e.bytes_moved for e in self.events
                   if e.stage is Stage.RESTORE)

    @property
    def restored_s(self) -> float:
        """Seconds spent reading state back from the checkpoint store."""
        return self.span(Stage.RESTORE)

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class across all events."""
        return split_bytes_by_class(self.bytes_stayed, self.bytes_moved,
                                    self.bytes_cross_rack,
                                    self.bytes_cross_pod)

    @property
    def queued_s(self) -> float:
        """Seconds spent queued behind in-flight reconfigurations."""
        return self.span(Stage.QUEUE)

    def span(self, stage: Stage) -> float:
        """Summed duration of every event of ``stage``."""
        return sum(e.duration for e in self.events if e.stage is stage)

    def downtime(self, asynchronous: bool = False) -> float:
        """App-visible stall in seconds.

        Synchronous jobs stall for the whole timeline.  ASYNC jobs hide
        each event's ``overlap_fraction`` under compute, degraded by the
        timeline's contention factor (see
        :meth:`TimelineEvent.hidden_under_compute`).  QUEUE spans are
        never downtime: while a reconfiguration waits its turn the job
        keeps stepping at its current size (they do count toward
        ``total``, the makespan view).
        """
        if not asynchronous:
            return self.total - self.queued_s
        return self.total - self.queued_s - sum(
            e.hidden_under_compute(self.contention)
            for e in self.events
            if e.stage is not Stage.QUEUE
        )

    def as_rows(self) -> list[dict]:
        """Timeline as plain dict rows (for tables/CSV)."""
        return [
            {
                "stage": e.stage.value,
                "label": e.label,
                "start_s": e.start,
                "end_s": e.end,
                "duration_s": e.duration,
                "overlap_fraction": e.overlap_fraction,
                "overlappable": e.overlappable,
                "bytes_moved": e.bytes_moved,
                "bytes_stayed": e.bytes_stayed,
                "bytes_cross_rack": e.bytes_cross_rack,
                "bytes_cross_pod": e.bytes_cross_pod,
                "bytes_checkpointed": e.bytes_checkpointed,
            }
            for e in self.events
        ]


class _TimelineBuilder:
    """Appends events back-to-back (the pipeline stages are serial)."""

    def __init__(self, contention: float = 1.0) -> None:
        self._events: list[TimelineEvent] = []
        self._t = 0.0
        self._contention = contention

    def add(self, stage: Stage, duration: float, label: str = "",
            overlap_fraction: float = 0.0, bytes_moved: int = 0,
            bytes_stayed: int = 0, bytes_cross_rack: int = 0,
            bytes_cross_pod: int = 0, bytes_checkpointed: int = 0) -> None:
        if duration <= 0.0:
            return
        self._events.append(
            TimelineEvent(stage, self._t, self._t + duration, label,
                          overlap_fraction, bytes_moved, bytes_stayed,
                          bytes_cross_rack, bytes_cross_pod,
                          bytes_checkpointed)
        )
        self._t += duration

    def extend(self, events: Sequence[TimelineEvent]) -> None:
        for e in events:
            self.add(e.stage, e.duration, e.label, e.overlap_fraction,
                     e.bytes_moved, e.bytes_stayed, e.bytes_cross_rack,
                     e.bytes_cross_pod, e.bytes_checkpointed)

    def build(self) -> Timeline:
        return Timeline(events=tuple(self._events), contention=self._contention)


# ======================================================= strategy registry ==
PlannerFn = Callable[[int, int, Union[int, Sequence[int]], Method], SpawnPlan]

StrategyLike = Union[Strategy, str]


@dataclass(frozen=True)
class StrategySpec:
    """One registered spawning strategy.

    ``planner`` has the normalized signature ``(ns, nt, cores, method)``
    where ``cores`` is either C (homogeneous cores-per-node) or the
    per-node A vector.  ``topology_aware`` strategies additionally drive
    *placement*: when the engine carries a :class:`~repro.core.topology
    .Topology`, :meth:`ReconfigEngine.select_expansion_nodes` places
    their expansion groups rack-local-first and
    :meth:`ReconfigEngine.select_release_nodes` shrinks them so whole
    racks are vacated; topology-blind strategies keep the greedy
    lowest-id / highest-id orders.
    """

    key: str                      # registry key, e.g. "hypercube"
    planner: PlannerFn
    parallel: bool = False        # pays sync/connect/reorder phases (§4.3-4.5)
    homogeneous_only: bool = False
    topology_aware: bool = False  # placement honours the engine's Topology
    two_phase: bool = False       # DMR-style async grant acceptance: the
    #                               spawn/sync/connect legs of an expansion
    #                               fully overlap compute (phase 1), only the
    #                               commit (reorder/final/redistribution)
    #                               stays on the critical path
    description: str = ""


_STRATEGY_REGISTRY: dict[str, StrategySpec] = {}


def strategy_key(strategy: StrategyLike) -> str:
    """Normalize a Strategy enum or plain string to its registry key."""
    return strategy.value if isinstance(strategy, Strategy) else str(strategy)


def register_strategy(spec: StrategySpec, *, overwrite: bool = False) -> StrategySpec:
    """Register a spawning strategy (third-party strategies welcome).

    Args:
        spec: the strategy spec; ``spec.key`` becomes the registry key.
        overwrite: replace an existing entry instead of raising.
    Returns:
        The spec, for chaining.
    Raises:
        ValueError: on a duplicate key without ``overwrite``.
    """
    if spec.key in _STRATEGY_REGISTRY and not overwrite:
        raise ValueError(f"strategy {spec.key!r} already registered")
    _STRATEGY_REGISTRY[spec.key] = spec
    return spec


def get_strategy(strategy: StrategyLike) -> StrategySpec:
    """Look up a registered spec by enum or key (KeyError lists known)."""
    key = strategy_key(strategy)
    try:
        return _STRATEGY_REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown strategy {key!r}; registered: {sorted(_STRATEGY_REGISTRY)}"
        ) from None


def registered_strategies() -> tuple[StrategySpec, ...]:
    """All specs in registration order (built-ins first)."""
    return tuple(_STRATEGY_REGISTRY.values())


# ---- cores normalization helpers -------------------------------------------
def as_core_vector(cores: Union[int, Sequence[int]], nt: int) -> list[int]:
    """C scalar -> per-node A vector wide enough for NT ranks.

    Args:
        cores: homogeneous cores-per-node C, or an explicit A vector
            (returned as a list unchanged).
        nt: target rank count the vector must cover.
    Returns:
        The per-node allocation vector.
    """
    if isinstance(cores, int):
        n_nodes = -(-nt // cores)
        return [cores] * n_nodes
    return [int(c) for c in cores]


def running_vector(a_vec: Sequence[int], ns: int) -> list[int]:
    """Pack the NS sources greedily into the allocation vector (R).

    Args:
        a_vec: per-node allocation vector A.
        ns: number of currently running source ranks.
    Returns:
        Per-node running counts R (same length prefix semantics as A).
    Raises:
        ValueError: if the sources do not fit in A.
    """
    out = []
    remaining = ns
    for a in a_vec:
        take = min(a, remaining)
        out.append(take)
        remaining -= take
    if remaining:
        raise ValueError("sources do not fit in the allocation vector")
    return out


def _cross_share(total: int, parts: Sequence[tuple[int, bool]]) -> int:
    """Portion of ``total`` bytes belonging to the cross-marked parts.

    ``parts`` is ``(weight, is_cross)`` per destination, in a
    deterministic order; ``total`` is distributed proportionally to the
    weights with exact integer arithmetic (cumulative shares), so the
    cross and non-cross portions always sum to ``total`` — the invariant
    the ``bytes_by_class`` reports rely on.
    """
    weight_sum = sum(w for w, _ in parts)
    if total <= 0 or weight_sum <= 0:
        return 0
    out = 0
    cum = 0
    prev = 0
    for w, is_cross in parts:
        cum += w
        share = total * cum // weight_sum
        if is_cross:
            out += share - prev
        prev = share
    return out


def _class_shares(total: int,
                  parts: Sequence[tuple[int, int]]) -> tuple[int, int]:
    """Rack- and pod-crossing portions of ``total`` bytes.

    Three-way generalization of :func:`_cross_share`: ``parts`` is
    ``(weight, category)`` per destination where category 0 is
    rack-local, 1 crosses racks inside the pod, and 2 crosses pods.
    The cumulative integer boundaries are identical to
    :func:`_cross_share` with ``is_cross = category >= 1``, so the
    returned ``cross_rack`` total is bit-for-bit what the 2-way split
    reported, and ``cross_pod <= cross_rack`` always holds (the pod
    share is a refinement of the rack share).
    """
    weight_sum = sum(w for w, _ in parts)
    if total <= 0 or weight_sum <= 0:
        return 0, 0
    xrack = 0
    xpod = 0
    cum = 0
    prev = 0
    for w, cat in parts:
        cum += w
        share = total * cum // weight_sum
        if cat >= 1:
            xrack += share - prev
        if cat >= 2:
            xpod += share - prev
        prev = share
    return xrack, xpod


def _as_homogeneous(cores: Union[int, Sequence[int]]) -> int:
    if isinstance(cores, int):
        return cores
    widths = {int(c) for c in cores}
    if len(widths) != 1:
        raise ValueError(
            "hypercube strategy requires homogeneous allocations; "
            "use PARALLEL_DIFFUSIVE (paper §4.2)"
        )
    return widths.pop()


# ---- built-in planners (normalized signatures) ------------------------------
def _plan_seq(ns: int, nt: int, cores, method: Method) -> SpawnPlan:
    return plan_sequential(ns, nt, as_core_vector(cores, nt), method)


def _plan_per_node(ns: int, nt: int, cores, method: Method) -> SpawnPlan:
    return plan_sequential(ns, nt, as_core_vector(cores, nt), method, per_node=True)


def _plan_single(ns: int, nt: int, cores, method: Method) -> SpawnPlan:
    return plan_sequential(ns, nt, as_core_vector(cores, nt), method, single=True)


def _plan_hypercube(ns: int, nt: int, cores, method: Method) -> SpawnPlan:
    return plan_hypercube(ns, nt, _as_homogeneous(cores), method)


def _plan_diffusive(ns: int, nt: int, cores, method: Method) -> SpawnPlan:
    a_vec = as_core_vector(cores, nt)
    return plan_diffusive(a_vec, running_vector(a_vec, ns), method)


register_strategy(StrategySpec(
    key=Strategy.SEQUENTIAL.value, planner=_plan_seq,
    description="one collective spawn; multi-node world (classic Merge)"))
register_strategy(StrategySpec(
    key=Strategy.SEQUENTIAL_PER_NODE.value, planner=_plan_per_node,
    description="one spawn per node, serial ([14]); O(nodes) latency"))
register_strategy(StrategySpec(
    key=Strategy.SINGLE.value, planner=_plan_single,
    description="rank 0 spawns alone, informs the rest (MaM Single)"))
register_strategy(StrategySpec(
    key=Strategy.PARALLEL_HYPERCUBE.value, planner=_plan_hypercube,
    parallel=True, homogeneous_only=True,
    description="§4.1 hypercube: (C+1)^s growth, homogeneous pools"))
register_strategy(StrategySpec(
    key=Strategy.PARALLEL_DIFFUSIVE.value, planner=_plan_diffusive,
    parallel=True,
    description="§4.2 iterative diffusive: heterogeneous pools"))


# ================================================================== plans ==
@dataclass(frozen=True)
class RedistributionSpec:
    """Stage-3 data movement: which final ranks receive which data shards.

    ``layout`` maps final global rank -> (group_id, local_rank); the
    elastic runtime turns this into a device permutation + resharding
    plan; the simulator charges bytes/bandwidth for it.

    ``bytes_total`` is the resolved cross-link data volume for THIS
    event (from the engine's bytes model, or ``bytes_per_rank *
    |nt - ns|`` as the scalar fallback); it is what the timeline charges
    as a REDISTRIBUTION event and what ``bytes_moved`` reports read.
    ``bytes_stayed`` is the local-link volume (shards a surviving device
    already holds) when the bytes model reports the per-link split —
    moved-bytes-only models leave it 0 and reproduce the aggregate
    single-bandwidth charge exactly.  ``bytes_cross_rack`` is the part
    of ``bytes_total`` resolved (against the engine's topology and the
    plan's node placement) to cross racks; 0 without a topology.
    ``bytes_cross_pod`` is the slice of that portion additionally
    crossing pods; 0 unless the topology defines pods.
    """

    layout: tuple[tuple[int, int], ...]
    ns: int
    nt: int
    bytes_per_rank: int = 0
    bytes_total: int = 0
    bytes_stayed: int = 0
    bytes_cross_rack: int = 0
    bytes_cross_pod: int = 0

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class (sums to stayed + total)."""
        return split_bytes_by_class(self.bytes_stayed, self.bytes_total,
                                    self.bytes_cross_rack,
                                    self.bytes_cross_pod)


@dataclass(frozen=True)
class CheckpointSpec:
    """Checkpoint-store traffic of one reconfiguration.

    ``bytes_checkpointed`` is the snapshot streamed TO the store
    (charged as a CHECKPOINT event, hidden under compute by
    ``cm.ckpt_overlap`` when the job runs ASYNC); ``bytes_restored`` is
    read BACK from it (a RESTORE event — always on the critical path:
    the app is down until its state is back).  Restore bytes are charged
    on the cross link without a distance-class split: the store is a
    shared filesystem outside the rack tree.
    """

    bytes_checkpointed: int = 0
    bytes_restored: int = 0


@dataclass(frozen=True)
class ReconfigPlan:
    """Full output of the process-management stage.

    Self-contained: carries everything a backend or the timeline builder
    needs (including doomed world sizes for shrink charging), so it can
    be executed by any backend without consulting cluster state again.
    """

    kind: str                      # "expand" | "shrink" | "checkpoint"
    #                              # | "restart" | "noop"
    method: Method
    strategy: StrategyLike
    asynchronous: bool
    ns: int = 0
    nt: int = 0
    spawn: Optional[SpawnPlan] = None
    shrink: Optional[ShrinkPlan] = None
    sync_graph: Optional[EventGraph] = None
    connect_rounds: int = 0
    redistribution: Optional[RedistributionSpec] = None
    shrink_world_sizes: tuple[int, ...] = ()   # sizes of TS-doomed worlds
    queue_delay_s: float = 0.0     # RMS arbitration wait before stage 2
    # Cluster node id of each allocation-vector entry (expansions):
    # ``node_ids[i]`` is where A-vector slot ``i`` lives.  Backends
    # acquire the plan's NEW nodes from this list (in order) instead of
    # greedily, which is what makes placement a priced, first-class
    # decision; empty means "no explicit placement" (greedy fallback).
    node_ids: tuple[int, ...] = ()
    # Checkpoint-store traffic: set on "checkpoint"/"restart" plans and
    # on failure shrinks that recover from the last checkpoint.
    checkpoint: Optional[CheckpointSpec] = None


@dataclass(frozen=True)
class ReconfigOutcome:
    """One executed reconfiguration: the plan + its charged timeline."""

    plan: ReconfigPlan
    timeline: Timeline

    @property
    def total_s(self) -> float:
        """Timeline wall time in seconds."""
        return self.timeline.total

    @property
    def downtime_s(self) -> float:
        """App-visible stall (honours the plan's ASYNC flag)."""
        return self.timeline.downtime(self.plan.asynchronous)

    @property
    def bytes_moved(self) -> int:
        """Stage-3 cross-link bytes charged on the timeline."""
        return self.timeline.bytes_moved

    @property
    def bytes_stayed(self) -> int:
        """Stage-3 local-link bytes charged on the timeline."""
        return self.timeline.bytes_stayed

    @property
    def bytes_cross_rack(self) -> int:
        """Stage-3 rack-crossing bytes charged on the timeline."""
        return self.timeline.bytes_cross_rack

    @property
    def bytes_cross_pod(self) -> int:
        """Stage-3 pod-crossing bytes charged on the timeline."""
        return self.timeline.bytes_cross_pod

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class charged on the timeline."""
        return self.timeline.bytes_by_class

    @property
    def queued_s(self) -> float:
        """RMS arbitration wait charged on the timeline (QUEUE spans)."""
        return self.timeline.queued_s

    @property
    def bytes_checkpointed(self) -> int:
        """Snapshot bytes streamed to the checkpoint store."""
        return self.timeline.bytes_checkpointed

    @property
    def bytes_restored(self) -> int:
        """Bytes read back from the store (RESTORE events)."""
        return self.timeline.bytes_restored

    @property
    def restored_s(self) -> float:
        """Seconds spent in RESTORE events."""
        return self.timeline.restored_s


class ExecutionBackend(Protocol):
    """A substrate that applies plans (live NodeGroups, bookkeeping, ...)."""

    def apply_expand(self, plan: ReconfigPlan) -> None: ...

    def apply_shrink(self, plan: ReconfigPlan) -> None: ...


# ======================================================= timeline builders ==
def _is_parallel(plan: SpawnPlan) -> bool:
    if isinstance(plan.strategy, Strategy):
        spec = _STRATEGY_REGISTRY.get(plan.strategy.value)
    else:  # third-party plans carry their registry key
        spec = _STRATEGY_REGISTRY.get(str(plan.strategy))
    if spec is not None:
        return spec.parallel
    return plan.strategy in (Strategy.PARALLEL_HYPERCUBE, Strategy.PARALLEL_DIFFUSIVE)


def _spawn_events(tb: _TimelineBuilder, plan: SpawnPlan, cm: "CostModel",
                  topology: Optional[Topology] = None,
                  node_ids: Sequence[int] = ()) -> None:
    """Spawn phase per strategy; events overlap by ``cm.spawn_overlap``.

    When the cost model prices spawn by topology (``gamma_rack`` /
    ``gamma_pod`` set) AND the caller supplies the cluster layout plus
    the plan's slot -> node placement, every launcher-tree edge is
    charged a distance penalty: the class between the SPAWNING rank's
    node and the target node (stages 1-2 are no longer a flat latency).
    Unpriced models — or plans without explicit placement — take the
    historical arithmetic verbatim, so existing numbers are bit-for-bit
    unchanged.
    """
    if not plan.groups:
        return
    f = cm.spawn_overlap
    priced = (topology is not None and len(node_ids) > 0
              and cm.spawn_topology_priced)

    def _node(slot: int) -> Optional[int]:
        return node_ids[slot] if 0 <= slot < len(node_ids) else None

    root_slot = next((i for i, r in enumerate(plan.running) if r > 0), 0)

    def _penalty(parent_slot: int, child_slot: int) -> float:
        assert topology is not None
        pn, cn = _node(parent_slot), _node(child_slot)
        if pn is None or cn is None:
            return 0.0
        return cm.spawn_distance_penalty(topology.distance_class(pn, cn))

    if plan.strategy in (Strategy.SEQUENTIAL, Strategy.SINGLE):
        g = plan.groups[0]
        dur = cm.spawn_call(g.size, len(g.nodes_spanned()))
        if priced:
            # One collective launch rooted at the sources: the call waits
            # for its farthest target node.
            dur += max(
                (_penalty(root_slot, slot) for slot in g.nodes_spanned()),
                default=0.0,
            )
        if plan.strategy is Strategy.SINGLE:
            # rank 0 informs the rest afterwards (MaM Single strategy)
            dur += cm.t_token * math.ceil(math.log2(max(plan.ns, 2)))
        tb.add(Stage.SPAWN, dur, label="collective spawn", overlap_fraction=f)
        return
    if plan.strategy is Strategy.SEQUENTIAL_PER_NODE:
        for g in plan.groups:
            dur = cm.spawn_call(g.size, 1)
            if priced:
                dur += _penalty(root_slot, g.node)
            tb.add(Stage.SPAWN, dur,
                   label=f"spawn node {g.node}", overlap_fraction=f)
        return
    # Parallel strategies: rounds of concurrent single-node spawns.
    by_gid = {g.gid: g for g in plan.groups}

    def _parent_slot(g) -> int:
        if g.parent_gid == SOURCE_GID:
            return root_slot
        parent = by_gid.get(g.parent_gid)
        return parent.node if parent is not None else root_slot

    initial_nodes = sum(1 for r in plan.running if r > 0)
    for s in range(1, plan.steps + 1):
        round_groups = plan.groups_in_step(s)
        if not round_groups:
            continue
        oversub = plan.method is Method.BASELINE and any(
            g.node < initial_nodes for g in round_groups
        )
        if priced:
            dur = cm.concurrent_round_priced(
                [(g.size, 1, _penalty(_parent_slot(g), g.node))
                 for g in round_groups],
                oversubscribed=oversub,
            )
        else:
            dur = cm.concurrent_round(
                [(g.size, 1) for g in round_groups], oversubscribed=oversub
            )
        tb.add(Stage.SPAWN, dur, label=f"round {s} ({len(round_groups)} groups)",
               overlap_fraction=f)


def _sync_event(tb: _TimelineBuilder, plan: SpawnPlan, cm: "CostModel") -> None:
    """§4.3 three-stage synchronization along the spawn tree.

    Critical path: deepest leaf sends up through ``depth`` levels (token +
    per-group barrier each), source barriers, then the release token walks
    back down the same depth.
    """
    if not _is_parallel(plan) or not plan.groups:
        return
    depth = plan.steps
    max_group = max(plan.group_sizes)
    per_level = cm.t_token + cm.barrier(max_group) + cm.comm_split(max_group)
    ports = cm.t_port  # opened concurrently by all acceptor roots
    dur = ports + per_level + depth * 2 * (cm.t_token + cm.barrier(max_group))
    tb.add(Stage.SYNC, dur, label=f"tree sync depth {depth}",
           overlap_fraction=cm.sync_overlap)


def _connect_events(tb: _TimelineBuilder, plan: SpawnPlan, cm: "CostModel") -> None:
    """§4.4 binary connection: ceil(log2 G) rounds of pairwise merges."""
    if not _is_parallel(plan):
        return
    sizes = {g.gid: g.size for g in plan.groups}
    for i, rnd in enumerate(binary_connection_schedule(len(plan.groups))):
        round_cost = 0.0
        for acc, conn in rnd.pairs:
            merged = sizes[acc] + sizes[conn]
            round_cost = max(round_cost, cm.connect_merge(merged))
            sizes[acc] = merged
            del sizes[conn]
        tb.add(Stage.CONNECT, round_cost,
               label=f"connect round {i + 1} ({len(rnd.pairs)} merges)",
               overlap_fraction=cm.connect_overlap)


def expansion_timeline(
    plan: SpawnPlan, cm: "CostModel", bytes_total: int = 0,
    queue_delay_s: float = 0.0, bytes_stayed: int = 0,
    bytes_cross_rack: int = 0, bytes_cross_pod: int = 0,
    topology: Optional[Topology] = None,
    node_ids: Sequence[int] = (),
) -> Timeline:
    """Charge one expansion as the paper's serial stage pipeline.

    Args:
        plan: the spawn plan to execute.
        cm: latency/bandwidth model (also supplies per-stage overlap
            fractions and the contention factor).
        bytes_total: stage-3 cross-link data volume; when positive a
            REDISTRIBUTION event carrying ``bytes_moved`` is appended.
        queue_delay_s: RMS arbitration wait before stage 2 starts (an
            in-flight reconfiguration must drain first); charged as a
            leading QUEUE event that counts toward ``total`` but never
            toward downtime.
        bytes_stayed: stage-3 local-link volume (shards surviving
            devices already hold), charged against ``cm.bw_local``.
        bytes_cross_rack: the rack-crossing portion of ``bytes_total``,
            charged against ``cm.bw_cross_rack`` (the rest rides the
            intra-rack link).
        bytes_cross_pod: the pod-crossing slice of ``bytes_cross_rack``,
            charged against ``cm.bw_cross_pod``.
        topology: cluster layout for topology-priced spawn (stages 1-2
            launcher-tree edges charged by distance class); only
            consulted when ``cm.spawn_topology_priced`` is set.
        node_ids: cluster node id per allocation-vector slot (see
            :class:`ReconfigPlan`); required for topology-priced spawn.
    Returns:
        The charged :class:`Timeline`.
    """
    tb = _TimelineBuilder(contention=cm.overlap_contention)
    if queue_delay_s > 0.0:
        tb.add(Stage.QUEUE, queue_delay_s, label="queued behind in-flight reconfig")
    _spawn_events(tb, plan, cm, topology=topology, node_ids=node_ids)
    _sync_event(tb, plan, cm)
    _connect_events(tb, plan, cm)
    parallel = _is_parallel(plan)
    if parallel:
        tb.add(Stage.REORDER, cm.comm_split(sum(plan.group_sizes)),
               label="Eq. 9 reorder split")
    # Final sources<->children intercomm (all strategies pay a merge of the
    # full target world; the classic strategies do it inside the spawn call
    # via the intercommunicator MPI_Comm_spawn returns).
    final = cm.connect_merge(plan.nt) if parallel else cm.beta_connect * plan.nt
    tb.add(Stage.FINAL, final, label="final intercomm merge")
    _redistribution_event(tb, cm, bytes_total, bytes_stayed, bytes_cross_rack,
                          bytes_cross_pod)
    return tb.build()


def _redistribution_event(tb: _TimelineBuilder, cm: "CostModel",
                          bytes_total: int, bytes_stayed: int,
                          bytes_cross_rack: int = 0,
                          bytes_cross_pod: int = 0) -> None:
    """Append the stage-3 event, priced per distance class (no bytes,
    no event)."""
    if bytes_total <= 0 and bytes_stayed <= 0:
        return
    xrack = min(max(0, bytes_cross_rack), max(0, bytes_total))
    xpod = min(max(0, bytes_cross_pod), xrack)
    if xrack > 0:
        label = (f"redistribute {bytes_total - xrack} B intra-rack + "
                 f"{xrack} B cross-rack + {max(0, bytes_stayed)} B local")
        if xpod > 0:
            label += f" ({xpod} B of it cross-pod)"
    elif bytes_stayed > 0:
        label = f"redistribute {bytes_total} B cross + {bytes_stayed} B local"
    else:
        label = f"redistribute {bytes_total} B"
    tb.add(Stage.REDISTRIBUTION,
           cm.redistribution(bytes_total, bytes_stayed, xrack, xpod),
           label=label, overlap_fraction=cm.redist_overlap,
           bytes_moved=bytes_total, bytes_stayed=max(0, bytes_stayed),
           bytes_cross_rack=xrack, bytes_cross_pod=xpod)


def _checkpoint_event(tb: _TimelineBuilder, cm: "CostModel",
                      snapshot_bytes: int) -> None:
    """Append the store-write event (no bytes, no event)."""
    if snapshot_bytes <= 0:
        return
    tb.add(Stage.CHECKPOINT, cm.checkpoint(snapshot_bytes),
           label=f"checkpoint {snapshot_bytes} B",
           overlap_fraction=cm.ckpt_overlap,
           bytes_checkpointed=snapshot_bytes)


def _restore_event(tb: _TimelineBuilder, cm: "CostModel",
                   restore_bytes: int) -> None:
    """Append the store-read event (no bytes, no event).

    The bytes ride the event's ``bytes_moved`` slot but the store sits
    outside the rack tree, so no distance-class split is attempted and
    the Timeline reports them as ``bytes_restored``, not stage-3 moved
    bytes.  Restores never overlap compute: the app is down until its
    state is back.
    """
    if restore_bytes <= 0:
        return
    tb.add(Stage.RESTORE, cm.restore(restore_bytes),
           label=f"restore {restore_bytes} B from checkpoint",
           bytes_moved=restore_bytes)


def shrink_timeline(
    kind: ShrinkKind,
    cm: "CostModel",
    *,
    ns: int = 0,
    nt: int = 0,
    doomed_world_sizes: Optional[Sequence[int]] = None,
    respawn_plan: Optional[SpawnPlan] = None,
    bytes_total: int = 0,
    queue_delay_s: float = 0.0,
    bytes_stayed: int = 0,
    bytes_cross_rack: int = 0,
    bytes_cross_pod: int = 0,
    restore_bytes: int = 0,
) -> Timeline:
    """Charge one shrink by mechanism (§4.6-4.7).

    * TS — release tokens to doomed worlds; they exit; root updates its
      structure.  No spawning at all (the paper's headline).
    * ZS — same token path, but ranks only go to sleep; nodes stay pinned.
    * SS — the Baseline path: spawn the NT-sized world (optionally with a
      parallel strategy: pass ``respawn_plan``), tear the old world down.

    ``bytes_total`` > 0 (cross link) or ``bytes_stayed`` > 0 (local
    link) appends a REDISTRIBUTION event (survivors absorb the doomed
    ranks' shards) after the mechanism's own events.
    ``queue_delay_s`` > 0 prepends a QUEUE event (RMS arbitration wait,
    e.g. a preemption arriving while another reconfiguration is in
    flight) that counts toward ``total`` but never toward downtime.
    ``restore_bytes`` > 0 appends a trailing RESTORE event: the shrink
    is a node *failure* and the survivors re-read the lost shards from
    the last checkpoint instead of receiving them from the (dead) doomed
    ranks.
    """
    tb = _TimelineBuilder(contention=cm.overlap_contention)
    if queue_delay_s > 0.0:
        tb.add(Stage.QUEUE, queue_delay_s, label="queued behind in-flight reconfig")
    doomed = list(doomed_world_sizes or [])
    if kind is ShrinkKind.TS:
        dur = cm.ts_terminate(doomed or [1]) + cm.t_token
        tb.add(Stage.TERMINATE, dur,
               label=f"TS terminate {len(doomed) or 1} worlds")
    elif kind is ShrinkKind.ZS:
        tb.add(Stage.ZOMBIFY, cm.t_token * 2, label="ZS mark + ack")
    else:  # SS
        if respawn_plan is not None:
            tb.extend(expansion_timeline(respawn_plan, cm).events)
            tb.add(Stage.TEARDOWN, cm.t_teardown_per_proc * ns,
                   label="old world finalize")
        else:
            # No respawn plan: estimate the target node count from the doomed
            # world widths (worlds are node-confined, so a world size is a
            # node width); with no width information degenerate to 1
            # proc/node.
            width = max(doomed) if doomed else 1
            tb.add(
                Stage.RESPAWN,
                cm.ss_respawn(nt, max(1, -(-nt // width)), ns),
                label="SS respawn",
            )
    _redistribution_event(tb, cm, bytes_total, bytes_stayed, bytes_cross_rack,
                          bytes_cross_pod)
    _restore_event(tb, cm, restore_bytes)
    return tb.build()


def checkpoint_timeline(
    cm: "CostModel", *, snapshot_bytes: int, queue_delay_s: float = 0.0
) -> Timeline:
    """Charge one standalone checkpoint: a single CHECKPOINT event.

    The write streams to the store at ``cm.bw_ckpt`` after the
    ``cm.alpha_ckpt`` setup; ``cm.ckpt_overlap`` of it hides under
    compute when the job runs ASYNC (the snapshot is a host copy, the
    write happens behind the step loop).
    """
    tb = _TimelineBuilder(contention=cm.overlap_contention)
    if queue_delay_s > 0.0:
        tb.add(Stage.QUEUE, queue_delay_s, label="queued behind in-flight reconfig")
    _checkpoint_event(tb, cm, snapshot_bytes)
    return tb.build()


def restart_timeline(
    cm: "CostModel",
    *,
    ns: int,
    nt: int,
    nodes: int,
    snapshot_bytes: int,
    restore_bytes: int,
    queue_delay_s: float = 0.0,
) -> Timeline:
    """Charge one full-stop checkpoint/restart — the rigid baseline.

    The application checkpoints, stops entirely, is respawned at the
    target size, and reads its state back:

    * CHECKPOINT — ``snapshot_bytes`` streamed to the store (only this
      leg can hide under compute, by ``cm.ckpt_overlap``);
    * RESPAWN — one SS full-stop respawn charge
      (:meth:`CostModel.ss_respawn`: spawn the NT-sized world over
      ``nodes`` nodes + tear the NS-sized old world down + the world
      split — teardown is *inside* the formula, so no separate TEARDOWN
      event is charged);
    * RESTORE — ``restore_bytes`` read back from the store onto the new
      world, always on the critical path.

    This is what malleable shrinks are measured against: same start and
    end allocation, but the whole state makes a store round-trip and
    every rank restarts.
    """
    tb = _TimelineBuilder(contention=cm.overlap_contention)
    if queue_delay_s > 0.0:
        tb.add(Stage.QUEUE, queue_delay_s, label="queued behind in-flight reconfig")
    _checkpoint_event(tb, cm, snapshot_bytes)
    tb.add(Stage.RESPAWN, cm.ss_respawn(nt, max(1, nodes), ns),
           label=f"full-stop respawn {ns} -> {nt} ranks")
    _restore_event(tb, cm, restore_bytes)
    return tb.build()


# ================================================================== engine ==
@dataclass
class ReconfigEngine:
    """Plans and executes reconfigurations through the strategy registry.

    One engine per job.  All four consumer layers sit on top of it:
    :class:`repro.core.MalleabilityManager` (facade),
    :mod:`repro.malleability.simulator` (timeline-charging backend),
    :class:`repro.elastic.ElasticRuntime` (live NodeGroup backend), and
    the benchmark drivers (registry iteration).
    """

    method: Method = Method.MERGE
    strategy: StrategyLike = Strategy.PARALLEL_HYPERCUBE
    asynchronous: bool = False
    bytes_per_rank: int = 0
    cost_model: Optional["CostModel"] = None
    # Cluster layout (node -> rack -> pod).  When set, stage-3 bytes are
    # resolved to the distance class between their source and
    # destination nodes (intra_node / intra_rack / cross_rack) and
    # topology-aware strategies place expansions rack-local-first and
    # shrink whole racks (see select_expansion_nodes /
    # select_release_nodes).  None behaves as a single rack: every moved
    # byte is intra_rack, reproducing the 2-class local/cross pricing.
    topology: Optional[Topology] = None
    # Stage-3 bytes model: ``f(ns_ranks, nt_ranks) -> bytes_moved`` (an
    # int charged on the cross link), or — for per-link pricing — a
    # mapping with ``bytes_stayed`` / ``bytes_moved`` keys (the
    # ``predicted_transfer_stats`` shape); a model exposing a ``stats``
    # attribute (e.g. repro.elastic.reshard.PytreeBytesModel) is asked
    # through it.  Analytic device-free models live in
    # repro.malleability.cost_model (replicated_bytes_model /
    # fsdp_bytes_model / replicated_link_model).  When None the scalar
    # ``bytes_per_rank`` fallback is charged instead.
    bytes_model: Optional[Callable[[int, int], Union[int, dict]]] = None
    # Fault tolerance: when True, failure shrinks (``plan_shrink(...,
    # failed=True)``) append a RESTORE event — the survivors re-read the
    # lost shards from the last checkpoint (the dead ranks cannot ship
    # them) — priced through :meth:`restore_bytes_on_fail`.  False keeps
    # failures priced exactly like voluntary shrinks (the historical
    # behaviour, bit for bit).
    restore_on_fail: bool = False

    def __post_init__(self) -> None:
        if self.cost_model is None:
            # Runtime-local import: core must stay importable without
            # triggering the malleability package at module load.
            from repro.malleability.cost_model import MN5

            self.cost_model = MN5

    # ------------------------------------------------------------ placement --
    def select_expansion_nodes(
        self,
        used: Iterable[int],
        free: Iterable[int],
        need: int,
        *,
        strategy: Optional[StrategyLike] = None,
    ) -> list[int]:
        """Pick which free nodes an expansion acquires, in fill order.

        Topology-aware strategies (with a topology configured) place
        rack-local-first and pack fresh racks whole (see
        :func:`repro.core.topo.place_rack_local`); everything else keeps
        the greedy lowest-id order both backends have always used, so
        plans and timelines are unchanged for existing strategies.
        """
        spec = get_strategy(strategy if strategy is not None else self.strategy)
        if self.topology is not None and spec.topology_aware:
            from .topo import place_rack_local

            return place_rack_local(self.topology, set(used), set(free), need)
        return sorted(free)[:need]

    def select_release_nodes(
        self,
        used: Iterable[int],
        n_release: int,
        *,
        strategy: Optional[StrategyLike] = None,
    ) -> list[int]:
        """Pick which nodes a target-count shrink returns to the RMS.

        Topology-aware strategies vacate whole racks first (see
        :func:`repro.core.topo.vacate_racks`); everything else releases
        the highest node ids, the runtime's historical order.
        """
        spec = get_strategy(strategy if strategy is not None else self.strategy)
        if self.topology is not None and spec.topology_aware:
            from .topo import vacate_racks

            return vacate_racks(self.topology, set(used), n_release)
        return sorted(used)[-n_release:] if n_release > 0 else []

    def allocation_arg(self, widths: Sequence[int]) -> Union[int, list[int]]:
        """Planner ``cores`` argument for a node set's width vector.

        Homogeneous-only strategies get the scalar width on a uniform
        allocation; on an uneven one they get the vector anyway, so the
        planner raises its §4.2 guidance error ("use
        PARALLEL_DIFFUSIVE") instead of silently mis-planning.  BOTH
        executors build their planner input here — the sim == live
        invariant depends on them never diverging.
        """
        out = [int(w) for w in widths]
        if (get_strategy(self.strategy).homogeneous_only
                and len(set(out)) == 1):
            return out[0]
        return out

    # ------------------------------------------------------------- planning --
    def redistribution_stats(self, ns: int, nt: int) -> tuple[int, int]:
        """Per-link stage-3 volumes ``(bytes_stayed, bytes_moved)``.

        Consults ``bytes_model`` when set — through its ``stats``
        attribute if it has one, else by calling it and accepting either
        a plain int (moved bytes; stayed unknown, charged 0 — the
        pre-split aggregate behaviour) or a mapping carrying
        ``bytes_stayed`` / ``bytes_moved``.  Without a model, falls back
        to the scalar ``bytes_per_rank * |nt - ns|`` on the cross link.
        """
        if self.bytes_model is None:
            return 0, max(0, self.bytes_per_rank * abs(nt - ns))
        stats_fn = getattr(self.bytes_model, "stats", None)
        out = stats_fn(ns, nt) if callable(stats_fn) else self.bytes_model(ns, nt)
        if isinstance(out, dict):
            return (max(0, int(out.get("bytes_stayed", 0))),
                    max(0, int(out.get("bytes_moved", 0))))
        return 0, max(0, int(out))

    def redistribution_bytes(self, ns: int, nt: int) -> int:
        """Stage-3 cross-link (moved) bytes for an ``ns -> nt`` resize."""
        return self.redistribution_stats(ns, nt)[1]

    def checkpoint_bytes(self, ns: int) -> int:
        """Snapshot size of the job's full state at ``ns`` ranks.

        A bytes model exposing a ``total_bytes`` attribute (the analytic
        models in :mod:`repro.malleability.cost_model` and
        :class:`repro.elastic.reshard.PytreeBytesModel` all do) is asked
        for the pytree total; otherwise the scalar fallback charges
        ``bytes_per_rank`` per rank — every rank snapshots its share.
        """
        if self.bytes_model is not None:
            total_fn = getattr(self.bytes_model, "total_bytes", None)
            if callable(total_fn):
                return max(0, int(total_fn(ns)))
        return max(0, self.bytes_per_rank * max(0, ns))

    def restore_bytes_on_fail(self, ns: int, nt: int) -> int:
        """Bytes re-read from the last checkpoint after losing ranks.

        Survivors keep their own shards; only the doomed ranks' share of
        the snapshot — ``(ns - nt) / ns`` of it, exact integer floor —
        must come back from the store.
        """
        if ns <= 0 or nt >= ns:
            return 0
        return self.checkpoint_bytes(ns) * (ns - nt) // ns

    def _expand_cross_bytes(
        self, spawn: SpawnPlan, node_ids: Sequence[int], moved: int
    ) -> tuple[int, int]:
        """(rack-, pod-)crossing portions of an expansion's moved bytes.

        Each spawned rank receives its proportional share of the moved
        volume; a destination node whose rack holds NO source rank can
        only be fed across racks, and — when the topology defines pods —
        one whose pod holds no source rank is fed across pods.  Exact
        integer arithmetic (cumulative shares), so the per-class volumes
        always sum to ``moved``.  Without a topology or explicit
        placement everything is one rack.
        """
        if self.topology is None or moved <= 0 or not node_ids:
            return 0, 0
        topo = self.topology
        src_slots = [
            i for i, r in enumerate(spawn.running)
            if r > 0 and i < len(node_ids)
        ]
        src_racks = {topo.rack_of(node_ids[i]) for i in src_slots}
        src_pods = (
            {topo.pod_of(node_ids[i]) for i in src_slots}
            if topo.pod_sizes else set()
        )

        def _cat(node: int) -> int:
            if topo.rack_of(node) in src_racks:
                return 0
            if topo.pod_sizes and topo.pod_of(node) not in src_pods:
                return 2
            return 1

        parts = [
            (s, _cat(node_ids[i]))
            for i, s in enumerate(spawn.to_spawn)
            if s > 0 and i < len(node_ids)
        ]
        return _class_shares(moved, parts)

    def _shrink_cross_bytes(
        self, state: ClusterState, shrink: ShrinkPlan, moved: int
    ) -> tuple[int, int]:
        """(rack-, pod-)crossing portions of a shrink's moved bytes.

        Survivors absorb the doomed ranks' shards proportionally, one
        part per (world, node) a surviving rank sits on — a multi-node
        initial world spanning racks is accounted node by node — and a
        destination node whose rack (pod) holds NO doomed node receives
        its share across racks (pods).
        """
        if self.topology is None or moved <= 0:
            return 0, 0
        topo = self.topology
        doomed = set(shrink.doomed_wids())
        victim_nodes = [
            n for a in shrink.actions if a.wid in doomed for n in a.nodes
        ]
        victim_racks = {topo.rack_of(n) for n in victim_nodes}
        if not victim_racks:
            return 0, 0
        victim_pods = (
            {topo.pod_of(n) for n in victim_nodes}
            if topo.pod_sizes else set()
        )

        def _cat(node: int) -> int:
            if topo.rack_of(node) in victim_racks:
                return 0
            if topo.pod_sizes and topo.pod_of(node) not in victim_pods:
                return 2
            return 1

        survivors = sorted(
            (w for w in state.worlds.values() if w.wid not in doomed),
            key=lambda w: (min(w.nodes), w.wid),
        )
        parts = []
        for w in survivors:
            for node in sorted({r.node for r in w.ranks}):
                n_ranks = sum(1 for r in w.ranks if r.node == node)
                parts.append((n_ranks, _cat(node)))
        return _class_shares(moved, parts)

    def plan_expand(
        self,
        ns: int,
        nt: int,
        cores: Union[int, Sequence[int]],
        *,
        strategy: Optional[StrategyLike] = None,
        method: Optional[Method] = None,
        queue_delay_s: float = 0.0,
        node_ids: Sequence[int] = (),
    ) -> ReconfigPlan:
        """Plan an NS -> NT expansion onto the given allocation.

        Args:
            ns: current rank count (sources).
            nt: target rank count.
            cores: C (homogeneous cores/node) or the per-node A vector
                (heterogeneous, requires a vector-capable strategy).
            strategy: override this engine's strategy for one plan.
            method: override this engine's method for one plan.
            queue_delay_s: RMS arbitration wait charged as a leading
                QUEUE timeline event (see :func:`expansion_timeline`).
            node_ids: cluster node id of each allocation-vector entry
                (source nodes first, then the placement order from
                :meth:`select_expansion_nodes`).  Backends acquire the
                new nodes from this list, and stage-3 bytes resolve
                their distance class through it; empty keeps the greedy
                single-rack behaviour.
        Returns:
            A self-contained :class:`ReconfigPlan` (spawn plan, sync
            graph, connect rounds, resolved per-class redistribution
            bytes).
        """
        spec = get_strategy(strategy if strategy is not None else self.strategy)
        m = method if method is not None else self.method
        spawn = spec.planner(ns, nt, cores, m)
        graph = None
        rounds = 0
        if spec.parallel and spawn.groups:
            graph = build_sync_graph(spawn)
            extend_graph_with_connection(graph, spawn)
            rounds = len(binary_connection_schedule(len(spawn.groups)))
        stayed, moved = self.redistribution_stats(ns, nt)
        xrack, xpod = self._expand_cross_bytes(spawn, node_ids, moved)
        redistribution = RedistributionSpec(
            layout=tuple(global_order(spawn)) if spawn.groups else (),
            ns=ns,
            nt=nt,
            bytes_per_rank=self.bytes_per_rank,
            bytes_total=moved,
            bytes_stayed=stayed,
            bytes_cross_rack=xrack,
            bytes_cross_pod=xpod,
        )
        return ReconfigPlan(
            kind="expand",
            method=m,
            strategy=spawn.strategy,
            asynchronous=self.asynchronous or spec.two_phase,
            ns=ns,
            nt=nt,
            spawn=spawn,
            sync_graph=graph,
            connect_rounds=rounds,
            redistribution=redistribution,
            queue_delay_s=max(0.0, queue_delay_s),
            node_ids=tuple(node_ids),
        )

    def plan_shrink(
        self,
        state: ClusterState,
        release_nodes: Optional[Sequence[int]] = None,
        release_cores: Optional[dict] = None,
        *,
        queue_delay_s: float = 0.0,
        failed: bool = False,
    ) -> ReconfigPlan:
        """Plan a shrink against live cluster bookkeeping.

        Args:
            state: the job's :class:`ClusterState`.
            release_nodes: node ids to release (TS path), or None.
            release_cores: core counts to release instead, or None.
            queue_delay_s: RMS arbitration wait charged as a leading
                QUEUE timeline event (see :func:`shrink_timeline`).
            failed: the released nodes died rather than being returned
                voluntarily.  With :attr:`restore_on_fail` set, the plan
                carries a :class:`CheckpointSpec` whose
                ``bytes_restored`` (:meth:`restore_bytes_on_fail`) is
                charged as a trailing RESTORE event — recovery from the
                last checkpoint.
        Returns:
            A :class:`ReconfigPlan` with the shrink actions, doomed
            world sizes (captured so the timeline can be charged later
            without re-reading possibly-mutated state), and resolved
            redistribution bytes.
        """
        shrink = _plan_shrink_actions(state, release_nodes, release_cores)
        doomed_sizes = tuple(
            state.worlds[wid].size
            for wid in shrink.doomed_wids()
            if wid in state.worlds
        )
        zombified = sum(
            len(a.ranks) for a in shrink.actions if a.kind.value == "zombify_ranks"
        )
        ns = sum(w.size for w in state.worlds.values())
        nt = max(0, ns - sum(doomed_sizes) - zombified)
        stayed, moved = self.redistribution_stats(ns, nt)
        xrack, xpod = self._shrink_cross_bytes(state, shrink, moved)
        ckpt = None
        if failed and self.restore_on_fail:
            ckpt = CheckpointSpec(
                bytes_restored=self.restore_bytes_on_fail(ns, nt))
        return ReconfigPlan(
            kind="shrink",
            method=self.method,
            strategy=self.strategy,
            asynchronous=self.asynchronous,
            ns=ns,
            nt=nt,
            shrink=shrink,
            shrink_world_sizes=doomed_sizes,
            redistribution=RedistributionSpec(
                layout=(),
                ns=ns,
                nt=nt,
                bytes_per_rank=self.bytes_per_rank,
                bytes_total=moved,
                bytes_stayed=stayed,
                bytes_cross_rack=xrack,
                bytes_cross_pod=xpod,
            ),
            queue_delay_s=max(0.0, queue_delay_s),
            checkpoint=ckpt,
        )

    def plan_checkpoint(
        self, ns: int, *, queue_delay_s: float = 0.0
    ) -> ReconfigPlan:
        """Plan a standalone checkpoint of the full state at ``ns`` ranks.

        No allocation change (``nt == ns``); the timeline is a single
        CHECKPOINT event sized by :meth:`checkpoint_bytes`.
        """
        return ReconfigPlan(
            kind="checkpoint",
            method=self.method,
            strategy=self.strategy,
            asynchronous=self.asynchronous,
            ns=ns,
            nt=ns,
            checkpoint=CheckpointSpec(
                bytes_checkpointed=self.checkpoint_bytes(ns)),
            queue_delay_s=max(0.0, queue_delay_s),
        )

    def plan_restart(
        self,
        ns: int,
        nt: int,
        *,
        queue_delay_s: float = 0.0,
        node_ids: Sequence[int] = (),
    ) -> ReconfigPlan:
        """Plan a full-stop checkpoint/restart to ``nt`` ranks.

        The rigid baseline: checkpoint everything, stop, respawn the
        NT-sized world (SS), read everything back.  ``node_ids`` is the
        target placement (the new world's nodes, in acquisition order);
        the respawn call fans out over ``len(node_ids)`` nodes (``nt``
        single-rank nodes when empty).
        """
        total = self.checkpoint_bytes(ns)
        return ReconfigPlan(
            kind="restart",
            method=self.method,
            strategy=self.strategy,
            asynchronous=self.asynchronous,
            ns=ns,
            nt=nt,
            checkpoint=CheckpointSpec(
                bytes_checkpointed=total, bytes_restored=total),
            queue_delay_s=max(0.0, queue_delay_s),
            node_ids=tuple(node_ids),
        )

    # ------------------------------------------------------------- timeline --
    def timeline(self, plan: ReconfigPlan) -> Timeline:
        """Charge a plan as an event timeline with this engine's CostModel.

        The plan's resolved ``redistribution.bytes_total`` is charged as
        a REDISTRIBUTION event, so ``est_wall`` prices data movement for
        every consumer reading this timeline.
        """
        cm = self.cost_model
        assert cm is not None  # resolved in __post_init__
        bytes_total = (
            plan.redistribution.bytes_total if plan.redistribution else 0
        )
        bytes_stayed = (
            plan.redistribution.bytes_stayed if plan.redistribution else 0
        )
        bytes_cross_rack = (
            plan.redistribution.bytes_cross_rack if plan.redistribution else 0
        )
        bytes_cross_pod = (
            plan.redistribution.bytes_cross_pod if plan.redistribution else 0
        )
        if plan.kind == "expand":
            assert plan.spawn is not None
            spec = _STRATEGY_REGISTRY.get(strategy_key(plan.strategy))
            if spec is not None and spec.two_phase:
                # Two-phase (DMR-style) expansion: the grant-acceptance
                # legs hide under compute entirely, subject to the same
                # contention degradation every overlapped event pays.
                cm = cm.with_overlap(spawn=1.0, sync=1.0, connect=1.0)
            return expansion_timeline(
                plan.spawn, cm, bytes_total=bytes_total,
                queue_delay_s=plan.queue_delay_s, bytes_stayed=bytes_stayed,
                bytes_cross_rack=bytes_cross_rack,
                bytes_cross_pod=bytes_cross_pod,
                topology=self.topology, node_ids=plan.node_ids,
            )
        if plan.kind == "shrink":
            assert plan.shrink is not None
            return shrink_timeline(
                plan.shrink.kind,
                cm,
                ns=plan.ns,
                nt=plan.nt,
                doomed_world_sizes=list(plan.shrink_world_sizes) or [1],
                bytes_total=bytes_total,
                queue_delay_s=plan.queue_delay_s,
                bytes_stayed=bytes_stayed,
                bytes_cross_rack=bytes_cross_rack,
                bytes_cross_pod=bytes_cross_pod,
                restore_bytes=(
                    plan.checkpoint.bytes_restored if plan.checkpoint else 0
                ),
            )
        if plan.kind == "checkpoint":
            ck = plan.checkpoint or CheckpointSpec()
            return checkpoint_timeline(
                cm, snapshot_bytes=ck.bytes_checkpointed,
                queue_delay_s=plan.queue_delay_s,
            )
        if plan.kind == "restart":
            ck = plan.checkpoint or CheckpointSpec()
            return restart_timeline(
                cm,
                ns=plan.ns,
                nt=plan.nt,
                nodes=len(plan.node_ids) or max(1, plan.nt),
                snapshot_bytes=ck.bytes_checkpointed,
                restore_bytes=ck.bytes_restored,
                queue_delay_s=plan.queue_delay_s,
            )
        return Timeline()

    # ------------------------------------------------------------- execution --
    def execute(
        self, plan: ReconfigPlan, backend: Optional[ExecutionBackend] = None
    ) -> ReconfigOutcome:
        """Charge the timeline, then let the backend apply the plan.

        Args:
            plan: a plan from :meth:`plan_expand` / :meth:`plan_shrink`.
            backend: optional substrate (live runtime, bookkeeping twin)
                that receives ``apply_expand`` / ``apply_shrink``.
        Returns:
            The :class:`ReconfigOutcome` (plan + charged timeline).
        """
        tl = self.timeline(plan)
        if backend is not None:
            if plan.kind == "expand":
                backend.apply_expand(plan)
            elif plan.kind == "shrink":
                backend.apply_shrink(plan)
            elif plan.kind == "restart":
                # Optional on the protocol: only substrates that can do
                # a full stop + respawn implement it ("checkpoint" plans
                # change no allocation, so they never reach a backend).
                apply_restart = getattr(backend, "apply_restart", None)
                if apply_restart is not None:
                    apply_restart(plan)
        return ReconfigOutcome(plan=plan, timeline=tl)
