"""Iterative Diffusive parallel spawning strategy (paper §4.2).

Handles heterogeneous allocations: nodes may contribute different core
counts, so spawned groups have variable sizes.  The allocation is
described by three vectors over the ``N`` nodes:

    A_i  cores assigned to the job on node i
    R_i  ranks of the job already running on node i
    S_i  ranks to spawn on node i,  S_i = A_i - R_i

Each round ``s`` the ``t_{s-1}`` live processes consume the next
contiguous ``t_{s-1}`` entries of ``S`` (one entry per live process, in
canonical process order); every positive entry spawns one node-confined
group of that size:

    t_s      = t_{s-1} + g_s,            t_0 = sum(R)      [Eq. 4]
    g_s      = sum_{i=lam_{s-1}}^{min(N,lam_s)-1} S_i      [Eq. 5]
    lam_s    = lam_{s-1} + t_{s-1},      lam_0 = 0         [Eq. 6]
    T_s      = T_{s-1} + G_s,            T_0 = I           [Eq. 7]
    G_s      = #{ i in range : R_i == 0 and S_i > 0 }      [Eq. 8]

NOTE on the paper's Table 2: iterating Eq. 6 gives lam = [0, 2, 8, 48]
for the worked example; the table prints lam_2 = 7 and lam_3 = 47, an
off-by-one typo propagated through the last two rows (all other columns
-- t, g, T, G -- match Eq. 4-8 exactly, as our tests assert).
"""
from __future__ import annotations

from collections.abc import Sequence

from .types import SOURCE_GID, GroupSpec, Method, SpawnPlan, StepTrace, Strategy


def plan_diffusive(
    cores: Sequence[int],
    running: Sequence[int],
    method: Method = Method.MERGE,
) -> SpawnPlan:
    """Build the iterative diffusive spawn plan from vectors A and R.

    For BASELINE the sources do not persist into the target world, so the
    full allocation is spawned fresh (S = A) while the R vector still
    provides the round-0 spawner pool.
    """
    if len(cores) != len(running):
        raise ValueError("A and R vectors must have equal length")
    n_nodes = len(cores)
    a_vec = [int(a) for a in cores]
    r_vec = [int(r) for r in running]
    if any(a < 0 for a in a_vec) or any(r < 0 for r in r_vec):
        raise ValueError("A and R must be non-negative")
    ns = sum(r_vec)
    if ns <= 0:
        raise ValueError("need at least one source process")

    if method is Method.MERGE:
        s_vec = [max(0, a - r) for a, r in zip(a_vec, r_vec)]
        if any(a < r for a, r in zip(a_vec, r_vec)):
            raise ValueError(
                "negative S entries: mixed shrink+expand must route the "
                "shrink part through the shrink planner first"
            )
    else:
        s_vec = list(a_vec)  # spawn the whole target allocation fresh

    # Canonical spawner order: sources first (node order, then local rank),
    # then spawned groups by gid.
    spawners: list[tuple[int, int]] = [(SOURCE_GID, r) for r in range(ns)]
    groups: list[GroupSpec] = []
    initial_nodes = sum(1 for r in r_vec if r > 0)
    trace: list[StepTrace] = [
        StepTrace(s=0, t=ns, g=0, lam=0, T=initial_nodes, G=0)
    ]
    gid = 0
    step = 0
    lam_prev = 0
    t_prev = ns
    remaining = sum(s_vec)
    while lam_prev < n_nodes and remaining > 0:
        step += 1
        lam_s = lam_prev + t_prev                       # Eq. 6
        lo, hi = lam_prev, min(n_nodes, lam_s)          # Eq. 5 index range
        g_s = 0
        G_s = 0
        new_groups: list[GroupSpec] = []
        for offset, i in enumerate(range(lo, hi)):
            if s_vec[i] <= 0:
                continue  # null S entries are disregarded (paper §4.2)
            pg, pr = spawners[offset]
            new_groups.append(
                GroupSpec(
                    gid=gid,
                    node=i,
                    size=s_vec[i],
                    step=step,
                    parent_gid=pg,
                    parent_rank=pr,
                )
            )
            gid += 1
            g_s += s_vec[i]
            if r_vec[i] == 0:                           # Eq. 8 condition
                G_s += 1
        groups.extend(new_groups)
        for g in new_groups:
            spawners.extend((g.gid, r) for r in range(g.size))
        prev = trace[-1]
        trace.append(
            StepTrace(s=step, t=prev.t + g_s, g=g_s, lam=lam_s, T=prev.T + G_s, G=G_s)
        )
        lam_prev = lam_s
        t_prev = prev.t + g_s
        remaining -= g_s

    nt = sum(s_vec) + (ns if method is Method.MERGE else 0)
    return SpawnPlan(
        method=method,
        strategy=Strategy.PARALLEL_DIFFUSIVE,
        nodes=n_nodes,
        cores=tuple(a_vec),
        running=tuple(r_vec),
        to_spawn=tuple(s_vec),
        groups=tuple(groups),
        steps=step,
        trace=tuple(trace),
        ns=ns,
        nt=nt,
    )
