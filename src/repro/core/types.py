"""Core datatypes for the parallel-spawning malleability framework.

Terminology follows the paper (Martín-Álvarez et al., "Parallel Spawning
Strategies for Dynamic-Aware MPI Applications"):

* *source* processes — the NS ranks alive before a reconfiguration.
* *target* processes — the NT ranks alive after it.
* *group*  — one spawned process-group; by construction each group's
  world (its MCW in MPI terms) is confined to a single node, which is
  what enables Termination Shrinkage (TS).
* *method* — BASELINE (spawn all NT, drop sources) or MERGE (reuse
  sources, spawn only the difference).
* *strategy* — how the spawn phase is executed.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional, Union

SOURCE_GID = -1  # pseudo group-id of the initial (source) group


class Method(enum.Enum):
    """Process-management method (MaM §3)."""

    BASELINE = "baseline"  # spawn NT fresh ranks, terminate the NS sources
    MERGE = "merge"        # reuse sources, spawn/terminate only the delta


class Strategy(enum.Enum):
    """Spawning strategy (MaM §3 + this paper §4)."""

    SEQUENTIAL = "sequential"            # one collective spawn call (classic Merge)
    SEQUENTIAL_PER_NODE = "per_node"     # one spawn call per node, serial ([14])
    SINGLE = "single"                    # only rank 0 spawns, informs the rest
    PARALLEL_HYPERCUBE = "hypercube"     # §4.1 (homogeneous allocations)
    PARALLEL_DIFFUSIVE = "diffusive"     # §4.2 (heterogeneous allocations)


class ShrinkKind(enum.Enum):
    """Shrinkage mechanisms compared in the paper (§1, §4.7)."""

    SS = "spawn_shrinkage"        # respawn the whole job smaller (Baseline)
    ZS = "zombie_shrinkage"       # excess ranks sleep; nodes stay pinned
    TS = "termination_shrinkage"  # whole node-confined worlds terminate


@dataclass(frozen=True)
class GroupSpec:
    """One spawned process group (one `MPI_Comm_spawn` in the paper).

    Attributes:
      gid:         group identifier, 0..G-1 in node order (§4.1/§4.2).
      node:        node index the group is confined to.
      size:        number of ranks in the group (== S[node] for diffusive,
                   == C for hypercube).
      step:        spawning round (1-based; round 0 is the initial state).
      parent_gid:  gid of the group whose member issued the spawn
                   (SOURCE_GID for the initial group).
      parent_rank: local rank of the spawning member inside its group.
    """

    gid: int
    node: int
    size: int
    step: int
    parent_gid: int
    parent_rank: int
    # Nodes the group's world spans.  Parallel strategies always produce
    # node-confined groups (len == 1, the TS-enabling invariant); the
    # classic SEQUENTIAL spawn produces one world spanning many nodes,
    # which is exactly what makes TS impossible for it.
    spans: tuple[int, ...] = ()

    def nodes_spanned(self) -> tuple[int, ...]:
        return self.spans if self.spans else (self.node,)


@dataclass(frozen=True)
class StepTrace:
    """Per-step bookkeeping matching the paper's Eqs. 1-8 / Table 2.

    t: total processes existing at END of step  (Eq. 2 / Eq. 4)
    g: processes generated during the step      (Eq. 5)
    lam: lambda_s, start index into S for the NEXT step (Eq. 6)
    T: total occupied nodes at end of step      (Eq. 1 / Eq. 7)
    G: new nodes added during the step          (Eq. 8)
    """

    s: int
    t: int
    g: int
    lam: int
    T: int
    G: int


@dataclass(frozen=True)
class SpawnPlan:
    """Complete description of one parallel spawn phase.

    The plan is purely declarative: the simulator executes it with a cost
    model, the elastic runtime executes it against real device groups.
    """

    method: Method
    # Built-in plans carry the Strategy enum; third-party registered
    # strategies (e.g. repro.core.topo) carry their registry key string.
    # Normalize with repro.core.strategy_key when a label is needed.
    strategy: Union[Strategy, str]
    nodes: int                     # N, nodes in the target allocation
    cores: tuple[int, ...]         # A vector (cores per node)
    running: tuple[int, ...]       # R vector (ranks running per node)
    to_spawn: tuple[int, ...]      # S vector (ranks to spawn per node)
    groups: tuple[GroupSpec, ...]  # all spawned groups, gid order
    steps: int                     # spawn rounds used
    trace: tuple[StepTrace, ...]   # per-step closed-form bookkeeping
    ns: int                        # source processes
    nt: int                        # target processes

    @property
    def group_sizes(self) -> tuple[int, ...]:
        return tuple(g.size for g in self.groups)

    def groups_in_step(self, s: int) -> list[GroupSpec]:
        return [g for g in self.groups if g.step == s]


@dataclass
class RankInfo:
    """Per-rank bookkeeping the root of each world maintains (§4.7)."""

    rank: int
    node: int
    zombie: bool = False


@dataclass
class World:
    """A node-confined communicator (one MCW) tracked by the global root.

    §4.7: the global root keeps, for each MCW, the nodelist where it
    executes; each world root keeps active/zombie status per rank.
    """

    wid: int
    nodes: tuple[int, ...]          # nodes this world spans (len==1 unless initial)
    ranks: list[RankInfo] = field(default_factory=list)
    is_initial: bool = False        # the job-start MCW (may span many nodes)

    @property
    def size(self) -> int:
        return len(self.ranks)

    @property
    def active_ranks(self) -> list[RankInfo]:
        return [r for r in self.ranks if not r.zombie]

    @property
    def all_zombie(self) -> bool:
        return all(r.zombie for r in self.ranks)


class ShrinkActionKind(enum.Enum):
    TERMINATE_WORLD = "terminate_world"   # TS: world exits, nodes returned
    ZOMBIFY_RANKS = "zombify_ranks"       # ZS: ranks sleep, node NOT returned
    AWAKEN_AND_TERMINATE = "awaken_and_terminate"  # all-zombie world -> TS (§4.7)
    MIGRATE_ROOT = "migrate_root"         # global root hand-off (§4.7)
    PARALLEL_RESPAWN = "parallel_respawn" # initial multi-node MCW fix (§4.6)
    POSTPONE = "postpone"                 # defer the initial-MCW problem (§4.6)


@dataclass(frozen=True)
class ShrinkAction:
    kind: ShrinkActionKind
    wid: Optional[int] = None
    ranks: tuple[int, ...] = ()
    nodes: tuple[int, ...] = ()
    new_root_wid: Optional[int] = None


@dataclass(frozen=True)
class ShrinkPlan:
    kind: ShrinkKind                   # dominant mechanism used
    actions: tuple[ShrinkAction, ...]
    nodes_returned: tuple[int, ...]    # nodes actually handed back to the RMS
    nodes_pinned: tuple[int, ...]      # nodes that stay pinned by zombies

    def doomed_wids(self) -> tuple[int, ...]:
        """Worlds this plan terminates (the single source both the engine's
        timeline charging and the live backend's node release consume)."""
        return tuple(
            a.wid
            for a in self.actions
            if a.wid is not None
            and a.kind in (ShrinkActionKind.TERMINATE_WORLD,
                           ShrinkActionKind.AWAKEN_AND_TERMINATE)
        )
