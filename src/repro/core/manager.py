"""MalleabilityManager — the MaM-equivalent facade (paper §3, §4.6).

Given a current cluster state and a target allocation, produce a
:class:`ReconfigPlan` describing the four malleability stages:

  1. reconfiguration feasibility (delegated to the RMS / caller),
  2. process management        (spawn plan or shrink plan),
  3. data redistribution       (a declarative spec the elastic runtime
                                or the simulator executes),
  4. resume.

Methods and strategies mirror MaM: BASELINE / MERGE methods, combined
with SEQUENTIAL / SEQUENTIAL_PER_NODE / SINGLE / PARALLEL_HYPERCUBE /
PARALLEL_DIFFUSIVE spawning strategies and the ASYNC overlap flag.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

from .connect import binary_connection_schedule, extend_graph_with_connection
from .diffusive import plan_diffusive
from .hypercube import plan_hypercube
from .reorder import global_order
from .shrink import ClusterState, plan_shrink
from .sync import EventGraph, build_sync_graph
from .types import (
    SOURCE_GID,
    GroupSpec,
    Method,
    ShrinkPlan,
    SpawnPlan,
    Strategy,
    StepTrace,
)


def plan_sequential(
    ns: int,
    nt: int,
    cores: Sequence[int],
    method: Method,
    per_node: bool = False,
    single: bool = False,
) -> SpawnPlan:
    """Classic (non-parallel) spawn plans used as baselines.

    * ``per_node=False``: ONE collective ``MPI_Comm_spawn`` creating every
      new rank at once; the spawned world spans all target nodes — fast to
      expand but structurally incapable of TS (the paper's motivation).
    * ``per_node=True``: one spawn per node, issued serially by the root
      ([14]'s approach) — node-confined worlds but O(nodes) latency.
    * ``single``: only rank 0 drives the spawns (MaM's Single strategy).
    """
    cores = tuple(int(c) for c in cores)
    n_nodes = len(cores)
    spawn_total = nt - ns if method is Method.MERGE else nt
    if spawn_total < 0:
        raise ValueError("expansion planner called for a shrink")
    running: list[int] = []
    remaining = ns
    for c in cores:
        take = min(c, remaining)
        running.append(take)
        remaining -= take
    s_vec = [a - r for a, r in zip(cores, running)] if method is Method.MERGE else list(cores)

    groups: list[GroupSpec] = []
    if per_node:
        gid = 0
        for node, size in enumerate(s_vec):
            if size <= 0:
                continue
            groups.append(
                GroupSpec(
                    gid=gid,
                    node=node,
                    size=size,
                    step=gid + 1,  # serial: one round each
                    parent_gid=SOURCE_GID,
                    parent_rank=0,
                )
            )
            gid += 1
    elif spawn_total > 0:
        spanned = tuple(i for i, s in enumerate(s_vec) if s > 0)
        groups.append(
            GroupSpec(
                gid=0,
                node=spanned[0] if spanned else 0,
                size=spawn_total,
                step=1,
                parent_gid=SOURCE_GID,
                parent_rank=0,
                spans=spanned,
            )
        )

    strategy = (
        Strategy.SEQUENTIAL_PER_NODE if per_node else (Strategy.SINGLE if single else Strategy.SEQUENTIAL)
    )
    steps = len(groups) if per_node else (1 if groups else 0)
    trace = [StepTrace(s=0, t=ns, g=0, lam=0, T=sum(1 for r in running if r), G=0)]
    t = ns
    for i, g in enumerate(groups):
        t += g.size
        trace.append(StepTrace(s=i + 1, t=t, g=g.size, lam=0, T=0, G=0))
    return SpawnPlan(
        method=method,
        strategy=strategy,
        nodes=n_nodes,
        cores=cores,
        running=tuple(running),
        to_spawn=tuple(s_vec),
        groups=tuple(groups),
        steps=steps,
        trace=tuple(trace),
        ns=ns,
        nt=nt,
    )


@dataclass(frozen=True)
class RedistributionSpec:
    """Stage-3 data movement: which final ranks receive which data shards.

    ``layout`` maps final global rank -> (group_id, local_rank); the
    elastic runtime turns this into a device permutation + resharding
    plan; the simulator charges bytes/bandwidth for it.
    """

    layout: tuple[tuple[int, int], ...]
    ns: int
    nt: int
    bytes_per_rank: int = 0


@dataclass(frozen=True)
class ReconfigPlan:
    """Full output of the process-management stage."""

    kind: str                      # "expand" | "shrink" | "noop"
    method: Method
    strategy: Strategy
    asynchronous: bool
    spawn: Optional[SpawnPlan] = None
    shrink: Optional[ShrinkPlan] = None
    sync_graph: Optional[EventGraph] = None
    connect_rounds: int = 0
    redistribution: Optional[RedistributionSpec] = None


@dataclass
class MalleabilityManager:
    """User-facing facade, one per job (mirrors MaM's init/config API)."""

    method: Method = Method.MERGE
    strategy: Strategy = Strategy.PARALLEL_HYPERCUBE
    asynchronous: bool = False
    bytes_per_rank: int = 0
    state: ClusterState = field(default_factory=ClusterState)

    # -- stage 2: process management --------------------------------------------
    def plan_expand(
        self,
        ns: int,
        nt: int,
        cores: Sequence[int] | int,
    ) -> ReconfigPlan:
        """Plan an NS -> NT expansion onto the given allocation.

        ``cores`` is either C (homogeneous, enables the hypercube) or the
        per-node A vector (heterogeneous, requires the diffusive strategy).
        """
        homogeneous = isinstance(cores, int)
        if self.strategy is Strategy.PARALLEL_HYPERCUBE:
            if not homogeneous:
                raise ValueError(
                    "hypercube strategy requires homogeneous allocations; "
                    "use PARALLEL_DIFFUSIVE (paper §4.2)"
                )
            spawn = plan_hypercube(ns, nt, cores, self.method)
        elif self.strategy is Strategy.PARALLEL_DIFFUSIVE:
            a_vec = self._as_vector(cores, nt)
            r_vec = self._running_vector(a_vec, ns)
            spawn = plan_diffusive(a_vec, r_vec, self.method)
        else:
            a_vec = self._as_vector(cores, nt)
            spawn = plan_sequential(
                ns,
                nt,
                a_vec,
                self.method,
                per_node=self.strategy is Strategy.SEQUENTIAL_PER_NODE,
                single=self.strategy is Strategy.SINGLE,
            )

        graph = None
        rounds = 0
        if spawn.strategy in (Strategy.PARALLEL_HYPERCUBE, Strategy.PARALLEL_DIFFUSIVE):
            graph = build_sync_graph(spawn)
            extend_graph_with_connection(graph, spawn)
            rounds = len(binary_connection_schedule(len(spawn.groups)))
        redistribution = RedistributionSpec(
            layout=tuple(global_order(spawn)) if spawn.groups else (),
            ns=ns,
            nt=nt,
            bytes_per_rank=self.bytes_per_rank,
        )
        return ReconfigPlan(
            kind="expand",
            method=self.method,
            strategy=spawn.strategy,
            asynchronous=self.asynchronous,
            spawn=spawn,
            sync_graph=graph,
            connect_rounds=rounds,
            redistribution=redistribution,
        )

    def plan_shrink(self, release_nodes=None, release_cores=None) -> ReconfigPlan:
        shrink = plan_shrink(self.state, release_nodes, release_cores)
        return ReconfigPlan(
            kind="shrink",
            method=self.method,
            strategy=self.strategy,
            asynchronous=self.asynchronous,
            shrink=shrink,
        )

    # -- helpers -----------------------------------------------------------------
    @staticmethod
    def _as_vector(cores: Sequence[int] | int, nt: int) -> list[int]:
        if isinstance(cores, int):
            n_nodes = -(-nt // cores)
            return [cores] * n_nodes
        return [int(c) for c in cores]

    @staticmethod
    def _running_vector(a_vec: Sequence[int], ns: int) -> list[int]:
        out = []
        remaining = ns
        for a in a_vec:
            take = min(a, remaining)
            out.append(take)
            remaining -= take
        if remaining:
            raise ValueError("sources do not fit in the allocation vector")
        return out
