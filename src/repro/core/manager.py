"""MalleabilityManager — the MaM-equivalent facade (paper §3, §4.6).

A thin application-facing wrapper over :class:`repro.core.engine.ReconfigEngine`:
it holds the job-wide configuration (method, strategy, ASYNC flag, data
volume) plus the live :class:`ClusterState`, and delegates all planning
to the engine's strategy registry.  The four malleability stages:

  1. reconfiguration feasibility (delegated to the RMS / caller),
  2. process management        (spawn plan or shrink plan — the engine),
  3. data redistribution       (a declarative spec the elastic runtime
                                or the simulator executes),
  4. resume.

Methods and strategies mirror MaM: BASELINE / MERGE methods, combined
with SEQUENTIAL / SEQUENTIAL_PER_NODE / SINGLE / PARALLEL_HYPERCUBE /
PARALLEL_DIFFUSIVE spawning strategies and the ASYNC overlap flag.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .engine import (
    ReconfigEngine,
    ReconfigPlan,
    RedistributionSpec,  # noqa: F401  (re-exported: historical home)
)
from .sequential import plan_sequential  # noqa: F401  (re-exported: historical home)
from .shrink import ClusterState
from .types import Method, Strategy


@dataclass
class MalleabilityManager:
    """User-facing facade, one per job (mirrors MaM's init/config API)."""

    method: Method = Method.MERGE
    strategy: Strategy = Strategy.PARALLEL_HYPERCUBE
    asynchronous: bool = False
    bytes_per_rank: int = 0
    state: ClusterState = field(default_factory=ClusterState)

    @property
    def engine(self) -> ReconfigEngine:
        return ReconfigEngine(
            method=self.method,
            strategy=self.strategy,
            asynchronous=self.asynchronous,
            bytes_per_rank=self.bytes_per_rank,
        )

    # -- stage 2: process management --------------------------------------------
    def plan_expand(
        self,
        ns: int,
        nt: int,
        cores: Sequence[int] | int,
    ) -> ReconfigPlan:
        """Plan an NS -> NT expansion onto the given allocation.

        ``cores`` is either C (homogeneous, enables the hypercube) or the
        per-node A vector (heterogeneous, requires the diffusive strategy).
        """
        return self.engine.plan_expand(ns, nt, cores)

    def plan_shrink(self, release_nodes=None, release_cores=None) -> ReconfigPlan:
        return self.engine.plan_shrink(self.state, release_nodes, release_cores)
