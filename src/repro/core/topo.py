"""Topology-aware spawning strategy: rack-local placement, rack-vacating
shrinks.

The paper's two testbeds differ mainly in node layout, and its shrink
advantage comes from returning whole allocation units to the RMS.  This
module makes that a *strategy* decision:

* **spawn structure** — groups are spawned with the iterative diffusive
  rounds (§4.2: the vector-capable parallel strategy), so the charged
  spawn/sync/connect timeline is identical to ``diffusive`` for the same
  allocation vector.  What changes is *which nodes end up in the
  vector*:
* **expansion placement** (:func:`place_rack_local`) — free nodes inside
  racks the job already occupies come first (most-occupied rack first),
  then fresh racks are packed whole (pod-local and fullest-first), so
  later shrinks can vacate complete racks;
* **shrink placement** (:func:`vacate_racks`) — victims are chosen so
  whole racks empty first, handing the RMS back rack-granular capacity
  exactly as TS hands back node-granular worlds.

Registered under the key ``"topo"`` through the ordinary third-party
extension point (:func:`repro.core.engine.register_strategy`): the
simulator, the live runtime, the trainer, and the benchmarks all pick it
up from the registry with no further wiring.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Iterable, Union

from .diffusive import plan_diffusive
from .engine import (
    StrategySpec,
    as_core_vector,
    register_strategy,
    running_vector,
)
from .topology import Topology
from .types import Method, SpawnPlan

TOPO_KEY = "topo"


# ------------------------------------------------------------- placement --
def place_rack_local(
    topology: Topology,
    used: set[int],
    free: set[int],
    need: int,
) -> list[int]:
    """Choose ``need`` free nodes for an expansion, rack-local-first.

    Order of preference:

    1. free nodes in racks the job already occupies (most-occupied rack
       first, node ids ascending within a rack) — new groups land next
       to their sources;
    2. fresh racks, packed whole: racks in pods the job already touches
       first, then racks with the most free nodes (a fresh rack the
       expansion can fill completely stays whole for a later
       rack-granular shrink), rack id as the final tiebreak;
    3. any remaining free nodes in id order (safety net — only reachable
       when the topology does not cover every pool node).

    Returns the chosen node ids in fill order (the plan's allocation
    vector tail).  Raises if the pool cannot satisfy the request.
    """
    if need <= 0:
        return []
    remaining_free = set(free)
    chosen: list[int] = []

    occupancy: dict[int, int] = {}
    for n in used:
        rack = topology.rack_of(n)
        occupancy[rack] = occupancy.get(rack, 0) + 1

    def take_rack(rack: int) -> None:
        for n in topology.nodes_in_rack(rack):
            if len(chosen) >= need:
                return
            if n in remaining_free:
                chosen.append(n)
                remaining_free.discard(n)

    for rack in sorted(occupancy, key=lambda r: (-occupancy[r], r)):
        take_rack(rack)
        if len(chosen) >= need:
            return chosen

    used_pods = {topology.pod_of_rack(r) for r in occupancy}

    def fresh_key(rack: int) -> tuple[int, int, int]:
        n_free = sum(
            1 for n in topology.nodes_in_rack(rack) if n in remaining_free
        )
        return (0 if topology.pod_of_rack(rack) in used_pods else 1,
                -n_free, rack)

    fresh = [r for r in range(topology.n_racks) if r not in occupancy]
    for rack in sorted(fresh, key=fresh_key):
        take_rack(rack)
        if len(chosen) >= need:
            return chosen

    for n in sorted(remaining_free):
        if len(chosen) >= need:
            return chosen
        chosen.append(n)
    if len(chosen) < need:
        raise RuntimeError(
            f"placement needs {need} free nodes, pool has {len(free)}"
        )
    return chosen


def vacate_racks(
    topology: Topology,
    used: set[int],
    n_release: int,
) -> list[int]:
    """Choose ``n_release`` victims so whole racks empty first.

    Whole racks whose used-node count fits the remaining release budget
    go first (fewest used nodes first — the cheapest racks to hand back
    complete — rack id descending as the tiebreak, matching the default
    highest-id-first release flavour); any remainder comes from the
    least-occupied surviving rack, highest node ids first.  Returns the
    victim ids sorted ascending (the shrink planner takes a set).

    Deliberately fewest-first, NOT best-fit: when the budget exactly
    matches a larger rack's occupancy, this policy still empties the
    small racks and fragments the large one — trading one fragmented
    rack for keeping the job's biggest rack partially occupied, which
    is what lets the next expansion land rack-local
    (:func:`place_rack_local`) instead of reopening a vacated rack
    cross-rack.  A placement optimizer weighing the two objectives
    against the trace is a ROADMAP follow-up.
    """
    if n_release <= 0:
        return []
    by_rack: dict[int, list[int]] = {}
    for n in sorted(used):
        by_rack.setdefault(topology.rack_of(n), []).append(n)

    victims: list[int] = []
    remaining = min(n_release, len(used))
    racks = sorted(by_rack, key=lambda r: (len(by_rack[r]), -r))
    for rack in racks:
        if remaining <= 0:
            break
        if len(by_rack[rack]) <= remaining:
            victims.extend(by_rack[rack])
            remaining -= len(by_rack[rack])
            by_rack[rack] = []
    if remaining > 0:
        rest = sorted((r for r in racks if by_rack[r]),
                      key=lambda r: (len(by_rack[r]), -r))
        for rack in rest:
            if remaining <= 0:
                break
            take = by_rack[rack][len(by_rack[rack]) - remaining:]
            victims.extend(take)
            remaining -= len(take)
    return sorted(victims)


# --------------------------------------------------------------- planner --
def plan_topo(
    ns: int,
    nt: int,
    cores: Union[int, Iterable[int]],
    method: Method = Method.MERGE,
) -> SpawnPlan:
    """Topology-aware spawn plan (normalized ``(ns, nt, cores, method)``).

    The allocation vector arrives already in placement order (sources
    first, then :func:`place_rack_local`'s fill order — the engine's
    ``select_expansion_nodes`` produced it), so the spawn structure is
    the iterative diffusive plan over that vector, re-tagged with this
    strategy's registry key.  Charged cost equals ``diffusive`` on the
    same vector; what the strategy changes is where the vector's nodes
    live — and therefore which distance class every stage-3 byte pays.
    """
    a_vec = as_core_vector(
        cores if isinstance(cores, int) else list(cores), nt
    )
    plan = plan_diffusive(a_vec, running_vector(a_vec, ns), method)
    return replace(plan, strategy=TOPO_KEY)


register_strategy(StrategySpec(
    key=TOPO_KEY,
    planner=plan_topo,
    parallel=True,
    topology_aware=True,
    description=("diffusive spawn rounds with rack/pod-local placement; "
                 "shrinks vacate whole racks"),
))
