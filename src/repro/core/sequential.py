"""Classic (non-parallel) spawning strategies used as baselines (MaM §3).

* SEQUENTIAL: ONE collective ``MPI_Comm_spawn`` creating every new rank
  at once; the spawned world spans all target nodes — fast to expand but
  structurally incapable of TS (the paper's motivation).
* SEQUENTIAL_PER_NODE: one spawn per node, issued serially by the root
  ([14]'s approach) — node-confined worlds but O(nodes) latency.
* SINGLE: only rank 0 drives the spawn (MaM's Single strategy).
"""
from __future__ import annotations

from typing import Sequence

from .types import SOURCE_GID, GroupSpec, Method, SpawnPlan, StepTrace, Strategy


def plan_sequential(
    ns: int,
    nt: int,
    cores: Sequence[int],
    method: Method,
    per_node: bool = False,
    single: bool = False,
) -> SpawnPlan:
    """Build the spawn plan for the classic strategies (see module doc)."""
    cores = tuple(int(c) for c in cores)
    n_nodes = len(cores)
    spawn_total = nt - ns if method is Method.MERGE else nt
    if spawn_total < 0:
        raise ValueError("expansion planner called for a shrink")
    running: list[int] = []
    remaining = ns
    for c in cores:
        take = min(c, remaining)
        running.append(take)
        remaining -= take
    s_vec = [a - r for a, r in zip(cores, running)] if method is Method.MERGE else list(cores)

    groups: list[GroupSpec] = []
    if per_node:
        gid = 0
        for node, size in enumerate(s_vec):
            if size <= 0:
                continue
            groups.append(
                GroupSpec(
                    gid=gid,
                    node=node,
                    size=size,
                    step=gid + 1,  # serial: one round each
                    parent_gid=SOURCE_GID,
                    parent_rank=0,
                )
            )
            gid += 1
    elif spawn_total > 0:
        spanned = tuple(i for i, s in enumerate(s_vec) if s > 0)
        groups.append(
            GroupSpec(
                gid=0,
                node=spanned[0] if spanned else 0,
                size=spawn_total,
                step=1,
                parent_gid=SOURCE_GID,
                parent_rank=0,
                spans=spanned,
            )
        )

    strategy = (
        Strategy.SEQUENTIAL_PER_NODE if per_node else (Strategy.SINGLE if single else Strategy.SEQUENTIAL)
    )
    steps = len(groups) if per_node else (1 if groups else 0)
    trace = [StepTrace(s=0, t=ns, g=0, lam=0, T=sum(1 for r in running if r), G=0)]
    t = ns
    for i, g in enumerate(groups):
        t += g.size
        trace.append(StepTrace(s=i + 1, t=t, g=g.size, lam=0, T=0, G=0))
    return SpawnPlan(
        method=method,
        strategy=strategy,
        nodes=n_nodes,
        cores=cores,
        running=tuple(running),
        to_spawn=tuple(s_vec),
        groups=tuple(groups),
        steps=steps,
        trace=tuple(trace),
        ns=ns,
        nt=nt,
    )
