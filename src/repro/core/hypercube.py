"""Hypercube parallel spawning strategy (paper §4.1).

Homogeneous allocations only: every node contributes exactly ``C`` cores,
and every spawned group has size ``C``.  In each round every live process
issues one spawn (``MPI_Comm_spawn`` over ``MPI_COMM_SELF`` in the paper)
creating one ``C``-sized group on a fresh node, so the node count grows by
the factor ``C + 1`` per round:

    T_s = (C+1)^s * I - I   (Baseline)        [Eq. 1]
    T_s = (C+1)^s * I       (Merge)           [Eq. 1]
    t_s = C * T_s                              [Eq. 2]
    s   = ceil( ln(N / I) / ln(C + 1) )        [Eq. 3]

with I = NS / C initial nodes and N = NT / C target nodes.
"""
from __future__ import annotations

import math

from .types import SOURCE_GID, GroupSpec, Method, SpawnPlan, StepTrace, Strategy


def steps_required(n_nodes: int, initial_nodes: int, cores: int) -> int:
    """Closed-form number of spawning rounds, Eq. 3.

    ``N`` target nodes, ``I`` initial nodes, ``C`` cores per node.
    """
    if n_nodes <= initial_nodes:
        return 0
    return math.ceil(
        math.log(n_nodes / initial_nodes) / math.log(cores + 1) - 1e-12
    )


def nodes_at_step(s: int, initial_nodes: int, cores: int, method: Method) -> int:
    """Cumulative spawnable node capacity at step ``s`` (Eq. 1)."""
    total = (cores + 1) ** s * initial_nodes
    if method is Method.BASELINE:
        total -= initial_nodes
    return total


def procs_at_step(s: int, initial_nodes: int, cores: int, method: Method) -> int:
    """Eq. 2: processes = C * nodes."""
    return cores * nodes_at_step(s, initial_nodes, cores, method)


def plan_hypercube(
    ns: int, nt: int, cores: int, method: Method = Method.MERGE
) -> SpawnPlan:
    """Build the full hypercube spawn plan for NS -> NT ranks.

    Requires ``NS % C == 0`` and ``NT % C == 0`` (paper precondition).
    Group ids are assigned in spawn order, which by construction is node
    order, so Eq. 9's reordering yields node-contiguous global ranks.
    """
    if ns % cores or nt % cores:
        raise ValueError(
            f"hypercube requires NS ({ns}) and NT ({nt}) divisible by C ({cores})"
        )
    if ns <= 0:
        raise ValueError("need at least one source process")
    initial_nodes = ns // cores
    n_nodes = nt // cores
    if method is Method.MERGE:
        n_groups = n_nodes - initial_nodes
    else:
        # Baseline replaces the sources: spawn the full target allocation.
        n_groups = n_nodes
    if n_groups < 0:
        raise ValueError("hypercube plans expansions; use the shrink planner")

    # Target nodes: fresh nodes first (I..N-1); Baseline additionally
    # re-populates the source nodes 0..I-1 last (transient oversubscription,
    # which the paper observes as the Baseline overhead in Fig. 4a).  For a
    # Baseline *shrink* (N < I) every target node is source-occupied.
    fresh = list(range(initial_nodes, n_nodes))
    node_of_gid = fresh + (
        list(range(min(initial_nodes, n_nodes))) if method is Method.BASELINE else []
    )
    assert len(node_of_gid) == n_groups

    # Canonical spawner order: source ranks first, then groups by gid, each
    # by local rank.  Every spawner creates at most one group per round.
    spawners: list[tuple[int, int]] = [(SOURCE_GID, r) for r in range(ns)]
    groups: list[GroupSpec] = []
    trace: list[StepTrace] = [
        StepTrace(s=0, t=ns, g=0, lam=0, T=initial_nodes, G=0)
    ]
    gid = 0
    step = 0
    while gid < n_groups:
        step += 1
        budget = min(len(spawners), n_groups - gid)  # final-round truncation
        new_groups: list[GroupSpec] = []
        for i in range(budget):
            pg, pr = spawners[i]
            new_groups.append(
                GroupSpec(
                    gid=gid,
                    node=node_of_gid[gid],
                    size=cores,
                    step=step,
                    parent_gid=pg,
                    parent_rank=pr,
                )
            )
            gid += 1
        groups.extend(new_groups)
        for g in new_groups:
            spawners.extend((g.gid, r) for r in range(g.size))
        prev = trace[-1]
        g_s = sum(g.size for g in new_groups)
        G_s = len({g.node for g in new_groups} - set(range(initial_nodes)))
        trace.append(
            StepTrace(
                s=step,
                t=prev.t + g_s,
                g=g_s,
                lam=0,  # lambda is a diffusive-only concept
                T=prev.T + G_s,
                G=G_s,
            )
        )

    # Cross-check the constructive plan against the closed forms (Eqs. 1-3).
    expected_steps = steps_required(n_nodes, initial_nodes, cores)
    if method is Method.BASELINE:
        # Baseline spawns N (not N-I) groups; capacity check uses Eq. 1's
        # Baseline branch: (C+1)^s * I - I >= N.
        expected_steps = 0
        while nodes_at_step(expected_steps, initial_nodes, cores, method) < n_nodes:
            expected_steps += 1
    if step != expected_steps:
        raise AssertionError(
            f"constructive plan used {step} steps, closed form says {expected_steps}"
        )

    n_vec = max(n_nodes, initial_nodes)
    a_vec = [cores] * n_nodes + [0] * (n_vec - n_nodes)
    # R records where the sources actually run during the reconfiguration
    # (drives oversubscription detection); for BASELINE they nonetheless
    # do not persist into the target world (handled via plan.method).
    r_vec = [cores] * initial_nodes + [0] * (n_vec - initial_nodes)
    if method is Method.MERGE:
        s_vec = [a - r for a, r in zip(a_vec, r_vec)]
    else:
        s_vec = [cores] * n_nodes + [0] * (n_vec - n_nodes)

    return SpawnPlan(
        method=method,
        strategy=Strategy.PARALLEL_HYPERCUBE,
        nodes=n_nodes,
        cores=tuple(a_vec),
        running=tuple(r_vec),
        to_spawn=tuple(s_vec),
        groups=tuple(groups),
        steps=step,
        trace=tuple(trace),
        ns=ns,
        nt=nt,
    )
