"""Vectorized timeline engine: array-charged events + analytic chargers.

The object path (:mod:`repro.core.engine`) charges one
:class:`~repro.core.engine.TimelineEvent` at a time and answers cost
queries by iterating Python objects.  That is the right shape for a
single reconfiguration, but mega-scale sweeps (100k-event churn traces,
1000-replica Monte-Carlo policy sweeps over 10k-node pods) need the same
numbers thousands of times per second.  This module provides the array
layer those sweeps run on:

* :class:`EventArrays` — a trace's events as one structured numpy array
  (stage code, start/end, overlap fraction, stage-3 bytes per distance
  class).  ``total`` / ``span`` / ``downtime`` / per-class byte totals
  are computed with array ops that reproduce the object path's
  accumulation order **bit-for-bit** (sequential ``accumulate`` /
  ``cumsum`` reductions, never pairwise re-association), and
  :meth:`EventArrays.to_timeline` reconstructs the plain
  :class:`~repro.core.engine.Timeline` object view unchanged.
* :class:`Charge` / :func:`charge_stats` — duration-typed events before
  placement on the clock, and the exact scalar reduction the
  :class:`~repro.core.engine._TimelineBuilder` + Timeline pair would
  perform on them (same ``t + d`` placement, same ``end - start``
  re-reads), for cache-miss charging where numpy call overhead would
  dominate 40-event reductions.
* Analytic chargers for the hot transition shapes — a MERGE hypercube
  expansion (:func:`hypercube_expand_charges`) and a TS shrink
  (:func:`ts_shrink_charges`) — that emit the identical event sequence
  the planner + builder would, in closed form: no GroupSpec lists, no
  sync graph, no per-pair connect walk.  A 1 -> 10000 node expansion
  charges in microseconds instead of building a 9999-group plan.

The contract every consumer relies on: for any plan the object path can
charge, the vectorized path produces the same floats and ints, bit for
bit.  ``tests/test_vectorized.py`` pins that over the full scenario
registry and on randomized plans.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

from .engine import Stage, Timeline, TimelineEvent
from .topology import split_bytes_by_class

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.malleability.cost_model import CostModel

# Stage <-> int8 code, in enum declaration order (stable across runs;
# CHECKPOINT/RESTORE were appended last, so earlier codes are unchanged).
STAGE_ORDER: tuple[Stage, ...] = tuple(Stage)
STAGE_CODE: dict[Stage, int] = {s: i for i, s in enumerate(STAGE_ORDER)}
_QUEUE_CODE = STAGE_CODE[Stage.QUEUE]
_RESTORE_CODE = STAGE_CODE[Stage.RESTORE]

# One row per charged event.  This is the on-disk/in-memory shape of a
# timeline; labels ride separately (object-view garnish, never math).
EVENT_DTYPE = np.dtype(
    [
        ("stage", np.int8),
        ("start", np.float64),
        ("end", np.float64),
        ("overlap_fraction", np.float64),
        ("bytes_moved", np.int64),
        ("bytes_stayed", np.int64),
        ("bytes_cross_rack", np.int64),
        ("bytes_cross_pod", np.int64),
        ("bytes_checkpointed", np.int64),
    ]
)


def _seq_sum(values: np.ndarray) -> float:
    """Strict left-to-right float sum (what ``sum()`` over events does).

    ``np.add.accumulate`` (like ``cumsum``) produces every prefix, so it
    is forced into the same sequential association as the object path's
    Python ``sum`` — unlike ``np.sum`` / ``np.add.reduceat``, whose
    pairwise re-association changes low-order bits at modest lengths.
    """
    if values.size == 0:
        return 0.0
    return float(np.add.accumulate(values)[-1])


@dataclass(frozen=True)
class Charge:
    """One stage duration before placement on the clock."""

    stage: Stage
    duration: float
    overlap_fraction: float = 0.0
    bytes_moved: int = 0
    bytes_stayed: int = 0
    bytes_cross_rack: int = 0
    bytes_cross_pod: int = 0
    label: str = ""
    bytes_checkpointed: int = 0


@dataclass(frozen=True)
class ChargeStats:
    """Scalar cost summary of one charged transition."""

    total: float
    downtime: float
    queued: float
    bytes_moved: int
    bytes_stayed: int
    bytes_cross_rack: int
    bytes_cross_pod: int
    bytes_checkpointed: int = 0
    bytes_restored: int = 0
    restored_s: float = 0.0


def charge_stats(
    charges: Iterable[Charge], *, contention: float = 1.0,
    asynchronous: bool = False,
) -> ChargeStats:
    """Reduce charges exactly as builder + Timeline would.

    Replays the builder's clock placement (skip non-positive durations,
    ``end = t + d``) and the Timeline's queries, which re-read each
    duration as ``end - start`` — kept verbatim so a float where
    ``(t + d) - t != d`` still reproduces the object path bit-for-bit.
    """
    t = 0.0
    queued = 0.0
    hidden_sum = 0.0
    moved = stayed = xrack = xpod = 0
    checkpointed = restored = 0
    restored_s = 0.0
    factor = max(0.0, 2.0 - max(contention, 1.0))
    for c in charges:
        if c.duration <= 0.0:
            continue
        end = t + c.duration
        d_eff = end - t
        t = end
        if c.stage is Stage.QUEUE:
            queued += d_eff
        else:
            f = min(max(c.overlap_fraction, 0.0), 1.0)
            hidden_sum += d_eff * min(f * factor, f)
        if c.stage is Stage.RESTORE:
            # Store traffic, not stage-3 movement (Timeline's exclusion).
            restored += c.bytes_stayed + c.bytes_moved
            restored_s += d_eff
        else:
            moved += c.bytes_moved
            stayed += c.bytes_stayed
            xrack += c.bytes_cross_rack
            xpod += c.bytes_cross_pod
        checkpointed += c.bytes_checkpointed
    downtime = t - queued
    if asynchronous:
        downtime = downtime - hidden_sum
    return ChargeStats(total=t, downtime=downtime, queued=queued,
                       bytes_moved=moved, bytes_stayed=stayed,
                       bytes_cross_rack=xrack, bytes_cross_pod=xpod,
                       bytes_checkpointed=checkpointed,
                       bytes_restored=restored, restored_s=restored_s)


@dataclass(frozen=True)
class EventArrays:
    """A charged timeline as one structured numpy array.

    ``data`` has dtype :data:`EVENT_DTYPE`; ``labels`` (optional, may be
    shorter than ``data``) carries the object view's event labels so
    :meth:`to_timeline` round-trips losslessly.  All cost queries are
    array reductions that match :class:`~repro.core.engine.Timeline`
    bit-for-bit.
    """

    data: np.ndarray
    contention: float = 1.0
    labels: tuple[str, ...] = ()

    def __len__(self) -> int:
        return int(self.data.shape[0])

    # ------------------------------------------------------------ builders --
    @classmethod
    def from_timeline(cls, tl: Timeline) -> "EventArrays":
        """Array view of an existing object timeline."""
        data = np.empty(len(tl.events), dtype=EVENT_DTYPE)
        for i, e in enumerate(tl.events):
            data[i] = (STAGE_CODE[e.stage], e.start, e.end,
                       e.overlap_fraction, e.bytes_moved, e.bytes_stayed,
                       e.bytes_cross_rack, e.bytes_cross_pod,
                       e.bytes_checkpointed)
        return cls(data=data, contention=tl.contention,
                   labels=tuple(e.label for e in tl.events))

    @classmethod
    def from_charges(
        cls, charges: Sequence[Charge], contention: float = 1.0
    ) -> "EventArrays":
        """Place charges back-to-back on the clock (builder semantics).

        Non-positive durations are dropped, exactly as
        ``_TimelineBuilder.add`` drops them; ``cumsum`` accumulates the
        clock sequentially, matching the builder's ``t += duration``.
        """
        kept = [c for c in charges if c.duration > 0.0]
        data = np.empty(len(kept), dtype=EVENT_DTYPE)
        durs = np.array([c.duration for c in kept], dtype=np.float64)
        ends = np.cumsum(durs)
        data["stage"] = np.array([STAGE_CODE[c.stage] for c in kept],
                                 dtype=np.int8)
        data["end"] = ends
        data["start"] = np.concatenate((np.zeros(1), ends[:-1])) \
            if kept else np.zeros(0)
        data["overlap_fraction"] = [c.overlap_fraction for c in kept]
        data["bytes_moved"] = [c.bytes_moved for c in kept]
        data["bytes_stayed"] = [c.bytes_stayed for c in kept]
        data["bytes_cross_rack"] = [c.bytes_cross_rack for c in kept]
        data["bytes_cross_pod"] = [c.bytes_cross_pod for c in kept]
        data["bytes_checkpointed"] = [c.bytes_checkpointed for c in kept]
        return cls(data=data, contention=contention,
                   labels=tuple(c.label for c in kept))

    # ------------------------------------------------------------- queries --
    @property
    def durations(self) -> np.ndarray:
        """Per-event durations, re-read as ``end - start`` (object rule)."""
        return self.data["end"] - self.data["start"]

    @property
    def total(self) -> float:
        """Wall time of the whole reconfiguration."""
        if len(self) == 0:
            return 0.0
        return float(self.data["end"].max())

    def span(self, stage: Stage) -> float:
        """Summed duration of every event of ``stage``."""
        mask = self.data["stage"] == STAGE_CODE[stage]
        return _seq_sum(self.durations[mask])

    def span_by_stage(self) -> dict[Stage, float]:
        """Every stage's span, one masked sequential reduction each."""
        durs = self.durations
        codes = self.data["stage"]
        return {
            s: _seq_sum(durs[codes == STAGE_CODE[s]]) for s in STAGE_ORDER
        }

    @property
    def queued_s(self) -> float:
        return self.span(Stage.QUEUE)

    @property
    def _stage3_mask(self) -> np.ndarray:
        """Events whose bytes are stage-3 movement (RESTORE excluded)."""
        return self.data["stage"] != _RESTORE_CODE

    @property
    def bytes_moved(self) -> int:
        return int(self.data["bytes_moved"][self._stage3_mask].sum())

    @property
    def bytes_stayed(self) -> int:
        return int(self.data["bytes_stayed"][self._stage3_mask].sum())

    @property
    def bytes_cross_rack(self) -> int:
        return int(self.data["bytes_cross_rack"][self._stage3_mask].sum())

    @property
    def bytes_cross_pod(self) -> int:
        return int(self.data["bytes_cross_pod"][self._stage3_mask].sum())

    @property
    def bytes_checkpointed(self) -> int:
        return int(self.data["bytes_checkpointed"].sum())

    @property
    def bytes_restored(self) -> int:
        """Bytes read back from the store (RESTORE events only)."""
        mask = self.data["stage"] == _RESTORE_CODE
        return int(self.data["bytes_stayed"][mask].sum()
                   + self.data["bytes_moved"][mask].sum())

    @property
    def restored_s(self) -> float:
        return self.span(Stage.RESTORE)

    @property
    def bytes_by_class(self) -> dict[str, int]:
        """Stage-3 bytes per distance class (sums to stayed + moved)."""
        return split_bytes_by_class(self.bytes_stayed, self.bytes_moved,
                                    self.bytes_cross_rack,
                                    self.bytes_cross_pod)

    def downtime(self, asynchronous: bool = False) -> float:
        """App-visible stall; mirrors ``Timeline.downtime`` exactly."""
        if not asynchronous:
            return self.total - self.queued_s
        f = np.clip(self.data["overlap_fraction"], 0.0, 1.0)
        factor = max(0.0, 2.0 - max(self.contention, 1.0))
        hidden = self.durations * np.minimum(f * factor, f)
        mask = self.data["stage"] != _QUEUE_CODE
        return self.total - self.queued_s - _seq_sum(hidden[mask])

    # ---------------------------------------------------------- object view --
    def to_timeline(self) -> Timeline:
        """Reconstruct the plain object timeline (thin view contract)."""
        labels = self.labels + ("",) * (len(self) - len(self.labels))
        events = tuple(
            TimelineEvent(
                stage=STAGE_ORDER[int(row["stage"])],
                start=float(row["start"]),
                end=float(row["end"]),
                label=labels[i],
                overlap_fraction=float(row["overlap_fraction"]),
                bytes_moved=int(row["bytes_moved"]),
                bytes_stayed=int(row["bytes_stayed"]),
                bytes_cross_rack=int(row["bytes_cross_rack"]),
                bytes_cross_pod=int(row["bytes_cross_pod"]),
                bytes_checkpointed=int(row["bytes_checkpointed"]),
            )
            for i, row in enumerate(self.data)
        )
        return Timeline(events=events, contention=self.contention)


# ==================================================== analytic chargers ==
@lru_cache(maxsize=None)
def hypercube_connect_max_merges(n_groups: int) -> tuple[int, ...]:
    """Largest merged-group size (in initial-group units) per §4.4 round.

    Positional replay of :func:`repro.core.connect
    .binary_connection_schedule` over equal-sized groups: each round
    pairs group ``i`` with ``groups - 1 - i``, survivors re-pack to
    ids ``0..new_groups-1``, so a flat array indexed by gid suffices.
    Because :meth:`CostModel.connect_merge` is affine and increasing in
    the merged rank count, the round's charged cost is the cost of its
    largest merge — this cache turns the object path's per-pair walk
    into one lookup.
    """
    sizes = np.ones(n_groups, dtype=np.int64)
    out: list[int] = []
    groups = n_groups
    while groups > 1:
        middle = groups // 2
        new_groups = groups - middle
        merged = sizes[:middle] + sizes[new_groups:groups][::-1]
        out.append(int(merged.max()))
        sizes = np.concatenate((merged, sizes[middle:new_groups]))
        groups = new_groups
    return tuple(out)


@lru_cache(maxsize=None)
def hypercube_round_budgets(ns: int, n_groups: int, cores: int) -> tuple[int, ...]:
    """Groups spawned per round of a MERGE hypercube expansion.

    Mirrors :func:`repro.core.hypercube.plan_hypercube`'s spawner loop:
    every live rank spawns one ``cores``-sized group per round, so the
    spawner count starts at ``ns`` and grows by ``budget * cores``.
    """
    budgets: list[int] = []
    spawners = ns
    gid = 0
    while gid < n_groups:
        budget = min(spawners, n_groups - gid)
        budgets.append(budget)
        gid += budget
        spawners += budget * cores
    return tuple(budgets)


def hypercube_expand_charges(
    cm: "CostModel", ns: int, nt: int, cores: int
) -> list[Charge]:
    """Closed-form event sequence of a MERGE hypercube expansion.

    Emits exactly the events ``expansion_timeline(plan_hypercube(ns, nt,
    cores, MERGE), cm)`` would charge — same expressions, same order —
    without building the plan: spawn rounds (uniform ``cores``-sized
    groups, so each concurrent round costs the single-call charge plus
    the launcher-contention term), the §4.3 tree sync, the §4.4 connect
    rounds priced at their largest merge, the Eq. 9 reorder split, and
    the final intercomm merge.  Only valid for homogeneous widths and an
    unpriced (topology-free) spawn; callers gate on that.
    """
    if ns <= 0 or ns % cores or nt % cores:
        raise ValueError(
            f"hypercube requires NS ({ns}) and NT ({nt}) divisible by C ({cores})"
        )
    n_groups = nt // cores - ns // cores
    if n_groups <= 0:
        return []
    charges: list[Charge] = []
    f = cm.spawn_overlap
    base = cm.spawn_call(cores, 1)
    budgets = hypercube_round_budgets(ns, n_groups, cores)
    for s, budget in enumerate(budgets, start=1):
        charges.append(Charge(
            Stage.SPAWN, base + cm.delta_contend * (budget - 1), f,
            label=f"round {s} ({budget} groups)",
        ))
    depth = len(budgets)
    per_level = cm.t_token + cm.barrier(cores) + cm.comm_split(cores)
    sync = cm.t_port + per_level + depth * 2 * (cm.t_token + cm.barrier(cores))
    charges.append(Charge(Stage.SYNC, sync, cm.sync_overlap,
                          label=f"tree sync depth {depth}"))
    for i, m in enumerate(hypercube_connect_max_merges(n_groups)):
        charges.append(Charge(Stage.CONNECT, cm.connect_merge(m * cores),
                              cm.connect_overlap,
                              label=f"connect round {i + 1}"))
    charges.append(Charge(Stage.REORDER, cm.comm_split(n_groups * cores),
                          label="Eq. 9 reorder split"))
    charges.append(Charge(Stage.FINAL, cm.connect_merge(nt),
                          label="final intercomm merge"))
    return charges


def ts_shrink_charges(
    cm: "CostModel", doomed_world_sizes: Sequence[int]
) -> list[Charge]:
    """Closed-form TS shrink: release tokens, doomed worlds exit."""
    doomed = list(doomed_world_sizes) or [1]
    dur = cm.ts_terminate(doomed) + cm.t_token
    return [Charge(Stage.TERMINATE, dur,
                   label=f"TS terminate {len(doomed)} worlds")]


def redistribution_charge(
    cm: "CostModel", bytes_total: int, bytes_stayed: int,
    bytes_cross_rack: int = 0, bytes_cross_pod: int = 0,
) -> list[Charge]:
    """Stage-3 charge with the engine's exact clamping (may be empty)."""
    if bytes_total <= 0 and bytes_stayed <= 0:
        return []
    xrack = min(max(0, bytes_cross_rack), max(0, bytes_total))
    xpod = min(max(0, bytes_cross_pod), xrack)
    return [Charge(
        Stage.REDISTRIBUTION,
        cm.redistribution(bytes_total, bytes_stayed, xrack, xpod),
        overlap_fraction=cm.redist_overlap,
        bytes_moved=bytes_total, bytes_stayed=max(0, bytes_stayed),
        bytes_cross_rack=xrack, bytes_cross_pod=xpod,
        label=f"redistribute {bytes_total} B",
    )]


def queue_charge(queue_delay_s: float) -> list[Charge]:
    """Leading RMS-arbitration wait (empty when zero)."""
    if queue_delay_s <= 0.0:
        return []
    return [Charge(Stage.QUEUE, queue_delay_s,
                   label="queued behind in-flight reconfig")]


def checkpoint_charge(cm: "CostModel", snapshot_bytes: int) -> list[Charge]:
    """Store-write charge with the engine's exact gating (may be empty)."""
    if snapshot_bytes <= 0:
        return []
    return [Charge(Stage.CHECKPOINT, cm.checkpoint(snapshot_bytes),
                   overlap_fraction=cm.ckpt_overlap,
                   bytes_checkpointed=snapshot_bytes,
                   label=f"checkpoint {snapshot_bytes} B")]


def restore_charge(cm: "CostModel", restore_bytes: int) -> list[Charge]:
    """Store-read charge; bytes count as restored, never stage-3 moved."""
    if restore_bytes <= 0:
        return []
    return [Charge(Stage.RESTORE, cm.restore(restore_bytes),
                   bytes_moved=restore_bytes,
                   label=f"restore {restore_bytes} B from checkpoint")]


def segment_times(steps: Sequence[int],
                  step_times: Sequence[float]) -> np.ndarray:
    """Per-transition modeled compute segments for a sorted plan.

    ``steps`` are the plan's (non-decreasing) event steps and
    ``step_times[i]`` the modeled seconds per application step of the
    allocation transition ``i`` leaves behind; the segment charged to
    transition ``i`` is the steps elapsed since the previous transition
    (since step 0 for the first) times that rate.  Same-step transitions
    get a zero delta, matching the object path's per-record accrual.
    IEEE float64 product, so the result is bit-identical to the
    equivalent Python-float arithmetic.
    """
    deltas = np.diff(np.asarray(steps, dtype=np.int64), prepend=0)
    return deltas * np.asarray(step_times, dtype=np.float64)


def restart_charges(
    cm: "CostModel", ns: int, nt: int, nodes: int,
    snapshot_bytes: int, restore_bytes: int,
) -> list[Charge]:
    """Closed-form full-stop checkpoint/restart event sequence.

    Emits exactly what :func:`repro.core.engine.restart_timeline`
    charges: checkpoint, one SS respawn (teardown is inside
    ``ss_respawn``), restore.
    """
    return [
        *checkpoint_charge(cm, snapshot_bytes),
        Charge(Stage.RESPAWN, cm.ss_respawn(nt, max(1, nodes), ns),
               label=f"full-stop respawn {ns} -> {nt} ranks"),
        *restore_charge(cm, restore_bytes),
    ]
