"""Rank reordering after the binary connection (paper §4.5, Eq. 9).

Binary connections are racy in the order intercommunicators arrive, so
the merged communicator's ranks are not node-ordered.  A final
``MPI_Comm_split`` with the key

    new_rank = world_rank + sum_j R_j + sum_{j < group_id} S_j      (Eq. 9)

restores a deterministic node-contiguous order:  sources keep their
original ranks 0..NS-1, and spawned group ``gid`` occupies the block
right after all sources and all lower-gid groups.  In the elastic JAX
runtime this same key fixes the device order of the rebuilt mesh.
"""
from __future__ import annotations

from .types import Method, SpawnPlan


def reorder_key(world_rank: int, sum_running: int, group_sizes, group_id: int) -> int:
    """Eq. 9 for one spawned process.

    ``world_rank`` is the process's rank inside its own group world,
    ``sum_running`` is sum(R) (ranks existing before the resize), and
    ``group_sizes[j]`` is S_j, the size of spawned group j.
    """
    return world_rank + sum_running + sum(group_sizes[j] for j in range(group_id))


def global_order(plan: SpawnPlan) -> list[tuple[int, int]]:
    """Final (group_id, local_rank) layout for the whole target world.

    Index in the returned list == final global rank.  For MERGE the
    sources (group_id == -1) keep ranks 0..NS-1; for BASELINE the sources
    vanish and the R-sum contribution is zero by construction (R == 0 in
    the plan's vectors).
    """
    sizes = plan.group_sizes
    sum_running = plan.ns if plan.method is Method.MERGE else 0
    total = sum_running + sum(sizes)
    layout: list[tuple[int, int] | None] = [None] * total
    if plan.method is Method.MERGE:
        for r in range(plan.ns):
            layout[r] = (-1, r)
    for g in plan.groups:
        for local in range(g.size):
            key = reorder_key(local, sum_running, sizes, g.gid)
            if layout[key] is not None:
                raise AssertionError(f"Eq. 9 key collision at rank {key}")
            layout[key] = (g.gid, local)
    if any(entry is None for entry in layout):
        raise AssertionError("Eq. 9 keys do not cover 0..NT-1")
    return layout  # type: ignore[return-value]


def node_of_rank(plan: SpawnPlan) -> list[int]:
    """Node hosting each final global rank (node-contiguity check)."""
    node_by_gid = {g.gid: g.node for g in plan.groups}
    out: list[int] = []
    src_nodes: list[int] = []
    if plan.method is Method.MERGE:
        # Source ranks sit on the initially running nodes, R[i] ranks each,
        # in node order.
        for i, r in enumerate(plan.running):
            src_nodes.extend([i] * r)
    for gid, local in global_order(plan):
        out.append(src_nodes[local] if gid == -1 else node_by_gid[gid])
    return out
