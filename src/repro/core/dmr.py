"""DMR-style asynchronous two-phase spawning strategy (``"dmr-async"``).

Iserte et al.'s DMR API decouples a resize into two phases: the RMS
*grants* the new allocation and the job *accepts* it asynchronously —
new processes are spawned, synchronized, and connected while the old
world keeps computing — and only the final commit (rank reorder, the
sources↔children intercomm, data redistribution) interrupts the
application.  This module registers that behaviour as an ordinary
strategy:

* **spawn structure** — the best parallel plan for the allocation:
  hypercube rounds (§4.1) on homogeneous pools, iterative diffusive
  rounds (§4.2) on heterogeneous ones, re-tagged with this strategy's
  registry key.  Event durations are identical to that underlying plan;
* **two-phase charging** — the spec's ``two_phase`` flag makes the
  engine charge the plan with full spawn/sync/connect overlap
  (``CostModel.with_overlap(spawn=1.0, sync=1.0, connect=1.0)``) and
  force ``asynchronous=True`` on the plan, so the grant-acceptance legs
  hide under compute — degraded by the ordinary contention factor —
  while REORDER/FINAL/REDISTRIBUTION stay on the critical path.

Consequently expansion *downtime* never exceeds the synchronous
baseline on the same allocation (strictly less whenever contention
leaves room to hide work), while *total* reconfiguration wall time is
unchanged — exactly the DMR trade: acceptance off the critical path,
commit still paid.  Shrinks are unaffected (TS shrinks carry no spawn
legs to hide).
"""
from __future__ import annotations

from dataclasses import replace
from typing import Iterable, Union

from .diffusive import plan_diffusive
from .engine import (
    StrategySpec,
    as_core_vector,
    register_strategy,
    running_vector,
)
from .hypercube import plan_hypercube
from .types import Method, SpawnPlan

DMR_KEY = "dmr-async"


def plan_dmr(
    ns: int,
    nt: int,
    cores: Union[int, Iterable[int]],
    method: Method = Method.MERGE,
) -> SpawnPlan:
    """Two-phase spawn plan (normalized ``(ns, nt, cores, method)``).

    Homogeneous allocations take the hypercube rounds, heterogeneous
    ones the iterative diffusive rounds; either way the plan is
    re-tagged ``"dmr-async"`` so the engine's timeline charger applies
    the two-phase overlap.
    """
    a_vec = as_core_vector(
        cores if isinstance(cores, int) else list(cores), nt
    )
    widths = set(a_vec)
    if len(widths) == 1:
        plan = plan_hypercube(ns, nt, widths.pop(), method)
    else:
        plan = plan_diffusive(a_vec, running_vector(a_vec, ns), method)
    return replace(plan, strategy=DMR_KEY)


register_strategy(StrategySpec(
    key=DMR_KEY,
    planner=plan_dmr,
    parallel=True,
    two_phase=True,
    description=("DMR two-phase async spawn: grant accepted off the "
                 "critical path (spawn/sync/connect fully overlapped), "
                 "only the commit interrupts compute"),
))
