"""Synchronization between process groups (paper §4.3).

After the parallel spawn, every group only knows its parent and its own
children (edges of the spawn tree).  Before any ``MPI_Comm_connect`` may
run, every port must already be open; the paper guarantees this with a
three-stage protocol executed over the spawn tree:

  1. *Subcommunicator creation* — per group, the root plus every member
     that spawned children split off a coordination subcommunicator.
  2. *Upside synchronization* — members wait for a token from each child
     group (Irecv+Waitall), the subcommunicator barriers, then the group
     root notifies its parent.  A group's token therefore implies its
     whole subtree is ready.
  3. *Downside synchronization* — the root receives the release token
     from its parent, the subcommunicator barriers, and members forward
     the token to their children.

We model this as an explicit happens-before event graph.  The graph is
used twice: tests verify the structural guarantee (every port_open
precedes every connect), and the malleability simulator assigns latencies
to events and takes the critical path to estimate reconfiguration time.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .types import SOURCE_GID, SpawnPlan

# Event kinds
SPAWNED = "spawned"          # group exists (end of its MPI_Comm_spawn)
PORT_OPEN = "port_open"      # root opened its port + published the name
UP_READY = "up_ready"        # subtree ready; root has sent token to parent
DOWN = "down"                # group released by its parent
CONNECT = "connect"          # one accept/connect pair of the binary phase
MERGED = "merged"            # per-round merge completed
FINAL_ACCEPT = "final_accept"  # sources <-> merged-children intercomm


@dataclass(frozen=True)
class Event:
    kind: str
    gid: int            # group the event belongs to (SOURCE_GID for sources)
    round: int = -1     # binary-connection round, if applicable
    peer: int = -1      # peer group, if applicable

    def __str__(self) -> str:  # compact label for debugging
        extra = f"@r{self.round}" if self.round >= 0 else ""
        peer = f"->{self.peer}" if self.peer >= 0 else ""
        return f"{self.kind}({self.gid}{peer}){extra}"


@dataclass
class EventGraph:
    """DAG of events with happens-before edges (u precedes v)."""

    events: list[Event] = field(default_factory=list)
    edges: dict[Event, list[Event]] = field(default_factory=dict)
    _index: set[Event] = field(default_factory=set)

    def add(self, ev: Event) -> Event:
        if ev not in self._index:
            self._index.add(ev)
            self.events.append(ev)
            self.edges[ev] = []
        return ev

    def before(self, u: Event, v: Event) -> None:
        self.add(u)
        self.add(v)
        self.edges[u].append(v)

    def predecessors(self) -> dict[Event, list[Event]]:
        preds: dict[Event, list[Event]] = {e: [] for e in self.events}
        for u, vs in self.edges.items():
            for v in vs:
                preds[v].append(u)
        return preds

    def topological(self) -> list[Event]:
        preds = self.predecessors()
        indeg = {e: len(ps) for e, ps in preds.items()}
        ready = [e for e in self.events if indeg[e] == 0]
        order: list[Event] = []
        while ready:
            e = ready.pop()
            order.append(e)
            for v in self.edges[e]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if len(order) != len(self.events):
            raise ValueError("event graph has a cycle")
        return order

    def reachable_from(self, src: Event) -> set[Event]:
        seen: set[Event] = set()
        stack = [src]
        while stack:
            e = stack.pop()
            for v in self.edges[e]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return seen


def spawn_children(plan: SpawnPlan) -> dict[int, list[int]]:
    """Map gid (or SOURCE_GID) -> list of child gids in the spawn tree."""
    children: dict[int, list[int]] = {SOURCE_GID: []}
    for g in plan.groups:
        children.setdefault(g.gid, [])
        children.setdefault(g.parent_gid, []).append(g.gid)
    return children


def port_openers(plan: SpawnPlan) -> set[int]:
    """Groups whose root opens a port before spawning (paper §4.6, item 1).

    Children with ``group_id < G/2`` open ports for the binary connection
    (acceptor ids only shrink across rounds, and merged groups adopt the
    acceptor's id, so this single precomputed set covers every round);
    the source root always opens the port for the final intercomm.
    """
    n_groups = len(plan.groups)
    return {SOURCE_GID} | {g.gid for g in plan.groups if g.gid < n_groups // 2}


def build_sync_graph(plan: SpawnPlan) -> EventGraph:
    """Event graph for spawn + 3-stage synchronization (no connection yet)."""
    g = EventGraph()
    children = spawn_children(plan)
    by_gid = {gs.gid: gs for gs in plan.groups}
    openers = port_openers(plan)

    src_spawned = g.add(Event(SPAWNED, SOURCE_GID))
    g.before(src_spawned, g.add(Event(PORT_OPEN, SOURCE_GID)))

    # Spawn dependencies: a group exists only after its parent existed (and,
    # for non-source parents, after the parent opened its own port, matching
    # the listing order: open_port -> spawn).
    for gs in plan.groups:
        ev = g.add(Event(SPAWNED, gs.gid))
        parent_spawned = Event(SPAWNED, gs.parent_gid)
        g.before(parent_spawned, ev)
        if gs.gid in openers:
            g.before(ev, g.add(Event(PORT_OPEN, gs.gid)))

    # Upside: group ready after itself spawned (+port open) and all
    # children ready.
    def up_event(gid: int) -> Event:
        return Event(UP_READY, gid)

    for gid in [SOURCE_GID] + [gs.gid for gs in plan.groups]:
        up = g.add(up_event(gid))
        g.before(Event(SPAWNED, gid), up)
        if gid in openers:
            g.before(Event(PORT_OPEN, gid), up)
        for child in children.get(gid, []):
            g.before(up_event(child), up)

    # Downside: source releases after its own up_ready; each group's down
    # waits for its parent's down.
    src_down = g.add(Event(DOWN, SOURCE_GID))
    g.before(Event(UP_READY, SOURCE_GID), src_down)
    # Process groups in spawn order so parents are handled first.
    for gs in sorted(plan.groups, key=lambda x: x.step):
        down = g.add(Event(DOWN, gs.gid))
        parent_down = Event(DOWN, gs.parent_gid)
        g.before(parent_down, down)

    del by_gid
    return g


def assert_ports_before_release(graph: EventGraph, plan: SpawnPlan) -> None:
    """Structural guarantee of §4.3: every DOWN event is preceded by every
    PORT_OPEN event (so no connect — which only happens after DOWN — can
    race a port)."""
    opens = [e for e in graph.events if e.kind == PORT_OPEN]
    downs = [e for e in graph.events if e.kind == DOWN]
    for po in opens:
        reach = graph.reachable_from(po)
        for d in downs:
            if d not in reach:
                raise AssertionError(f"{po} does not precede {d}: port race!")
