"""Shrink planning: TS / ZS / SS decision logic (paper §4.6-§4.7).

The whole point of the parallel spawning strategies is that every spawned
world is confined to one node, so shrinking can *terminate* worlds and
hand their nodes back to the RMS (Termination Shrinkage) instead of
respawning everything (SS) or leaving zombies that pin nodes (ZS).

State model (mirrors the paper's root-rank bookkeeping):

* the global root keeps ``{world -> nodelist}``;
* each world root keeps per-rank ``(node, zombie?)`` flags;
* the initial world may span several nodes and cannot be partially
  returned — §4.6 enumerates how that is handled (we implement the
  paper's adopted policy: postpone until a shrink actually needs it).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .types import (
    RankInfo,
    ShrinkAction,
    ShrinkActionKind,
    ShrinkKind,
    ShrinkPlan,
    World,
)


@dataclass
class ClusterState:
    """Worlds currently alive in the job + global-root bookkeeping."""

    worlds: dict[int, World] = field(default_factory=dict)
    global_root_wid: int = 0
    expansions_done: int = 0  # §4.6: history matters for the initial MCW
    _next_wid: int = 0

    # ---- construction helpers -------------------------------------------------
    def add_world(self, nodes, ranks_per_node, is_initial=False) -> World:
        wid = self._next_wid
        self._next_wid += 1
        ranks: list[RankInfo] = []
        rank = 0
        for node, count in zip(nodes, ranks_per_node):
            for _ in range(count):
                ranks.append(RankInfo(rank=rank, node=node))
                rank += 1
        w = World(wid=wid, nodes=tuple(nodes), ranks=ranks, is_initial=is_initial)
        self.worlds[wid] = w
        if len(self.worlds) == 1:
            self.global_root_wid = wid
        return w

    # ---- queries ---------------------------------------------------------------
    def nodes_in_use(self) -> set[int]:
        return {n for w in self.worlds.values() for n in w.nodes}

    def worlds_on_node(self, node: int) -> list[World]:
        return [w for w in self.worlds.values() if node in w.nodes]

    def total_active_ranks(self) -> int:
        return sum(len(w.active_ranks) for w in self.worlds.values())


def plan_shrink(state: ClusterState, release_nodes=None, release_cores=None) -> ShrinkPlan:
    """Decide shrink actions for an RMS request.

    Args:
      state: live cluster bookkeeping.
      release_nodes: node ids the RMS wants back entirely.
      release_cores: {node: n_cores} partial within-node releases (§4.6
        last bullet: excess ranks become zombies, ZS).

    Returns a :class:`ShrinkPlan`; the caller applies it via
    :func:`apply_shrink`.
    """
    release_nodes = set(release_nodes or ())
    release_cores = dict(release_cores or {})
    actions: list[ShrinkAction] = []
    returned: list[int] = []
    pinned: list[int] = []
    used_ts = used_zs = False

    # --- whole-node releases ---------------------------------------------------
    doomed_wids: set[int] = set()
    for wid, w in state.worlds.items():
        span = set(w.nodes)
        if not span:
            continue
        if span <= release_nodes:
            doomed_wids.add(wid)
    for wid in sorted(doomed_wids):
        w = state.worlds[wid]
        if w.all_zombie:
            # §4.7: a fully-zombie world is awakened so it can terminate.
            actions.append(
                ShrinkAction(ShrinkActionKind.AWAKEN_AND_TERMINATE, wid=wid, nodes=w.nodes)
            )
        else:
            actions.append(
                ShrinkAction(ShrinkActionKind.TERMINATE_WORLD, wid=wid, nodes=w.nodes)
            )
        returned.extend(w.nodes)
        used_ts = True

    # Root migration (§4.7): if the global root's world terminates, hand
    # the structure to the lowest-wid surviving world.
    if state.global_root_wid in doomed_wids:
        survivors = sorted(set(state.worlds) - doomed_wids)
        if survivors:
            actions.append(
                ShrinkAction(
                    ShrinkActionKind.MIGRATE_ROOT,
                    wid=state.global_root_wid,
                    new_root_wid=survivors[0],
                )
            )

    # --- nodes requested but not fully coverable by dying worlds ---------------
    for node in sorted(release_nodes):
        holders = [w for w in state.worlds.values() if node in w.nodes and w.wid not in doomed_wids]
        for w in holders:
            if len(w.nodes) > 1:
                # §4.7 last paragraph: a multi-node MCW asked to give up a
                # subset of its nodes cannot use TS -> fall back to ZS for
                # the ranks on that node; the node stays pinned.
                zr = tuple(r.rank for r in w.ranks if r.node == node and not r.zombie)
                if zr:
                    actions.append(
                        ShrinkAction(ShrinkActionKind.ZOMBIFY_RANKS, wid=w.wid, ranks=zr, nodes=(node,))
                    )
                    used_zs = True
                pinned.append(node)

    # --- partial within-node core releases (ZS; §4.6 last bullet) --------------
    for node, n_cores in sorted(release_cores.items()):
        remaining = n_cores
        for w in sorted(state.worlds_on_node(node), key=lambda w: -w.wid):
            if w.wid in doomed_wids or remaining <= 0:
                continue
            candidates = [r for r in w.ranks if r.node == node and not r.zombie]
            take = candidates[len(candidates) - min(remaining, len(candidates)):]
            if not take:
                continue
            remaining -= len(take)
            if len(take) == len([r for r in w.ranks if not r.zombie]) and len(w.nodes) == 1:
                # Whole (single-node) world zombified -> §4.7 upgrades to TS.
                actions.append(
                    ShrinkAction(
                        ShrinkActionKind.AWAKEN_AND_TERMINATE, wid=w.wid, nodes=w.nodes
                    )
                )
                returned.extend(w.nodes)
                used_ts = True
            else:
                actions.append(
                    ShrinkAction(
                        ShrinkActionKind.ZOMBIFY_RANKS,
                        wid=w.wid,
                        ranks=tuple(r.rank for r in take),
                        nodes=(node,),
                    )
                )
                used_zs = True
                if node not in pinned:
                    pinned.append(node)

    kind = ShrinkKind.TS if used_ts and not used_zs else (
        ShrinkKind.ZS if used_zs and not used_ts else
        (ShrinkKind.TS if used_ts else ShrinkKind.ZS)
    )
    return ShrinkPlan(
        kind=kind,
        actions=tuple(actions),
        nodes_returned=tuple(sorted(set(returned))),
        nodes_pinned=tuple(sorted(set(pinned) - set(returned))),
    )


def plan_initial_world_shrink(state: ClusterState, nodes_to_return: int) -> ShrinkAction:
    """§4.6: policy for the multi-node *initial* MCW (postpone approach).

    * no expansion yet                  -> PARALLEL_RESPAWN (recreate the job
      with the parallel strategy so worlds become node-confined, then TS);
    * request smaller than the initial allocation -> POSTPONE (return only
      expanded nodes, keep initial MCW intact);
    * request >= initial allocation     -> the whole initial MCW terminates
      (TERMINATE_WORLD), remainder comes from the expanded set.
    """
    initial = next((w for w in state.worlds.values() if w.is_initial), None)
    if initial is None or len(initial.nodes) <= 1:
        return ShrinkAction(ShrinkActionKind.POSTPONE)
    if state.expansions_done == 0:
        return ShrinkAction(ShrinkActionKind.PARALLEL_RESPAWN, wid=initial.wid)
    if nodes_to_return < len(initial.nodes):
        return ShrinkAction(ShrinkActionKind.POSTPONE, wid=initial.wid)
    return ShrinkAction(
        ShrinkActionKind.TERMINATE_WORLD, wid=initial.wid, nodes=initial.nodes
    )


def apply_shrink(state: ClusterState, plan: ShrinkPlan) -> ClusterState:
    """Mutate ``state`` according to ``plan`` (returns it for chaining)."""
    for act in plan.actions:
        if act.kind in (ShrinkActionKind.TERMINATE_WORLD, ShrinkActionKind.AWAKEN_AND_TERMINATE):
            state.worlds.pop(act.wid, None)
        elif act.kind is ShrinkActionKind.ZOMBIFY_RANKS:
            w = state.worlds[act.wid]
            chosen = set(act.ranks)
            for r in w.ranks:
                if r.rank in chosen:
                    r.zombie = True
        elif act.kind is ShrinkActionKind.MIGRATE_ROOT:
            if act.new_root_wid is not None:
                state.global_root_wid = act.new_root_wid
    return state
