"""Binary connection between spawned groups (paper §4.4, Listing 2).

Once all ports are known to be open, the G spawned groups merge pairwise
in ceil(log2 G) rounds.  Each round with ``groups`` active ids:

    middle     = groups // 2
    new_groups = groups - middle
    id <  middle      -> MPI_Comm_accept  (keeps its id)
    id >= new_groups  -> MPI_Comm_connect to id' = groups - id - 1,
                         then adopts id'
    middle == id < new_groups (odd count) -> idles this round

so after the round exactly ``new_groups`` ids remain; the process repeats
until one group holds every spawned rank.
"""
from __future__ import annotations

from dataclasses import dataclass

from .sync import CONNECT, DOWN, MERGED, PORT_OPEN, Event, EventGraph
from .types import SpawnPlan


@dataclass(frozen=True)
class ConnectRound:
    index: int
    # (acceptor_id, connector_id) pairs; ids are *current* ids, i.e. the
    # representative (lowest/acceptor) id of each already-merged set.
    pairs: tuple[tuple[int, int], ...]
    idle: tuple[int, ...]


def binary_connection_schedule(n_groups: int) -> list[ConnectRound]:
    """Pairing schedule of §4.4 for ``n_groups`` spawned groups."""
    rounds: list[ConnectRound] = []
    groups = n_groups
    idx = 0
    while groups > 1:
        middle = groups // 2
        new_groups = groups - middle
        pairs = tuple((i, groups - 1 - i) for i in range(middle))
        idle = tuple(range(middle, new_groups)) if groups % 2 else ()
        rounds.append(ConnectRound(index=idx, pairs=pairs, idle=idle))
        groups = new_groups
        idx += 1
    return rounds


def simulate_merges(n_groups: int) -> dict[int, list[int]]:
    """Run the schedule symbolically; return final {representative: members}.

    Verifies the §4.4 invariant that the procedure converges to a single
    group containing every original gid exactly once.
    """
    members: dict[int, list[int]] = {i: [i] for i in range(n_groups)}
    for rnd in binary_connection_schedule(n_groups):
        merged: dict[int, list[int]] = {}
        consumed: set[int] = set()
        for acc, conn in rnd.pairs:
            merged[acc] = members[acc] + members[conn]
            consumed.update((acc, conn))
        for i in rnd.idle:
            merged[i] = members[i]
            consumed.add(i)
        # ids not mentioned this round keep their sets (only happens when
        # n==1 upfront).
        for i, m in members.items():
            if i not in consumed:
                merged[i] = m
        members = merged
    return members


def required_ports(n_groups: int) -> set[int]:
    """Ids that act as acceptor in at least one round.

    Equals {0 .. n_groups//2 - 1}, the ``group_id < (groups-I)/2`` port-
    opening condition in Listing 4 — asserted by tests.
    """
    ports: set[int] = set()
    for rnd in binary_connection_schedule(n_groups):
        ports.update(acc for acc, _ in rnd.pairs)
    return ports


def extend_graph_with_connection(graph: EventGraph, plan: SpawnPlan) -> EventGraph:
    """Append binary-connection events to a §4.3 sync graph.

    Every pair's CONNECT waits on: both participants' DOWN release, the
    acceptor's PORT_OPEN, and both participants' previous-round MERGED
    event.  This encodes Listing 2's loop structure.
    """
    n_groups = len(plan.groups)
    schedule = binary_connection_schedule(n_groups)
    # representative id -> MERGED event of the round it last participated in
    last_merge: dict[int, Event] = {}

    def down_of(gid: int) -> Event:
        return Event(DOWN, gid)

    for rnd in schedule:
        for acc, conn in rnd.pairs:
            c = graph.add(Event(CONNECT, conn, round=rnd.index, peer=acc))
            m = graph.add(Event(MERGED, acc, round=rnd.index, peer=conn))
            graph.before(Event(PORT_OPEN, acc), c)
            for gid in (acc, conn):
                graph.before(down_of(gid), c)
                if gid in last_merge:
                    graph.before(last_merge[gid], c)
            graph.before(c, m)
            last_merge[acc] = m
            last_merge.pop(conn, None)
    return graph
