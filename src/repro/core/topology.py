"""Cluster topology: the node -> rack -> pod tree placement prices against.

The paper's two testbeds differ mainly in node layout (§5.1: MN5's
InfiniBand fat-tree vs NASP's flat 10 GbE), and its shrink advantage
comes from returning *whole* allocation units to the RMS.  This module
gives the stack a first-class layout object:

* :class:`Topology` — an explicit tree over node ids.  Racks may be
  uneven (different node counts), and racks may optionally be grouped
  into pods; node ids are assigned to racks in prefix order, exactly how
  :class:`~repro.elastic.node_group.DevicePool` numbers its nodes.
* **distance classes** — every (source node, destination node) pair
  resolves to one of :data:`DISTANCE_CLASSES`; the
  :class:`~repro.malleability.cost_model.CostModel` prices each class
  with its own bandwidth, and the
  :class:`~repro.core.engine.ReconfigEngine` charges every stage-3 byte
  on the class between its source and destination ranks.

A pool without an explicit topology behaves as ONE rack: every moved
byte is ``intra_rack``, which is exactly the PR-4 local/cross split
(``intra_rack`` falls back to the cross-link bandwidth), so untopologized
configurations reproduce the previous numbers bit for bit.
"""
from __future__ import annotations

from dataclasses import dataclass

# Stage-3 transfer classes, nearest first.  ``intra_node`` is data a
# surviving device already holds (the former ``bytes_stayed`` /
# local-link volume); ``intra_rack`` / ``cross_rack`` split the former
# cross-link ``bytes_moved`` by whether the transfer leaves its rack;
# ``cross_pod`` is the slice of ``cross_rack`` that additionally leaves
# its pod (only ever non-zero on a topology with ``pod_sizes`` set).
DISTANCE_CLASSES: tuple[str, ...] = (
    "intra_node", "intra_rack", "cross_rack", "cross_pod")


def split_bytes_by_class(bytes_stayed: int, bytes_moved: int,
                         bytes_cross_rack: int,
                         bytes_cross_pod: int = 0) -> dict[str, int]:
    """The canonical stayed/moved/cross-rack/cross-pod class split.

    Every ``bytes_by_class`` report (timeline events, timelines,
    redistribution specs, runtime and scenario records) delegates here,
    so the class accounting can only ever change in one place.  The
    values always sum to ``bytes_stayed + bytes_moved``.

    ``bytes_cross_pod`` is a *refinement* of ``bytes_cross_rack`` (a
    pod-crossing transfer necessarily crosses racks), so the reported
    ``cross_rack`` entry is the pod-local remainder.
    """
    return {
        "intra_node": bytes_stayed,
        "intra_rack": bytes_moved - bytes_cross_rack,
        "cross_rack": bytes_cross_rack - bytes_cross_pod,
        "cross_pod": bytes_cross_pod,
    }


@dataclass(frozen=True)
class Topology:
    """Node -> rack -> pod tree with prefix node numbering.

    Args:
        rack_sizes: nodes per rack (uneven widths allowed); rack ``r``
            owns the next ``rack_sizes[r]`` node ids in order, mirroring
            how ``DevicePool`` assigns devices to nodes.
        pod_sizes: optional racks per pod (prefix assignment over rack
            ids); must sum to ``len(rack_sizes)`` when given.  Pods are
            a placement preference (the ``topo`` strategy opens fresh
            racks pod-locally) *and* a pricing boundary: with pods set,
            rack-crossing transfers that also leave their pod resolve
            to the ``cross_pod`` class.  Without pods every rack is its
            own pod and ``cross_pod`` never appears — the 3-class
            behaviour, bit for bit.
    """

    rack_sizes: tuple[int, ...]
    pod_sizes: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if not self.rack_sizes or any(s <= 0 for s in self.rack_sizes):
            raise ValueError(
                f"rack_sizes must be non-empty positive ints, got "
                f"{self.rack_sizes}"
            )
        if self.pod_sizes:
            if any(s <= 0 for s in self.pod_sizes):
                raise ValueError(
                    f"pod_sizes must be positive ints, got {self.pod_sizes}"
                )
            if sum(self.pod_sizes) != len(self.rack_sizes):
                raise ValueError(
                    f"pod_sizes {self.pod_sizes} must cover the "
                    f"{len(self.rack_sizes)} racks exactly"
                )

    # ---- constructors -------------------------------------------------------
    @classmethod
    def uniform(cls, n_racks: int, nodes_per_rack: int,
                racks_per_pod: int = 0) -> "Topology":
        """Evenly-sized racks (and optionally pods); the MN5-like case."""
        if n_racks <= 0 or nodes_per_rack <= 0:
            raise ValueError("n_racks and nodes_per_rack must be positive")
        pods: tuple[int, ...] = ()
        if racks_per_pod:
            if n_racks % racks_per_pod:
                raise ValueError(
                    f"{n_racks} racks do not divide into pods of "
                    f"{racks_per_pod}"
                )
            pods = (racks_per_pod,) * (n_racks // racks_per_pod)
        return cls(rack_sizes=(nodes_per_rack,) * n_racks, pod_sizes=pods)

    @classmethod
    def single_rack(cls, n_nodes: int) -> "Topology":
        """Everything in one rack: the degenerate (pre-topology) layout."""
        return cls(rack_sizes=(n_nodes,))

    # ---- queries ------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return sum(self.rack_sizes)

    @property
    def n_racks(self) -> int:
        return len(self.rack_sizes)

    def rack_of(self, node: int) -> int:
        """Rack id owning ``node`` (raises on out-of-range ids)."""
        if node < 0:
            raise ValueError(f"negative node id {node}")
        offset = 0
        for rack, size in enumerate(self.rack_sizes):
            offset += size
            if node < offset:
                return rack
        raise ValueError(
            f"node {node} outside this {self.n_nodes}-node topology"
        )

    def nodes_in_rack(self, rack: int) -> tuple[int, ...]:
        """Node ids owned by ``rack``, ascending."""
        start = sum(self.rack_sizes[:rack])
        return tuple(range(start, start + self.rack_sizes[rack]))

    def pod_of_rack(self, rack: int) -> int:
        """Pod id owning ``rack`` (rack id itself when pods are unset)."""
        if not 0 <= rack < self.n_racks:
            raise ValueError(f"rack {rack} outside {self.n_racks} racks")
        if not self.pod_sizes:
            return rack
        offset = 0
        for pod, size in enumerate(self.pod_sizes):
            offset += size
            if rack < offset:
                return pod
        raise AssertionError("pod_sizes validated to cover all racks")

    def pod_of(self, node: int) -> int:
        return self.pod_of_rack(self.rack_of(node))

    def distance_class(self, src_node: int, dst_node: int) -> str:
        """Transfer class between two nodes (one of DISTANCE_CLASSES).

        ``cross_pod`` is only ever returned when ``pod_sizes`` is set:
        without pods, ``pod_of_rack`` degenerates to the rack id, which
        would misclassify every rack crossing as a pod crossing.
        """
        if src_node == dst_node:
            return "intra_node"
        if self.rack_of(src_node) == self.rack_of(dst_node):
            return "intra_rack"
        if self.pod_sizes and self.pod_of(src_node) != self.pod_of(dst_node):
            return "cross_pod"
        return "cross_rack"
