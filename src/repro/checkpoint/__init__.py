"""Mesh-independent checkpointing (save once, restore on any mesh).

Used three ways in the framework:
  * the SS (Spawn Shrinkage) baseline restarts from the latest checkpoint;
  * fault tolerance restores lost shards after a node failure;
  * ordinary periodic checkpointing during training (async capable).
"""
from .store import (
    CheckpointManager,
    latest_step,
    restore_tree,
    save_tree,
)

__all__ = ["CheckpointManager", "latest_step", "restore_tree", "save_tree"]
