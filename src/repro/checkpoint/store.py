"""Flat-file checkpoint store with a pytree manifest.

Layout:  <dir>/step_<n>/manifest.json + one ``.npy`` per leaf.
Leaves are written from fully-addressable host copies and restored with
an explicit target sharding, so a checkpoint written under one mesh
restores under any other — the property both SS-restart and failure
recovery need.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((key or "leaf", leaf))
    return out


def save_tree(tree: Any, directory: str, step: int) -> str:
    """Synchronous save; returns the checkpoint path.

    Crash-safe: leaves stream into a ``.tmp`` staging directory that is
    published over ``path`` only once every leaf and the manifest have
    landed.  A failed leaf write removes the staging directory instead
    of orphaning it (``latest_step`` ignores ``.tmp`` names, but the
    garbage would accumulate), and re-saving an existing step replaces
    the old snapshot whole — ``os.replace`` cannot clobber a non-empty
    directory on its own.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    try:
        manifest = {"step": step, "leaves": []}
        for i, (key, leaf) in enumerate(_leaf_paths(tree)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({"key": key, "file": fname, "dtype": str(arr.dtype),
                                       "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_") and not name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_tree(
    template: Any,
    directory: str,
    step: int,
    mesh: Optional[Mesh] = None,
    spec_tree: Any = None,
) -> Any:
    """Restore into ``template``'s structure, placing leaves on ``mesh``.

    ``template`` supplies the pytree structure (its leaf values are
    ignored); ``spec_tree`` gives the target PartitionSpecs (single spec
    or matching pytree).  Without a mesh, leaves land on the default
    device.
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    treedef = jax.tree_util.tree_structure(template)
    n = treedef.num_leaves
    if n != len(leaves_meta):
        raise ValueError(f"checkpoint has {len(leaves_meta)} leaves, template {n}")
    arrays = [np.load(os.path.join(path, m["file"])) for m in leaves_meta]
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, tree)
    if isinstance(spec_tree, P) or spec_tree is None:
        specs = jax.tree.map(lambda _: spec_tree or P(), tree)
    else:
        specs = spec_tree
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs
    )


class CheckpointManager:
    """Periodic, optionally-async checkpointing with retention."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree: Any, step: int) -> None:
        # Snapshot to host synchronously (cheap, avoids racing mutation),
        # write to disk on a worker thread (overlaps with compute).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def _write():
            save_tree(host_tree, self.directory, step)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore_latest(self, template: Any, mesh=None, spec_tree=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore_tree(template, self.directory, step, mesh, spec_tree), step

    def _gc(self) -> None:
        steps = sorted(
            int(name.split("_")[1])
            for name in os.listdir(self.directory)
            if name.startswith("step_") and not name.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
