"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    loss_chunk=512,   # 256k vocab: chunk the fp32 loss materialization
)

SMOKE = CONFIG.replace(
    name="command-r-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab=512,
    loss_chunk=0,
    remat=False,
)
