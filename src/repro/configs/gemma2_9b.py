"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating attention (4096 window) and
logit soft-capping [arXiv:2408.00118; hf]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    sliding_window=4096,
    alt_local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    loss_chunk=512,
)

SMOKE = CONFIG.replace(
    name="gemma2-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    sliding_window=8,
    loss_chunk=0,
    remat=False,
)
