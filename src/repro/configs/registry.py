"""Registry of the ten assigned architectures and their shape cells."""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.common import ModelConfig

ARCHS: tuple[str, ...] = (
    "zamba2_1p2b",
    "stablelm_3b",
    "yi_34b",
    "command_r_plus_104b",
    "gemma2_9b",
    "phi35_moe_42b",
    "llama4_scout_17b",
    "musicgen_medium",
    "qwen2_vl_7b",
    "xlstm_125m",
)

# Canonical --arch aliases (hyphenated ids from the assignment).
ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b",
    "stablelm-3b": "stablelm_3b",
    "yi-34b": "yi_34b",
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma2-9b": "gemma2_9b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "musicgen-medium": "musicgen_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "xlstm-125m": "xlstm_125m",
}


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str       # train | prefill | decode | long_decode


SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "long_decode"),
)

# Archs with a sub-quadratic path for long_500k (SSM / hybrid / local+global
# alternating).  Pure full-attention archs skip that cell (DESIGN.md).
LONG_OK = {"zamba2_1p2b", "gemma2_9b", "xlstm_125m"}


def arch_config(name: str) -> ModelConfig:
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.SMOKE


def shape_skip_reason(arch: str, shape: ShapeCell) -> str | None:
    arch = ALIASES.get(arch, arch)
    if shape.kind == "long_decode" and arch not in LONG_OK:
        return "pure full-attention arch: 500k dense decode has no sub-quadratic path (DESIGN.md shape/skip policy)"
    return None


def input_shapes(arch: str) -> list[ShapeCell]:
    return [s for s in SHAPES if shape_skip_reason(arch, s) is None]
