"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec frontend is a STUB — ``input_specs()``
supplies precomputed frame embeddings (B, S, d_model)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    embed_inputs=True,
)

SMOKE = CONFIG.replace(
    name="musicgen-smoke",
    n_layers=2,
    d_model=48,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=128,
    remat=False,
)
