"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  The shared attn+MLP block (one set of weights)
is applied every 6 Mamba2 layers, zamba2-style.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=128,
    attn_every=6,
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
    attn_every=2,
    remat=False,
)
