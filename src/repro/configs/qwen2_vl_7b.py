"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision tower is a STUB — ``input_specs()`` supplies
precomputed patch embeddings; M-RoPE (t/h/w sections 16/24/24 over the
64 rotary channels) is implemented in full."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    mrope_sections=(16, 24, 24),
    embed_inputs=True,
    loss_chunk=512,
)

SMOKE = CONFIG.replace(
    name="qwen2-vl-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    mrope_sections=(2, 3, 3),
    loss_chunk=0,
    remat=False,
)
