"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: mixing blocks carry their own projections (mLSTM proj factor 2,
sLSTM with a 4/3 GLU FFN).  Every 2nd block is sLSTM."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm_slstm_every=2,
    xlstm_proj_factor=2.0,
    xlstm_chunk=128,
)

SMOKE = CONFIG.replace(
    name="xlstm-smoke",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    vocab=256,
    xlstm_chunk=8,
    remat=False,
)
