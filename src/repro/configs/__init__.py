"""Assigned architecture configs (exact sizes from public literature).

Every arch is selectable via ``--arch <id>``; ``smoke_config`` returns a
reduced same-family variant for CPU tests; ``input_shapes`` enumerates
the four assigned input-shape cells per arch (with documented skips).
"""
from .registry import (
    ARCHS,
    SHAPES,
    arch_config,
    input_shapes,
    shape_skip_reason,
    smoke_config,
)

__all__ = [
    "ARCHS",
    "SHAPES",
    "arch_config",
    "input_shapes",
    "shape_skip_reason",
    "smoke_config",
]
