"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — llama-arch GQA [arXiv:2403.04652; hf].

56 heads are not divisible by the 16-way model axis: baseline relies on
GSPMD's uneven sharding (internal padding); the perf pass pads heads
explicitly (see EXPERIMENTS.md §Perf).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
)

SMOKE = CONFIG.replace(
    name="yi-smoke",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab=256,
    remat=False,
)
