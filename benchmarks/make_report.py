"""Generate the EXPERIMENTS.md data tables from dry-run artifacts.

Writes results/dryrun_table.md and results/roofline_table.md; EXPERIMENTS.md
includes them verbatim.  Run after ``repro.launch.dryrun_all``.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from roofline import load_records, roofline_terms, what_would_help  # noqa: E402


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f} TB"
    if b >= 1e9:
        return f"{b/1e9:.2f} GB"
    if b >= 1e6:
        return f"{b/1e6:.1f} MB"
    return f"{b/1e3:.0f} KB"


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | chips | status | compile (s) | peak HBM/chip | HLO TFLOP/chip | collective/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | SKIP | — | — | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | **{r['status']}** | — | — | — | — |"
            )
            continue
        pd = r["per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} | ok "
            f"| {r['compile_s']} | {pd['peak_hbm_est']/1e9:.1f} GB "
            f"| {pd['flops']/1e12:.2f} | {fmt_bytes(r['collectives']['total_bytes'])} |"
        )
    return "\n".join(lines)


def roofline_table(recs, mesh="single") -> str:
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bottleneck | useful FLOP ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        t = roofline_terms(r)
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['t_compute_s']*1e3:.2f} "
            f"| {t['t_memory_s']*1e3:.2f} | {t['t_collective_s']*1e3:.2f} "
            f"| **{t['dominant']}** | {t['useful_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} | {what_would_help(t)} |"
        )
    return "\n".join(lines)


def main():
    dd = os.path.join(os.path.dirname(__file__), os.pardir, "results", "dryrun")
    out_dir = os.path.join(os.path.dirname(__file__), os.pardir, "results")
    recs = load_records(dd)
    with open(os.path.join(out_dir, "dryrun_table.md"), "w") as f:
        f.write(dryrun_table(recs) + "\n")
    ok_single = [r for r in recs if r.get("mesh") == "single" and r["status"] == "ok"]
    with open(os.path.join(out_dir, "roofline_table.md"), "w") as f:
        f.write(roofline_table(recs, "single") + "\n")
    n_ok = sum(1 for r in recs if r["status"] == "ok")
    n_skip = sum(1 for r in recs if r["status"] == "skipped")
    n_bad = len(recs) - n_ok - n_skip
    print(f"{len(recs)} records: {n_ok} ok, {n_skip} skipped, {n_bad} failed")
    print(f"single-pod ok: {len(ok_single)}")


if __name__ == "__main__":
    main()
