"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = simulated
reconfiguration wall time in microseconds; derived = the paper-facing
ratio for that row), followed by the envelope summary versus the paper's
reported numbers and, when dry-run artifacts exist, the roofline table.

``--smoke`` shrinks the expensive grids to a CI-sized subset (tiny node
lists, one model config) so the whole run finishes in seconds; the
scenario, hetero, and policy tables always run in full (they are cheap,
and their coverage is the point of the uploaded artifact).  The CI
benchmark job uploads stdout as a workflow artifact.

``--json`` emits the same rows as a machine-readable document — this is
the bench-regression gate's interchange format: ``BENCH_baseline.json``
at the repo root is a committed ``--smoke --json`` run (refresh it with
``scripts/check_bench.py --update``), and ``scripts/check_bench.py``
fails CI when any row's est_wall drifts more than 10% from it.  JSON
rows are emitted in a stable sort order (by row name, so scenario then
strategy; duplicates keep their relative order), which keeps baseline
diffs reviewable and ``--update`` runs reproducible.

The document also carries a ``scale`` section: MEASURED simulator
throughput (object vs vectorized events/sec on 1k/10k/100k churn
traces, plus a 1000-replica Monte-Carlo sweep over a 10k-node pod).
Those numbers are machine-dependent, so the gate never drift-compares
them — it applies thresholds instead (min vectorized speedup, max MC
wall seconds).  ``--no-scale`` skips the section for quick local runs.

``--repeat N`` re-collects the deterministic tables N times and prints
a per-table wall-time report (best-of-N), which is how the simulator's
own throughput is profiled without touching the row output.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from paper_tables import (  # noqa: E402
    MN5_NODES,
    NASP_NODES,
    REDIST_ARCHS,
    SCHED_SMOKE_GRID,
    SCHED_SMOKE_RANDOM,
    fig1_hypercube_rounds,
    fig4a_homogeneous_expansion,
    fig4b_homogeneous_shrink,
    fig5_preferred_grid,
    fig6_heterogeneous,
    overlap_sweep,
    paper_envelopes,
    policy_sweep,
    scenario_traces,
    table2_trace,
    table_faults,
    table_hetero_strategies,
    table_redistribution,
    table_scale,
    table_scheduler,
    table_serve,
    table_throughput,
    table_topology,
)

SMOKE_MN5_NODES = [1, 2, 4]
SMOKE_NASP_NODES = [1, 2, 4]
SMOKE_REDIST_ARCHS = ("xlstm_125m",)


def collect_rows(smoke: bool = False, timings: dict | None = None) -> list[dict]:
    """Every table as flat ``{"name", "us_per_call", "derived"}`` rows.

    When ``timings`` is a dict, each table's producer wall time (seconds)
    is recorded under its table name — keeping the minimum across repeat
    calls, so ``--repeat N`` reports best-of-N per table.
    """
    mn5 = SMOKE_MN5_NODES if smoke else MN5_NODES
    nasp = SMOKE_NASP_NODES if smoke else NASP_NODES
    archs = SMOKE_REDIST_ARCHS if smoke else REDIST_ARCHS

    rows: list[dict] = []

    def add(name: str, us: float, derived: str) -> None:
        rows.append({"name": name, "us_per_call": round(us), "derived": derived})

    def timed(table: str, producer):
        t0 = time.perf_counter()
        out = producer()
        if timings is not None:
            dt = time.perf_counter() - t0
            timings[table] = min(timings.get(table, dt), dt)
        return out

    for r in timed("fig4a", lambda: fig4a_homogeneous_expansion(mn5)):
        add(f"fig4a/{r['method']}/I{r['I']}-N{r['N']}",
            r["time_s"] * 1e6, f"{r['vs_merge']}")

    for r in timed("fig4b", lambda: fig4b_homogeneous_shrink(mn5)):
        add(f"fig4b/{r['method']}/I{r['I']}-N{r['N']}",
            r["time_s"] * 1e6, f"{r['speedup_ts']}")

    for r in timed("fig5", lambda: fig5_preferred_grid(mn5)):
        add(f"fig5/I{r['I']}-N{r['N']}", r["time_s"] * 1e6, f"{r['best']}")

    for r in timed("fig6", lambda: fig6_heterogeneous(nasp)):
        derived = r.get("vs_merge", r.get("speedup_ts", ""))
        add(f"fig{r['figure']}/{r['method']}/I{r['I']}-N{r['N']}",
            r["time_s"] * 1e6, f"{derived}")

    for r in timed("table2", table2_trace):
        add(f"table2/s{r['s']}", 0,
            f"t={r['t']};g={r['g']};lam={r['lambda']};T={r['T']};G={r['G']}")

    for r in timed("fig1", fig1_hypercube_rounds):
        add(f"fig1/C{r['C']}-I{r['I']}-N{r['N']}", 0,
            f"rounds={r['rounds']};groups={r['groups']}")

    for r in timed("scenario", scenario_traces):
        add(f"scenario/{r['scenario']}/s{r['step']}-{r['kind']}",
            r["time_s"] * 1e6,
            f"downtime_us={r['downtime_s']*1e6:.0f};{r['mechanism']};"
            f"{r['nodes']};bytes={r['bytes_moved']};"
            f"stayed={r['bytes_stayed']}")

    for r in timed("hetero", table_hetero_strategies):
        add(f"hetero/{r['scenario']}/{r['strategy']}",
            r["makespan_s"] * 1e6,
            f"downtime_us={r['downtime_s']*1e6:.0f};events={r['events']};"
            f"bytes={r['bytes_moved']};stayed={r['bytes_stayed']}")

    for r in timed("topo", table_topology):
        add(f"topo/{r['scenario']}/{r['strategy']}",
            r["makespan_s"] * 1e6,
            f"downtime_us={r['downtime_s']*1e6:.0f};events={r['events']};"
            f"intra_node={r['bytes_intra_node']};"
            f"intra_rack={r['bytes_intra_rack']};"
            f"cross_rack={r['bytes_cross_rack']};"
            f"cross_pod={r['bytes_cross_pod']}")

    for r in timed("redist", lambda: table_redistribution(archs)):
        add(f"redist/{r['arch']}/{r['bytes_model']}/I{r['I']}-N{r['N']}",
            r["time_s"] * 1e6,
            f"bytes={r['bytes_moved']};redist_share={r['redist_share']}")

    for r in timed("overlap",
                   lambda: overlap_sweep(archs[0] if smoke else "stablelm_3b")):
        add(f"overlap/{r['arch']}/f{r['overlap_fraction']}-c{r['contention']}",
            r["downtime_s"] * 1e6,
            f"wall_us={r['est_wall_s']*1e6:.0f};hidden={r['hidden_share']}")

    for r in timed("policy", policy_sweep):
        add(f"policy/{r['policy']}/{r['strategy']}",
            r["makespan_s"] * 1e6,
            f"downtime_us={r['downtime_s']*1e6:.0f};"
            f"queued_us={r['queued_s']*1e6:.0f};events={r['events']};"
            f"bytes={r['bytes_moved']}")

    for r in timed("faults", table_faults):
        add(f"faults/{r['scenario']}/{r['strategy']}",
            r["makespan_s"] * 1e6,
            f"downtime_us={r['downtime_s']*1e6:.0f};"
            f"restored_us={r['restored_s']*1e6:.0f};events={r['events']};"
            f"ckpt={r['bytes_checkpointed']};restored={r['bytes_restored']};"
            f"bytes={r['bytes_moved']}")

    for r in timed("serve", table_serve):
        add(f"serve/{r['scenario']}/{r['strategy']}",
            r["p50_latency_s"] * 1e6,
            f"p99_us={r['p99_latency_s']*1e6:.0f};"
            f"downtime_us={r['downtime_s']*1e6:.0f};"
            f"queued_us={r['queued_s']*1e6:.0f};"
            f"resizes={r['resizes']};done={r['completed']};"
            f"bytes={r['bytes_moved']};cross_rack={r['bytes_cross_rack']}")

    # --smoke shrinks the knob search (8-corner grid, 2 restarts); the
    # workloads themselves always run in full — their closed-loop
    # coverage is the point of the table.
    sched = (lambda: table_scheduler(grid=SCHED_SMOKE_GRID,
                                     n_random=SCHED_SMOKE_RANDOM)
             ) if smoke else table_scheduler
    for r in timed("sched", sched):
        add(f"sched/{r['workload']}/{r['strategy']}",
            r["makespan_s"] * 1e6,
            f"score={r['score']};beats_rigid={r['beats_baseline']};"
            f"downtime_us={r['downtime_s']*1e6:.0f};"
            f"expand_downtime_us={r['expand_downtime_s']*1e6:.0f};"
            f"queue_s={r['mean_queue_s']};util={r['utilization']};"
            f"reconfigs={r['reconfigs']}")

    # Same smoke shrink for the throughput objective-swap search; the
    # strategy trace rows always run in full (cheap, coverage is the
    # point).
    thrpt = (lambda: table_throughput(grid=SCHED_SMOKE_GRID,
                                      n_random=SCHED_SMOKE_RANDOM)
             ) if smoke else table_throughput
    for r in timed("thrpt", thrpt):
        if r["table"] == "strategy":
            add(f"thrpt/{r['scenario']}/{r['strategy']}",
                r["time_to_result_s"] * 1e6,
                f"makespan_us={r['makespan_s']*1e6:.0f};"
                f"accrued_us={r['accrued_s']*1e6:.0f};"
                f"events={r['events']};uneven={r['uneven_pool']}")
        else:
            add(f"thrpt/opt/{r['workload']}/{r['objective']}",
                r["time_to_result_s"] * 1e6,
                f"makespan_us={r['makespan_s']*1e6:.0f};"
                f"queue_s={r['mean_queue_s']};util={r['utilization']};"
                f"knobs={r['knobs']};diverges={r['diverges']};"
                f"wins={r['wins']}")

    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny grids for CI: same tables, seconds instead of minutes",
    )
    ap.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit rows + envelopes as JSON (the bench-regression format)",
    )
    ap.add_argument(
        "--repeat", type=int, default=1, metavar="N",
        help="collect the deterministic tables N times and report "
             "best-of-N wall time per table",
    )
    ap.add_argument(
        "--no-scale", action="store_true",
        help="skip the measured-throughput scale section "
             "(object-vs-vectorized churn + Monte-Carlo sweep)",
    )
    args = ap.parse_args(argv)
    if args.repeat < 1:
        ap.error("--repeat must be >= 1")
    mn5 = SMOKE_MN5_NODES if args.smoke else MN5_NODES
    nasp = SMOKE_NASP_NODES if args.smoke else NASP_NODES

    timings: dict = {}
    for _ in range(args.repeat):
        rows = collect_rows(smoke=args.smoke, timings=timings)
    envelopes = paper_envelopes(mn5, nasp)
    scale = [] if args.no_scale else table_scale()

    def timing_report(stream) -> None:
        print(f"=== per-table wall time (best of {args.repeat}) ===",
              file=stream)
        for table, dt in sorted(timings.items(), key=lambda kv: -kv[1]):
            print(f"{table:<10} {dt*1e3:10.1f} ms", file=stream)
        print(f"{'total':<10} {sum(timings.values())*1e3:10.1f} ms",
              file=stream)

    if args.as_json:
        # Stable row order (scenario, strategy — encoded in the name):
        # baseline diffs stay reviewable and --update is reproducible.
        # sorted() is stable, so duplicate names keep their relative
        # order and the gate's #k disambiguation is unaffected.  The
        # measured `scale` section is exempt from that reproducibility
        # contract — the gate thresholds it instead of diffing it.
        rows = sorted(rows, key=lambda r: r["name"])
        print(json.dumps(
            {
                "smoke": args.smoke,
                "rows": rows,
                "envelopes": [
                    {"metric": r["metric"], "ours": r["ours"],
                     "paper": r["paper"]}
                    for r in envelopes
                ],
                "scale": scale,
            },
            indent=1,
        ))
        if args.repeat > 1:
            timing_report(sys.stderr)
        return

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")

    print()
    print("=== paper envelope check (simulator vs paper §5) ===")
    for r in envelopes:
        print(f"{r['metric']}: ours={r['ours']} paper={r['paper']}")

    if scale:
        print()
        print("=== simulator throughput (measured, machine-dependent) ===")
        for r in scale:
            if r["table"] == "scale":
                obj = (f"object={r['object_events_per_s']}/s"
                       if r["object_measured"]
                       else f"object~{r['object_events_per_s']}/s (extrap.)")
                print(f"scale/{r['events']}ev: "
                      f"vectorized={r['vectorized_events_per_s']}/s {obj} "
                      f"speedup={r['speedup_vs_object']}x")
            else:
                print(f"scale-mc/{r['pool_nodes']}nodes-"
                      f"{r['replicas']}replicas: {r['reconfigs']} reconfigs "
                      f"in {r['wall_s']}s ({r['reconfigs_per_s']}/s, "
                      f"cache {r['cache_hits']}h/{r['cache_misses']}m)")

    if args.repeat > 1:
        print()
        timing_report(sys.stdout)

    # roofline table if the dry-run has produced artifacts
    dd = os.path.join(os.path.dirname(__file__), os.pardir, "results", "dryrun")
    if os.path.isdir(dd) and os.listdir(dd):
        from roofline import table, what_would_help  # noqa: E402,F401

        rows = table(dd, mesh="single")
        if rows:
            print()
            print("=== roofline (single-pod, per chip) ===")
            print("arch,shape,t_compute_ms,t_memory_ms,t_collective_ms,"
                  "dominant,useful_ratio,roofline_fraction,peak_hbm_gb")
            for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
                print(
                    f"{r['arch']},{r['shape']},{r['t_compute_s']*1e3:.2f},"
                    f"{r['t_memory_s']*1e3:.2f},{r['t_collective_s']*1e3:.2f},"
                    f"{r['dominant']},{r['useful_ratio']:.2f},"
                    f"{r['roofline_fraction']:.3f},{r['peak_hbm_gb']:.1f}"
                )


if __name__ == "__main__":
    main()
